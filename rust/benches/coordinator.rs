//! End-to-end coordinator benchmark: measured host base-calling
//! throughput through the full DNN + CTC + vote pipeline (the L3 perf
//! deliverable), plus batching-policy ablation, DNN-shard scaling
//! (`dnn_shards` 1/2/4 with per-shard utilization), and adaptive
//! autoscaling under a bursty synthetic load (`autoscale_rows`: the
//! scale-event trace showing the pool converging upward), and the
//! tiered-serving accuracy-vs-throughput sweep (`tier_rows`: hq
//! agreement and escalation cost across `--escalate-margin` values,
//! with an hq-only baseline row), and the multi-tenant TCP front-end
//! (`serve_rows`: many-small vs few-huge tenant shapes over a real
//! socket, measuring wire-path cost against the library numbers), and
//! the streaming assembly + early-rejection sweep (`pipeline_rows`:
//! the `helix assemble` path across reject thresholds, with the
//! streaming-vs-offline consensus identity asserted inline).
//! Self-contained:
//! runs on the native quantized backend by default (artifacts are
//! materialized on first run); HELIX_BACKEND=xla on a `--features xla`
//! build benchmarks the PJRT engine over `make artifacts` output instead.
//!
//!     cargo bench --bench coordinator
//!
//! Knob-to-paper-figure mapping for every emitted field: docs/TUNING.md.

use std::time::Duration;

use helix::basecall::ctc::beam_search;
use helix::bench::timer::bench;
use helix::coordinator::{AutoscaleConfig, BatchPolicy, Coordinator,
                         CoordinatorConfig, ScaleAction};
use helix::genome::pore::PoreModel;
use helix::genome::synth::{RunSpec, SequencingRun};
use helix::runtime::meta::default_artifacts_dir;
use helix::runtime::{Backend, BackendKind};

fn main() {
    let dir = default_artifacts_dir();
    let kind = BackendKind::from_env().unwrap();
    kind.prepare(&dir).unwrap();
    let pm = PoreModel::load(&format!("{dir}/pore_model.json")).unwrap();
    let run = SequencingRun::simulate(&pm, RunSpec {
        genome_len: 1200,
        coverage: 4,
        seed: 99,
        ..Default::default()
    });
    let total_bases: usize = run.reads.iter().map(|r| r.seq.len()).sum();

    // raw DNN executor throughput at each exported batch size
    println!("== {} DNN executor ==", kind.name());
    let mut backend = kind.open(&dir).unwrap();
    let window = backend.meta().window;
    let sig = vec![0.1f32; window];
    for b in backend.meta().batches("guppy", 32) {
        let sigs: Vec<Vec<f32>> = (0..b).map(|_| sig.clone()).collect();
        let t = backend.meta().find("guppy", 32, b).unwrap().time_steps;
        let st = bench(&format!("guppy fp32 batch={b} (T={t})"), 400, || {
            std::hint::black_box(
                backend.run_windows("guppy", 32, &sigs).unwrap());
        });
        let windows_per_sec = b as f64 / (st.median_ns / 1e9);
        println!("    -> {windows_per_sec:.0} windows/s \
                  (~{:.0} bases/s DNN-only)", windows_per_sec * 30.0);
    }

    // decode cost on realistic outputs
    let lps = {
        let sigs: Vec<Vec<f32>> = run.reads[0].signal
            .chunks(window).take(1)
            .map(|c| {
                let mut v = c.to_vec();
                v.resize(window, 0.0);
                v
            })
            .collect();
        backend.run_windows("guppy", 32, &sigs).unwrap()
    };
    bench("beam_search width=10 on real output", 200, || {
        std::hint::black_box(beam_search(&lps[0], 10));
    });

    // full coordinator with different batch policies; per-read p50/p99
    // latency comes from the streaming collector's histogram
    println!("\n== coordinator end-to-end ({} reads, {} bases) ==",
             run.reads.len(), total_bases);
    let mut rows: Vec<String> = Vec::new();
    for (label, policy) in [
        ("batch=1", BatchPolicy { max_batch: 1,
                                  max_wait: Duration::ZERO }),
        ("batch=8/5ms", BatchPolicy { max_batch: 8,
                                      max_wait: Duration::from_millis(5) }),
        ("batch=32/10ms", BatchPolicy { max_batch: 32,
                                        max_wait: Duration::from_millis(10) }),
    ] {
        let t0 = std::time::Instant::now();
        let mut coord = Coordinator::new(CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            backend: kind,
            policy,
            artifacts_dir: dir.clone(),
            ..Default::default()
        }).unwrap();
        let mut called = Vec::new();
        for r in &run.reads {
            coord.submit(r);
            // streaming drain keeps the bounded output queue moving
            called.extend(coord.drain_ready());
        }
        let metrics = coord.metrics.clone();
        called.extend(coord.finish().unwrap());
        let dt = t0.elapsed().as_secs_f64();
        let bases: usize = called.iter().map(|c| c.seq.len()).sum();
        let p50 = metrics.read_latency.quantile_micros(0.50);
        let p99 = metrics.read_latency.quantile_micros(0.99);
        println!("{label:<14} {:>8.2}s  {:>9.0} bases/s   fill {:.2}   \
                  lat p50 {:.1}ms p99 {:.1}ms",
                 dt, bases as f64 / dt,
                 metrics.mean_batch_fill(policy.max_batch),
                 p50 as f64 / 1e3, p99 as f64 / 1e3);
        rows.push(format!(
            "{{\"policy\": \"{label}\", \"wall_s\": {dt:.3}, \
             \"bases_per_s\": {:.0}, \"batch_fill\": {:.3}, \
             \"p50_us\": {p50}, \"p99_us\": {p99}}}",
            bases as f64 / dt,
            metrics.mean_batch_fill(policy.max_batch)));
    }
    // DNN-shard scaling: a bigger run so there are enough batches to
    // spread, small batches so the shards interleave. The scaling
    // number is the DNN *stage* throughput — windows per second of the
    // busiest shard's forward-pass time — which is the stage's capacity
    // whether or not the surrounding pipeline (decode-bound on 2 cores)
    // can consume it.
    let shard_run = SequencingRun::simulate(&pm, RunSpec {
        genome_len: 4000,
        coverage: 10,
        seed: 131,
        ..Default::default()
    });
    println!("\n== dnn shard scaling ({} reads) ==", shard_run.reads.len());
    let mut shard_rows: Vec<String> = Vec::new();
    let mut base_win_per_s = 0.0f64;
    for shards in [1usize, 2, 4] {
        let t0 = std::time::Instant::now();
        let mut coord = Coordinator::new(CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            backend: kind,
            dnn_shards: shards,
            decode_threads: 4,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
            },
            artifacts_dir: dir.clone(),
            ..Default::default()
        }).unwrap();
        let mut called = Vec::new();
        for r in &shard_run.reads {
            coord.submit(r);
            called.extend(coord.drain_ready());
        }
        let metrics = coord.metrics.clone();
        called.extend(coord.finish().unwrap());
        let dt = t0.elapsed().as_secs_f64();
        let win_per_s = metrics.dnn_stage_windows_per_s();
        if shards == 1 {
            base_win_per_s = win_per_s;
        }
        let utils: Vec<String> = metrics.shard_utilization()
            .iter().map(|u| format!("{u:.3}")).collect();
        println!("shards={shards}  {:>8.2}s wall  dnn-stage {:>9.0} \
                  win/s ({:.2}x)  util [{}]",
                 dt, win_per_s,
                 if base_win_per_s > 0.0 { win_per_s / base_win_per_s }
                 else { 1.0 },
                 utils.join(" "));
        shard_rows.push(format!(
            "{{\"shards\": {shards}, \"wall_s\": {dt:.3}, \
             \"dnn_stage_win_per_s\": {win_per_s:.0}, \
             \"speedup_vs_1\": {:.3}, \"shard_util\": [{}]}}",
            if base_win_per_s > 0.0 { win_per_s / base_win_per_s }
            else { 1.0 },
            utils.join(", ")));
    }

    // adaptive autoscaling under a BURSTY load: reads arrive in waves
    // with idle gaps, starting from one live shard. The deliverable is
    // the scale-event trace (autoscale_rows): under the bursts the
    // controller must converge the pool upward from min_shards, and the
    // summary records where it landed. Determinism of the called output
    // is pinned separately in tests/coordinator_stream.rs; this section
    // is about convergence speed and final shape.
    println!("\n== adaptive autoscaling (bursty load, {} reads) ==",
             shard_run.reads.len());
    let mut autoscale_rows: Vec<String> = Vec::new();
    let autoscale_summary;
    {
        let acfg = AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            tick: Duration::from_millis(10),
            high_util: 0.40,
            low_util: 0.05,
            up_ticks: 1,
            down_ticks: 5,
            cooldown_ticks: 1,
            ..AutoscaleConfig::default()
        };
        let t0 = std::time::Instant::now();
        let mut coord = Coordinator::new(CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            backend: kind,
            dnn_shards: 1,
            decode_threads: 4,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
            },
            autoscale: Some(acfg),
            artifacts_dir: dir.clone(),
            ..Default::default()
        }).unwrap();
        let mut called = Vec::new();
        for (i, r) in shard_run.reads.iter().enumerate() {
            coord.submit(r);
            called.extend(coord.drain_ready());
            if i % 48 == 47 {
                // inter-burst gap: long enough for utilization to dip,
                // short enough that the next burst re-saturates
                std::thread::sleep(Duration::from_millis(25));
            }
        }
        let final_live = coord.live_dnn_shards();
        let metrics = coord.metrics.clone();
        called.extend(coord.finish().unwrap());
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(called.len(), shard_run.reads.len());
        let events = metrics.scale_events();
        let ups = events.iter()
            .filter(|e| e.action == ScaleAction::Up).count();
        let downs = events.iter()
            .filter(|e| e.action == ScaleAction::Down).count();
        let peak_live = events.iter()
            .map(|e| e.live_after).max().unwrap_or(1);
        for e in &events {
            autoscale_rows.push(format!(
                "{{\"t_ms\": {:.1}, \"stage\": \"{}\", \
                 \"action\": \"{}\", \"slot\": {}, \"live\": {}}}",
                e.at_micros as f64 / 1e3, e.stage.name(),
                e.action.name(), e.slot, e.live_after));
        }
        println!("min 1 / max 4, tick 10ms: {} scale events \
                  (+{ups}/-{downs}), peak live {peak_live}, live at \
                  end-of-submission {final_live}, {dt:.2}s wall",
                 events.len());
        println!("{}", metrics.report(8));
        autoscale_summary = format!(
            "{{\"min_shards\": 1, \"max_shards\": 4, \
             \"tick_ms\": 10, \"ups\": {ups}, \"downs\": {downs}, \
             \"peak_live\": {peak_live}, \"final_live\": {final_live}, \
             \"wall_s\": {dt:.3}}}");
    }

    // SLO-driven scaling under a latency-sensitive TRICKLE load: one
    // small read at a time with idle gaps, so shard utilization stays
    // near zero — but a wide batch with a long deadline makes every
    // window wait out max_wait, so the p99 of each tick's completions
    // breaches the SLO and the controller must grow the pool on the
    // latency signal alone (utilization thresholds are set so they can
    // never fire). The deliverable is slo_rows: the stage-tagged
    // scale-event trace of a pool scaling up while "idle".
    println!("\n== SLO-driven scaling (trickle load, utilization ~0) ==");
    let mut slo_rows: Vec<String> = Vec::new();
    let slo_summary;
    {
        let slo = Duration::from_millis(5);
        let acfg = AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            tick: Duration::from_millis(10),
            high_util: 2.0, // unreachable: never hot by utilization
            low_util: 0.0,  // unreachable: never cold either
            up_ticks: 1,
            down_ticks: 1,
            cooldown_ticks: 1,
            slo: Some(slo),
            ..AutoscaleConfig::default()
        };
        let t0 = std::time::Instant::now();
        let mut coord = Coordinator::new(CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            backend: kind,
            dnn_shards: 1,
            policy: BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(25),
            },
            autoscale: Some(acfg),
            artifacts_dir: dir.clone(),
            ..Default::default()
        }).unwrap();
        let mut called = Vec::new();
        let n_trickle = run.reads.len().min(30);
        for r in run.reads.iter().take(n_trickle) {
            coord.submit(r);
            called.extend(coord.drain_ready());
            std::thread::sleep(Duration::from_millis(12));
        }
        let final_live = coord.live_dnn_shards();
        let metrics = coord.metrics.clone();
        called.extend(coord.finish().unwrap());
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(called.len(), n_trickle);
        let events = metrics.scale_events();
        let ups = events.iter()
            .filter(|e| e.action == ScaleAction::Up).count();
        assert!(ups >= 1,
                "p99 {}µs over the {slo:?} SLO must scale the pool up \
                 even at ~0 utilization (events: {events:?})",
                metrics.read_latency.quantile_micros(0.99));
        for e in &events {
            slo_rows.push(format!(
                "{{\"t_ms\": {:.1}, \"stage\": \"{}\", \
                 \"action\": \"{}\", \"slot\": {}, \"live\": {}}}",
                e.at_micros as f64 / 1e3, e.stage.name(),
                e.action.name(), e.slot, e.live_after));
        }
        let p99_ms = metrics.read_latency.quantile_micros(0.99)
            as f64 / 1e3;
        let mean_util = {
            let u = metrics.shard_utilization();
            u.iter().sum::<f64>() / u.len().max(1) as f64
        };
        println!("slo p99<{slo:?}, tick 10ms: {} scale events \
                  (+{ups}), run p99 {p99_ms:.1}ms, mean util \
                  {mean_util:.3}, live at end {final_live}, \
                  {dt:.2}s wall", events.len());
        println!("{}", metrics.report(64));
        slo_summary = format!(
            "{{\"slo_ms\": 5, \"p99_ms\": {p99_ms:.1}, \
             \"mean_util\": {mean_util:.3}, \"ups\": {ups}, \
             \"final_live\": {final_live}, \"wall_s\": {dt:.3}}}");
    }

    // Tiered serving sweep: speculative fast tier (auto-picked low-bit
    // rung) with confidence-gated escalation to the hq tier, across
    // escalation margins. The accuracy axis is hq agreement — the
    // fraction of reads whose called sequence is byte-identical to the
    // hq-only baseline (margin "inf" must reach 1.0 by construction;
    // margin 0 shows what the fast tier alone gives up). The throughput
    // axis is wall-clock bases/s of the full pipeline. Paper framing:
    // Helix's low-bit quantization buys throughput at an accuracy cost;
    // the margin knob trades the two continuously instead of forcing a
    // global bit-width choice.
    println!("\n== tiered serving sweep ({} reads) ==", run.reads.len());
    let mut tier_rows: Vec<String> = Vec::new();
    let tier_summary;
    {
        let call_tiered = |margin: Option<f32>| {
            let t0 = std::time::Instant::now();
            let mut coord = Coordinator::new(CoordinatorConfig {
                model: "guppy".into(),
                bits: 32,
                backend: kind,
                decode_threads: 4,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(5),
                },
                escalate_margin: margin,
                artifacts_dir: dir.clone(),
                ..Default::default()
            }).unwrap();
            let tiers = coord.tier_set()
                .map(|t| (t.fast_bits, t.hq_bits));
            let mut called = Vec::new();
            for r in &run.reads {
                coord.submit(r);
                called.extend(coord.drain_ready());
            }
            let metrics = coord.metrics.clone();
            called.extend(coord.finish().unwrap());
            called.sort_by_key(|c| c.read_id);
            (called, metrics, t0.elapsed().as_secs_f64(), tiers)
        };
        let (hq_called, _hm, hq_dt, _t) = call_tiered(None);
        let hq_bases: usize =
            hq_called.iter().map(|c| c.seq.len()).sum();
        println!("hq-only        {hq_dt:>8.2}s  {:>9.0} bases/s  \
                  (agreement 1.000 by definition)",
                 hq_bases as f64 / hq_dt);
        tier_rows.push(format!(
            "{{\"margin\": \"hq-only\", \"wall_s\": {hq_dt:.3}, \
             \"bases_per_s\": {:.0}, \"hq_agreement\": 1.0, \
             \"esc_rate\": 0.0, \"esc_p99_ms\": 0.0, \
             \"fast_decided\": 0, \"escalations\": 0}}",
            hq_bases as f64 / hq_dt));
        let mut fastbits = (0u32, 32u32);
        for margin in [0.0f32, 1.0, 3.0, f32::INFINITY] {
            let (called, m, dt, tiers) = call_tiered(Some(margin));
            if let Some(t) = tiers {
                fastbits = t;
            }
            let bases: usize = called.iter().map(|c| c.seq.len()).sum();
            let agree = called.iter().zip(&hq_called)
                .filter(|(a, b)| a.seq == b.seq)
                .count() as f64 / hq_called.len().max(1) as f64;
            let esc_rate = m.escalation_rate();
            let esc_p99_ms = m.escalation_latency
                .quantile_micros(0.99) as f64 / 1e3;
            let mlabel = if margin.is_infinite() { "inf".into() }
                         else { format!("{margin}") };
            println!("margin {mlabel:<7} {dt:>8.2}s  {:>9.0} bases/s  \
                      agreement {agree:.3}  esc {:.1}% p99 \
                      {esc_p99_ms:.1}ms",
                     bases as f64 / dt, esc_rate * 100.0);
            tier_rows.push(format!(
                "{{\"margin\": \"{mlabel}\", \"wall_s\": {dt:.3}, \
                 \"bases_per_s\": {:.0}, \"hq_agreement\": {agree:.4}, \
                 \"esc_rate\": {esc_rate:.4}, \
                 \"esc_p99_ms\": {esc_p99_ms:.2}, \
                 \"fast_decided\": {}, \"escalations\": {}}}",
                bases as f64 / dt,
                m.fast_decided.load(std::sync::atomic::Ordering::Relaxed),
                m.escalations.load(std::sync::atomic::Ordering::Relaxed)));
        }
        tier_summary = format!(
            "{{\"fast_bits\": {}, \"hq_bits\": {}, \
             \"hq_only_wall_s\": {hq_dt:.3}}}",
            fastbits.0, fastbits.1);
    }

    // Multi-tenant TCP serving: the same pipeline behind the wire
    // front-end (`coordinator::net`), measured in two tenant shapes.
    // "many-small" fans the run's reads across 8 concurrent clients —
    // the per-connection/framing overhead and fan-in path; "few-huge"
    // streams long concatenated signals from 2 clients — the sustained
    // single-stream throughput path. Quota is unlimited here (admission
    // *behavior* is pinned by the test suite); the axis being tracked
    // is wire-path cost vs the in-process library numbers above.
    println!("\n== tcp serving ({} reads) ==", run.reads.len());
    let mut serve_rows: Vec<String> = Vec::new();
    let serve_summary;
    {
        use helix::coordinator::{Client, ServeConfig, Server};
        let small: Vec<Vec<f32>> = run.reads.iter()
            .map(|r| r.signal.clone()).collect();
        let huge: Vec<Vec<f32>> = (0..4usize)
            .map(|lane| {
                let mut s = Vec::new();
                for r in run.reads.iter().skip(lane).step_by(4) {
                    s.extend_from_slice(&r.signal);
                }
                s
            })
            .collect();
        let scenarios: [(&str, usize, &Vec<Vec<f32>>); 2] =
            [("many-small", 8, &small), ("few-huge", 2, &huge)];
        for (label, clients, signals) in scenarios {
            let server = Server::start(CoordinatorConfig {
                model: "guppy".into(),
                bits: 32,
                backend: kind,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(5),
                },
                artifacts_dir: dir.clone(),
                ..Default::default()
            }, ServeConfig {
                tenant_quota: 0,
                ..ServeConfig::default()
            }).unwrap();
            let addr = server.local_addr();
            let t0 = std::time::Instant::now();
            let handles: Vec<_> = (0..clients).map(|lane| {
                let mine: Vec<Vec<f32>> = signals.iter().enumerate()
                    .filter(|(i, _)| i % clients == lane)
                    .map(|(_, s)| s.clone()).collect();
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    for (i, s) in mine.iter().enumerate() {
                        c.submit(i as u64, s).unwrap();
                    }
                    let summary = c.drain().unwrap();
                    let bases: usize = summary.results.iter()
                        .map(|(_, s)| s.len()).sum();
                    (summary.results.len(), bases)
                })
            }).collect();
            let mut reads_out = 0usize;
            let mut bases = 0usize;
            for h in handles {
                let (r, b) = h.join().unwrap();
                reads_out += r;
                bases += b;
            }
            let dt = t0.elapsed().as_secs_f64();
            let m = server.metrics();
            let p99_ms =
                m.read_latency.quantile_micros(0.99) as f64 / 1e3;
            server.shutdown().unwrap();
            println!("{label:<12} {clients} clients  {dt:>8.2}s  \
                      {:>9.0} bases/s   {reads_out} reads  \
                      lat p99 {p99_ms:.1}ms",
                     bases as f64 / dt);
            serve_rows.push(format!(
                "{{\"scenario\": \"{label}\", \"clients\": {clients}, \
                 \"reads\": {reads_out}, \"wall_s\": {dt:.3}, \
                 \"bases_per_s\": {:.0}, \"p99_ms\": {p99_ms:.2}}}",
                bases as f64 / dt));
        }
        serve_summary =
            format!("{{\"scenarios\": {}, \"tenant_quota\": 0}}",
                    serve_rows.len());
    }

    // Streaming assembly + early rejection: the `helix assemble` path
    // measured end-to-end (`pipeline_rows`). Voted reads side-feed the
    // in-pipeline analysis stage; the sweep walks the reject threshold
    // from off through a finite margin to "inf" (reject everything
    // with a finite top-2 margin, i.e. all of it). Axes per row: wall
    // throughput, reads surviving the gate, decode windows skipped by
    // rejection, polished consensus length and its identity to the
    // simulated genome — and the streaming-vs-offline byte-identity
    // flag, asserted inline so a divergence fails the bench loudly
    // rather than publishing a wrong row.
    println!("\n== streaming assembly + rejection ({} reads) ==",
             run.reads.len());
    let mut pipeline_rows: Vec<String> = Vec::new();
    let pipeline_summary;
    {
        use helix::basecall::edit::identity;
        use helix::coordinator::ANALYSIS_MIN_OVERLAP;
        let call_assemble = |reject: Option<f32>| {
            let t0 = std::time::Instant::now();
            let mut coord = Coordinator::new(CoordinatorConfig {
                model: "guppy".into(),
                bits: 32,
                backend: kind,
                decode_threads: 4,
                analysis_threads: 2,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(5),
                },
                reject_threshold: reject,
                artifacts_dir: dir.clone(),
                ..Default::default()
            }).unwrap();
            let state = coord.analysis_state().unwrap();
            let mut called = Vec::new();
            for r in &run.reads {
                coord.submit(r);
                called.extend(coord.drain_ready());
            }
            let metrics = coord.metrics.clone();
            called.extend(coord.finish().unwrap());
            called.sort_by_key(|c| c.read_id);
            (called, state.consensus(0), metrics,
             t0.elapsed().as_secs_f64())
        };
        for (label, reject) in [("off", None),
                                ("0", Some(0.0f32)),
                                ("1.5", Some(1.5)),
                                ("inf", Some(f32::INFINITY))] {
            let (called, consensus, m, dt) = call_assemble(reject);
            let seqs: Vec<Vec<u8>> =
                called.iter().map(|c| c.seq.clone()).collect();
            let offline =
                helix::pipeline::consensus(&seqs, ANALYSIS_MIN_OVERLAP);
            assert_eq!(consensus, offline,
                       "streaming consensus diverged from the offline \
                        pipeline at reject {label}");
            let id = identity(&consensus, &run.genome);
            let rejected = m.rejected_reads
                .load(std::sync::atomic::Ordering::Relaxed);
            let rwin = m.rejected_windows
                .load(std::sync::atomic::Ordering::Relaxed);
            let bases: usize =
                called.iter().map(|c| c.seq.len()).sum();
            println!("reject {label:<5} {dt:>8.2}s  {:>9.0} bases/s  \
                      {} reads out ({rejected} rejected, {rwin} \
                      windows skipped)  consensus {} bp  identity \
                      {id:.4}",
                     bases as f64 / dt, called.len(), consensus.len());
            pipeline_rows.push(format!(
                "{{\"reject\": \"{label}\", \"wall_s\": {dt:.3}, \
                 \"bases_per_s\": {:.0}, \"reads_out\": {}, \
                 \"rejected_reads\": {rejected}, \
                 \"rejected_windows\": {rwin}, \
                 \"consensus_len\": {}, \"identity\": {id:.4}, \
                 \"offline_match\": true}}",
                bases as f64 / dt, called.len(), consensus.len()));
        }
        pipeline_summary = format!(
            "{{\"analysis_threads\": 2, \
             \"min_overlap\": {ANALYSIS_MIN_OVERLAP}, \
             \"genome_len\": 1200}}");
    }

    // machine-readable summary for the perf trajectory (see ci.sh);
    // field semantics are documented in docs/TUNING.md
    let json = format!(
        "{{\"bench\": \"coordinator\", \"backend\": \"{}\", \
         \"reads\": {}, \"bases\": {}, \"rows\": [{}], \
         \"shard_rows\": [{}], \"autoscale\": {}, \
         \"autoscale_rows\": [{}], \"slo\": {}, \
         \"slo_rows\": [{}], \"tier\": {}, \"tier_rows\": [{}], \
         \"serve\": {}, \"serve_rows\": [{}], \
         \"pipeline\": {}, \"pipeline_rows\": [{}]}}\n",
        kind.name(), run.reads.len(), total_bases, rows.join(", "),
        shard_rows.join(", "), autoscale_summary,
        autoscale_rows.join(", "), slo_summary, slo_rows.join(", "),
        tier_summary, tier_rows.join(", "),
        serve_summary, serve_rows.join(", "),
        pipeline_summary, pipeline_rows.join(", "));
    match std::fs::write("BENCH_coordinator.json", &json) {
        Ok(()) => println!("\nwrote BENCH_coordinator.json"),
        Err(e) => println!("\ncould not write BENCH_coordinator.json: {e}"),
    }
}
