//! Hot-path micro-benchmarks: CTC decode, voting, edit distance, signal
//! simulation. (In-tree timer replaces criterion — offline build.)
//!
//!     cargo bench --bench basecall_hot

use helix::basecall::ctc::{beam_search, greedy_decode, LogProbs};
use helix::basecall::edit::{edit_distance, edit_distance_banded};
use helix::basecall::vote::consensus;
use helix::bench::timer::bench;
use helix::genome::pore::PoreModel;
use helix::util::rng::Rng;

/// Guppy-shaped logprobs: T=145, peaked like a trained model's output.
fn realistic_lp(t: usize, seed: u64) -> LogProbs {
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(t * 5);
    for _ in 0..t {
        let hot = rng.below(5);
        let mut row = [0.02f32; 5];
        row[hot] = 0.92;
        let sum: f32 = row.iter().sum();
        data.extend(row.iter().map(|p| (p / sum).ln()));
    }
    LogProbs::new(t, data)
}

fn main() {
    println!("== basecall hot paths ==");
    let lp = realistic_lp(145, 1);

    bench("greedy_decode T=145", 200, || {
        std::hint::black_box(greedy_decode(&lp));
    });
    for width in [2usize, 10, 32, 64] {
        bench(&format!("beam_search T=145 width={width}"), 300, || {
            std::hint::black_box(beam_search(&lp, width));
        });
    }

    let mut rng = Rng::new(2);
    let a: Vec<u8> = (0..30).map(|_| rng.base()).collect();
    let mut b = a.clone();
    b[10] = (b[10] + 1) % 4;
    b.insert(20, 2);
    bench("edit_distance 30x31", 100, || {
        std::hint::black_box(edit_distance(&a, &b));
    });
    bench("edit_distance_banded 30x31 band=4", 100, || {
        std::hint::black_box(edit_distance_banded(&a, &b, 4));
    });
    let long_a: Vec<u8> = (0..300).map(|_| rng.base()).collect();
    let mut long_b = long_a.clone();
    for _ in 0..20 {
        let i = rng.below(long_b.len());
        long_b[i] = (long_b[i] + 1) % 4;
    }
    bench("edit_distance 300x300", 150, || {
        std::hint::black_box(edit_distance(&long_a, &long_b));
    });
    bench("edit_distance_banded 300x300 band=40", 150, || {
        std::hint::black_box(edit_distance_banded(&long_a, &long_b, 40));
    });

    let truth: Vec<u8> = (0..30).map(|_| rng.base()).collect();
    let mut n1 = truth.clone();
    n1[5] = (n1[5] + 1) % 4;
    let mut n2 = truth.clone();
    n2[20] = (n2[20] + 2) % 4;
    bench("consensus 3x30-base reads", 150, || {
        std::hint::black_box(consensus(&truth, &[&n1, &n2]));
    });

    let pm = PoreModel::synthetic(7);
    let seq: Vec<u8> = (0..400).map(|_| rng.base()).collect();
    let mut sim_rng = Rng::new(3);
    bench("pore simulate 400-base read", 150, || {
        std::hint::black_box(pm.simulate(&seq, &mut sim_rng));
    });
}
