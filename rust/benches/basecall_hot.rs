//! Hot-path micro-benchmarks: quantized DNN forward (SWAR vs scalar
//! reference), CTC decode (pruned vs exhaustive beam), voting, edit
//! distance, signal simulation. (In-tree timer replaces criterion —
//! offline build.)
//!
//!     cargo bench --bench basecall_hot
//!
//! Emits a structured `kernel_rows` section into BENCH_kernels.json
//! (windows/s for the native forward at each bit-width, decodes/s at
//! each beam width) and hard-gates it against the checked-in baseline
//! band in benches/baseline_kernels.json: a metric below
//! `metric * (1 - tolerance)` or a SWAR/pruning speedup below the
//! row's `min_speedup` floor exits non-zero, which fails `./ci.sh
//! bench`. Re-baseline on a new machine with
//! `HELIX_BENCH_UPDATE_BASELINE=1` (keeps the bands, rewrites the
//! absolute metrics). Field-to-figure mapping: docs/TUNING.md.

use helix::basecall::ctc::{beam_search, beam_search_pruned, greedy_decode,
                           BeamPrune, LogProbs};
use helix::basecall::edit::{edit_distance, edit_distance_banded};
use helix::basecall::vote::consensus;
use helix::bench::timer::bench;
use helix::genome::pore::PoreModel;
use helix::runtime::{Backend, NativeBackend};
use helix::util::json::Json;
use helix::util::rng::Rng;

/// Guppy-shaped logprobs: T=145, peaked like a trained model's output.
fn realistic_lp(t: usize, seed: u64) -> LogProbs {
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(t * 5);
    for _ in 0..t {
        let hot = rng.below(5);
        let mut row = [0.02f32; 5];
        row[hot] = 0.92;
        let sum: f32 = row.iter().sum();
        data.extend(row.iter().map(|p| (p / sum).ln()));
    }
    LogProbs::new(t, data)
}

/// One gated kernel measurement: the JSON row plus what the baseline
/// band checks (`metric` = the row's primary throughput; `speedup` =
/// vectorized-over-reference ratio on the same inputs).
struct KernelRow {
    key: String,
    metric: f64,
    speedup: f64,
    json: String,
}

/// Candidate baseline locations: cargo runs benches with cwd = the
/// crate root (rust/), but keep the repo-root-relative spelling too so
/// a direct `./rust/target/release/...` invocation from the repo root
/// still finds it.
const BASELINE_PATHS: &[&str] = &["benches/baseline_kernels.json",
                                  "rust/benches/baseline_kernels.json"];

fn find_baseline() -> Option<(String, String)> {
    for p in BASELINE_PATHS {
        if let Ok(text) = std::fs::read_to_string(p) {
            return Some((p.to_string(), text));
        }
    }
    None
}

/// Gate the measured rows against the baseline band; returns human
/// readable failure descriptions (empty = pass).
fn gate(rows: &[KernelRow], baseline: &Json, tolerance: f64)
        -> Vec<String> {
    let mut failures = Vec::new();
    let Some(brows) = baseline.get("rows").and_then(|r| r.as_arr()) else {
        return vec!["baseline has no \"rows\" array".into()];
    };
    for b in brows {
        let Some(key) = b.get("key").and_then(|k| k.as_str()) else {
            failures.push("baseline row without \"key\"".into());
            continue;
        };
        let Some(row) = rows.iter().find(|r| r.key == key) else {
            failures.push(format!(
                "baseline row '{key}' was not measured this run"));
            continue;
        };
        if let Some(metric) = b.get("metric").and_then(|m| m.as_f64()) {
            let floor = metric * (1.0 - tolerance);
            if row.metric < floor {
                failures.push(format!(
                    "{key}: {:.0}/s is below the baseline band \
                     ({:.0}/s * (1 - {tolerance}) = {floor:.0}/s)",
                    row.metric, metric));
            }
        }
        if let Some(ms) = b.get("min_speedup").and_then(|m| m.as_f64()) {
            if row.speedup < ms {
                failures.push(format!(
                    "{key}: speedup {:.2}x is below the floor {ms:.2}x",
                    row.speedup));
            }
        }
    }
    failures
}

/// Rewrite the baseline's absolute metrics from this run, keeping the
/// tolerance and per-row `min_speedup` bands (1.0 for new keys).
fn update_baseline(rows: &[KernelRow], old: Option<&Json>, path: &str) {
    let tolerance = old
        .and_then(|b| b.get("tolerance"))
        .and_then(|t| t.as_f64())
        .unwrap_or(0.75);
    let mut out = Vec::new();
    for r in rows {
        let min_speedup = old
            .and_then(|b| b.get("rows"))
            .and_then(|rs| rs.as_arr())
            .and_then(|rs| rs.iter().find(|b| {
                b.get("key").and_then(|k| k.as_str())
                    == Some(r.key.as_str())
            }))
            .and_then(|b| b.get("min_speedup"))
            .and_then(|m| m.as_f64())
            .unwrap_or(1.0);
        out.push(format!(
            "    {{\"key\": \"{}\", \"metric\": {:.0}, \
             \"min_speedup\": {min_speedup}}}",
            r.key, r.metric));
    }
    let json = format!(
        "{{\n  \"tolerance\": {tolerance},\n  \"rows\": [\n{}\n  ]\n}}\n",
        out.join(",\n"));
    match std::fs::write(path, &json) {
        Ok(()) => println!("rebaselined {path}"),
        Err(e) => {
            eprintln!("could not write {path}: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let mut kernel_rows: Vec<KernelRow> = Vec::new();

    // SWAR forward throughput vs the retained scalar reference, per
    // exported bit-width, on the builtin native model (batch = 32, the
    // largest exported batch). Same random signals for both paths, and
    // the outputs are asserted bit-identical before timing anything —
    // a wrong kernel must fail loudly, not get benchmarked.
    println!("== native quantized forward (SWAR vs scalar) ==");
    let mut backend = NativeBackend::builtin();
    let window = backend.meta().window;
    let batch = 32usize;
    let mut rng = Rng::new(7);
    let sigs: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..window).map(|_| rng.normal() as f32 * 0.8).collect())
        .collect();
    for bits in [32u32, 16, 8, 5] {
        let vectorized = backend.run_windows("guppy", bits, &sigs).unwrap();
        let reference = backend.run_reference("guppy", bits, &sigs).unwrap();
        assert_eq!(vectorized.len(), reference.len());
        for (v, r) in vectorized.iter().zip(reference.iter()) {
            assert_eq!(v.t, r.t);
            for (a, b) in v.data.iter().zip(r.data.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "SWAR forward diverged from scalar at {bits}b");
            }
        }
        let st_v = bench(&format!("forward {bits:>2}b batch=32 swar"),
                         300, || {
            std::hint::black_box(
                backend.run_windows("guppy", bits, &sigs).unwrap());
        });
        let st_s = bench(&format!("forward {bits:>2}b batch=32 scalar"),
                         300, || {
            std::hint::black_box(
                backend.run_reference("guppy", bits, &sigs).unwrap());
        });
        let win_per_s = batch as f64 / (st_v.median_ns / 1e9);
        let scalar_win_per_s = batch as f64 / (st_s.median_ns / 1e9);
        let speedup = win_per_s / scalar_win_per_s;
        println!("    -> {win_per_s:.0} windows/s \
                  (scalar {scalar_win_per_s:.0}, {speedup:.2}x)");
        kernel_rows.push(KernelRow {
            key: format!("forward/{bits}"),
            metric: win_per_s,
            speedup,
            json: format!(
                "{{\"kind\": \"forward\", \"key\": \"forward/{bits}\", \
                 \"bits\": {bits}, \"win_per_s\": {win_per_s:.0}, \
                 \"scalar_win_per_s\": {scalar_win_per_s:.0}, \
                 \"speedup\": {speedup:.3}}}"),
        });
    }

    // decode throughput per beam width: pruned (default thresholds)
    // vs the exhaustive search on model-realistic peaked rows.
    println!("\n== basecall hot paths ==");
    let lp = realistic_lp(145, 1);
    let prune = BeamPrune::defaults();

    bench("greedy_decode T=145", 200, || {
        std::hint::black_box(greedy_decode(&lp));
    });
    for width in [2usize, 10, 32, 64] {
        let st_full = bench(
            &format!("beam_search T=145 width={width}"), 300, || {
                std::hint::black_box(beam_search(&lp, width));
            });
        let st_pruned = bench(
            &format!("beam_search T=145 width={width} pruned"), 300, || {
                std::hint::black_box(beam_search_pruned(&lp, width, prune));
            });
        let dec_per_s = 1e9 / st_pruned.median_ns;
        let full_dec_per_s = 1e9 / st_full.median_ns;
        let speedup = dec_per_s / full_dec_per_s;
        println!("    -> width {width}: pruned {dec_per_s:.0} dec/s \
                  (full {full_dec_per_s:.0}, {speedup:.2}x)");
        kernel_rows.push(KernelRow {
            key: format!("decode/{width}"),
            metric: dec_per_s,
            speedup,
            json: format!(
                "{{\"kind\": \"decode\", \"key\": \"decode/{width}\", \
                 \"beam_width\": {width}, \"dec_per_s\": {dec_per_s:.0}, \
                 \"full_dec_per_s\": {full_dec_per_s:.0}, \
                 \"speedup\": {speedup:.3}, \
                 \"prune_delta\": {}, \"prune_floor\": {}}}",
                prune.symbol_delta, prune.score_floor),
        });
    }

    let mut rng = Rng::new(2);
    let a: Vec<u8> = (0..30).map(|_| rng.base()).collect();
    let mut b = a.clone();
    b[10] = (b[10] + 1) % 4;
    b.insert(20, 2);
    bench("edit_distance 30x31", 100, || {
        std::hint::black_box(edit_distance(&a, &b));
    });
    bench("edit_distance_banded 30x31 band=4", 100, || {
        std::hint::black_box(edit_distance_banded(&a, &b, 4));
    });
    let long_a: Vec<u8> = (0..300).map(|_| rng.base()).collect();
    let mut long_b = long_a.clone();
    for _ in 0..20 {
        let i = rng.below(long_b.len());
        long_b[i] = (long_b[i] + 1) % 4;
    }
    bench("edit_distance 300x300", 150, || {
        std::hint::black_box(edit_distance(&long_a, &long_b));
    });
    bench("edit_distance_banded 300x300 band=40", 150, || {
        std::hint::black_box(edit_distance_banded(&long_a, &long_b, 40));
    });

    let truth: Vec<u8> = (0..30).map(|_| rng.base()).collect();
    let mut n1 = truth.clone();
    n1[5] = (n1[5] + 1) % 4;
    let mut n2 = truth.clone();
    n2[20] = (n2[20] + 2) % 4;
    bench("consensus 3x30-base reads", 150, || {
        std::hint::black_box(consensus(&truth, &[&n1, &n2]));
    });

    let pm = PoreModel::synthetic(7);
    let seq: Vec<u8> = (0..400).map(|_| rng.base()).collect();
    let mut sim_rng = Rng::new(3);
    bench("pore simulate 400-base read", 150, || {
        std::hint::black_box(pm.simulate(&seq, &mut sim_rng));
    });

    // emit BENCH_kernels.json before gating so a failing run still
    // leaves the measurements on disk for diagnosis.
    let found = find_baseline();
    let json = format!(
        "{{\n  \"backend\": \"native\",\n  \"batch\": {batch},\n  \
         \"kernel_rows\": [\n    {}\n  ],\n  \"gate\": {{\"baseline\": \
         {}, \"updated\": {}}}\n}}\n",
        kernel_rows.iter().map(|r| r.json.clone())
            .collect::<Vec<_>>().join(",\n    "),
        match &found {
            Some((p, _)) => format!("\"{p}\""),
            None => "null".into(),
        },
        std::env::var("HELIX_BENCH_UPDATE_BASELINE").as_deref() == Ok("1"));
    match std::fs::write("BENCH_kernels.json", &json) {
        Ok(()) => println!("\nwrote BENCH_kernels.json"),
        Err(e) => println!("\ncould not write BENCH_kernels.json: {e}"),
    }

    let baseline = found.as_ref().map(|(p, text)| {
        (p.clone(), Json::parse(text).unwrap_or_else(|e| {
            eprintln!("unparsable baseline {p}: {e}");
            std::process::exit(1);
        }))
    });

    if std::env::var("HELIX_BENCH_UPDATE_BASELINE").as_deref() == Ok("1") {
        let path = baseline.as_ref()
            .map(|(p, _)| p.clone())
            .unwrap_or_else(|| BASELINE_PATHS[0].to_string());
        update_baseline(&kernel_rows, baseline.as_ref().map(|(_, b)| b),
                        &path);
        return;
    }

    let Some((path, base)) = baseline else {
        eprintln!("no kernel baseline found (looked at {BASELINE_PATHS:?}); \
                   the perf gate requires one — run with \
                   HELIX_BENCH_UPDATE_BASELINE=1 to create it");
        std::process::exit(1);
    };
    let tolerance = base.get("tolerance")
        .and_then(|t| t.as_f64())
        .unwrap_or(0.75);
    let failures = gate(&kernel_rows, &base, tolerance);
    if failures.is_empty() {
        println!("kernel perf gate: {} rows within the {path} band \
                  (tolerance {tolerance})", kernel_rows.len());
    } else {
        eprintln!("kernel perf gate FAILED against {path}:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        eprintln!("(rebaseline with HELIX_BENCH_UPDATE_BASELINE=1 if this \
                   machine is legitimately slower)");
        std::process::exit(1);
    }
}
