//! Figure/table regeneration bench: times each paper panel's regeneration
//! and prints the panels themselves — one bench per table AND figure, as
//! DESIGN.md's experiment index requires.
//!
//!     cargo bench --bench pim_figures

use helix::bench::figures;
use helix::bench::timer::bench;
use helix::pim::mapper::Topology;
use helix::pim::schemes::{evaluate, Scheme};
use helix::pim::variation;
use helix::runtime::meta::default_artifacts_dir;

fn main() {
    let dir = default_artifacts_dir();

    println!("== per-panel regeneration timing ==");
    bench("scheme evaluation (8 schemes x 3 models)", 150, || {
        for topo in Topology::all() {
            for s in Scheme::all() {
                std::hint::black_box(evaluate(s, &topo, 10));
            }
        }
    });
    bench("device MC 10k samples (fig15 unit)", 300, || {
        std::hint::black_box(variation::duration_mc(
            60.0, variation::ADC_WRITE_VOLTAGE, 10_000, 7));
    });

    // regenerate every panel (the figure output itself is the artifact;
    // CSV-derived panels are skipped gracefully when artifacts are absent)
    for f in ["fig2", "fig3", "fig7", "fig8", "fig9", "fig10", "fig13",
              "fig14", "fig15", "fig16", "fig21", "fig22", "fig23",
              "fig24", "fig25", "fig26", "table1", "table2", "table3",
              "table4", "table5"] {
        let t0 = std::time::Instant::now();
        match figures::run(f, &dir) {
            Ok(()) => println!("[{f}] regenerated in {:.2?}", t0.elapsed()),
            Err(e) => println!("[{f}] unavailable: {e}"),
        }
    }
}
