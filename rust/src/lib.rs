//! # helix
//!
//! Reproduction of *"Helix: Algorithm/Architecture Co-design for Accelerating
//! Nanopore Genome Base-calling"* (Lou, Janga, Jiang — PACT 2020).
//!
//! Layer-3 of the three-layer stack: the rust coordinator owns the event
//! loop, batching, CTC decoding, read voting, the downstream assembly
//! pipeline, and the cycle-level PIM simulator that reproduces the paper's
//! architecture evaluation. The DNN forward pass runs behind the
//! `runtime::Backend` trait: by default the pure-Rust quantized native
//! executor (self-contained, deterministic), or — with the `xla` cargo
//! feature — an AOT-compiled XLA artifact (JAX/Pallas, built once by
//! `make artifacts`) executed through PJRT. Python is never on the
//! request path.
//!
//! A paper-section-to-module map lives in the repo-root
//! `ARCHITECTURE.md`; the serving pipeline's stage/queue diagram is in
//! `src/coordinator/README.md`.
//!
//! ## Quickstart (native backend, zero artifacts)
//!
//! The default backend needs nothing on disk — pointing the coordinator
//! at a directory with no `meta.json` selects the builtin deterministic
//! quantized model, so this example runs on a bare checkout:
//!
//! ```
//! use helix::coordinator::{Coordinator, CoordinatorConfig};
//! use helix::genome::pore::PoreModel;
//! use helix::genome::synth::{RunSpec, SequencingRun};
//!
//! # fn main() -> anyhow::Result<()> {
//! // simulate a tiny sequencing run
//! let pm = PoreModel::synthetic(7);
//! let run = SequencingRun::simulate(&pm, RunSpec {
//!     genome_len: 400,
//!     coverage: 1,
//!     ..Default::default()
//! });
//!
//! let mut coord = Coordinator::new(CoordinatorConfig {
//!     dnn_shards: 2,       // replicate the DNN executor across 2 shards
//!     artifacts_dir: "does-not-exist".into(), // builtin in-memory model
//!     ..Default::default()
//! })?;
//! for read in &run.reads {
//!     coord.submit(read);
//! }
//! let called = coord.finish()?;
//! assert_eq!(called.len(), run.reads.len());
//! assert!(called.iter().all(|c| !c.seq.is_empty()));
//! # Ok(())
//! # }
//! ```
//!
//! Reads also stream out *mid-run* — `Coordinator::try_recv` /
//! `recv_timeout` return each `CalledRead` the moment its last window
//! decodes; `finish()` is only the end-of-run drain.
//!
//! The DNN executor pool can also size *itself*: setting
//! `CoordinatorConfig::autoscale` (see `coordinator::autoscale`) runs
//! a sample→decide→scale control loop that grows the pool under
//! saturation and retires idle replicas, without ever changing called
//! output — byte-identical to a fixed-shard run over the same input.
//!
//! Setting `CoordinatorConfig::escalate_margin` additionally turns on
//! **tiered serving**: every window runs a speculative low-bit fast
//! tier first, and windows whose CTC confidence margin falls below the
//! threshold are re-queued to a full-precision hq tier (see
//! `runtime::TierSet` and the escalation contract in
//! `src/coordinator/README.md`).
#![warn(missing_docs)]

pub mod util;
pub mod runtime;
pub mod basecall;
pub mod genome;
pub mod coordinator;
pub mod pim;
pub mod pipeline;
pub mod bench;
