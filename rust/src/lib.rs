//! # helix
//!
//! Reproduction of *"Helix: Algorithm/Architecture Co-design for Accelerating
//! Nanopore Genome Base-calling"* (Lou, Janga, Jiang — PACT 2020).
//!
//! Layer-3 of the three-layer stack: the rust coordinator owns the event
//! loop, batching, CTC decoding, read voting, the downstream assembly
//! pipeline, and the cycle-level PIM simulator that reproduces the paper's
//! architecture evaluation. The DNN forward pass runs behind the
//! `runtime::Backend` trait: by default the pure-Rust quantized native
//! executor (self-contained, deterministic), or — with the `xla` cargo
//! feature — an AOT-compiled XLA artifact (JAX/Pallas, built once by
//! `make artifacts`) executed through PJRT. Python is never on the
//! request path.
pub mod util;
pub mod runtime;
pub mod basecall;
pub mod genome;
pub mod coordinator;
pub mod pim;
pub mod pipeline;
pub mod bench;
