//! Cycle-level PIM simulator: the paper's architecture contribution.
//!
//! Reproduces the evaluation methodology of §5.1 (a dot-product-engine
//! simulator in the spirit of [40]/NVSim/Spectre-MC): SOT-MRAM device
//! physics (`device`), process-variation Monte-Carlo (`variation`), CMOS
//! and SOT-MRAM ADC models (`adc`), the bit-sliced crossbar dot-product
//! engine (`crossbar`), ISAAC tile/chip configs (`isaac`), the DNN-to-array
//! mapper over the full-size Table 3 topologies (`mapper`), the crossbar
//! CTC engine (`ctc_engine`), SOT-MRAM binary comparator arrays
//! (`comparator`), the Table 2 power/area model (`power`), and the eight
//! evaluation schemes of §5.3 (`schemes`).

pub mod adc;
pub mod comparator;
pub mod crossbar;
pub mod ctc_engine;
pub mod device;
pub mod isaac;
pub mod mapper;
pub mod power;
pub mod schemes;
pub mod variation;
