//! SOT-MRAM binary comparator arrays for read voting (§4.3, Figs 19/20).
//!
//! Each DNA symbol is encoded in 3 bits; each bit occupies a 2-cell pair in
//! a row (0 = LRS,HRS; 1 = HRS,LRS). The query symbol drives the two RBLs of
//! each pair with complementary voltages, so a matching pair draws no source
//! line current and any mismatch does — an analog XNOR across the whole row
//! in one cycle. Sub-strings of one read live in rows; the query read is
//! streamed on the bit-lines; the first row with zero SL current is the
//! longest match.

use crate::util::rng::Rng;

/// 3-bit encoding of Fig 19(c): A=001, C=010, T=000, G=100, -=101.
pub fn encode(sym: u8) -> [u8; 3] {
    match sym {
        0 => [0, 0, 1], // A
        1 => [0, 1, 0], // C
        2 => [1, 0, 0], // G
        3 => [0, 0, 0], // T
        _ => [1, 0, 1], // blank
    }
}

/// A `rows x cols` comparator array (cols counted in CELLS; a symbol takes
/// 6 cells = 3 bit-pairs).
#[derive(Clone, Debug)]
pub struct ComparatorArray {
    /// array rows (one candidate sequence per row).
    pub rows: usize,
    /// array columns, counted in cells.
    pub cols: usize,
    /// per-cell read upset probability (from `variation::cell_error_rate`).
    pub cell_error: f64,
    /// comparison frequency in MHz.
    pub freq_mhz: f64,
}

impl ComparatorArray {
    /// The paper's design point: 256x256, 1e-11 cell error (§4.3).
    pub fn paper() -> Self {
        ComparatorArray { rows: 256, cols: 256, cell_error: 1e-11,
                          freq_mhz: 640.0 }
    }

    /// Max symbols per row (2 cells per bit, 3 bits per symbol).
    pub fn symbols_per_row(&self) -> usize {
        self.cols / 6
    }

    /// Compare a stored row against a query of equal length: true iff every
    /// symbol matches (zero SL current). Functional model of Fig 20.
    pub fn row_matches(&self, stored: &[u8], query: &[u8]) -> bool {
        if stored.len() != query.len() {
            return false;
        }
        for (s, q) in stored.iter().zip(query) {
            let es = encode(*s);
            let eq = encode(*q);
            for b in 0..3 {
                // cell pair (es) vs complementary voltages (eq): current
                // flows iff bits differ
                if es[b] != eq[b] {
                    return false;
                }
            }
        }
        true
    }

    /// Same with per-cell upsets injected (reliability study §4.3).
    pub fn row_matches_noisy(&self, stored: &[u8], query: &[u8],
                             rng: &mut Rng) -> bool {
        let clean = self.row_matches(stored, query);
        // a row compares 6*len cells; any upset flips the verdict
        let p_row_err = 1.0
            - (1.0 - self.cell_error).powi(6 * stored.len() as i32);
        if rng.f64() < p_row_err {
            !clean
        } else {
            clean
        }
    }

    /// Longest suffix(a)/prefix(b) match via the array: suffixes of `a` are
    /// written into rows (longest first), `b`'s prefix drives the RBLs; the
    /// first matching row wins. Returns the match length (exact matching —
    /// the hardware compares binary vectors).
    pub fn longest_match(&self, a: &[u8], b: &[u8]) -> usize {
        let max = a.len().min(b.len()).min(self.symbols_per_row());
        for len in (1..=max).rev() {
            if self.row_matches(&a[a.len() - len..], &b[..len]) {
                return len;
            }
        }
        0
    }

    /// Cycle cost of one voting group: write all sub-strings of the
    /// scaffold (one row-write per sub-string), then stream `n_reads`
    /// queries (one compare cycle each; the array compares up to `rows`
    /// stored sub-strings against a query concurrently — "Helix can
    /// concurrently compare up to 256 reads" §6.3).
    pub fn cycles_per_vote(&self, scaffold_len: usize, n_reads: usize)
                           -> f64 {
        let writes = scaffold_len.min(self.rows) as f64;
        let compares = n_reads as f64;
        writes + compares
    }
}

/// Expected comparator mistakes when comparing `n` reads of `len` bases
/// (the paper: 1 mistake per 556 million 30-base reads at 1e-11/cell).
pub fn expected_errors(n_reads: f64, len: usize, cell_error: f64) -> f64 {
    n_reads * 6.0 * len as f64 * cell_error
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn encoding_is_injective() {
        let codes: Vec<[u8; 3]> = (0..5).map(encode).collect();
        for i in 0..5 {
            for j in (i + 1)..5 {
                assert_ne!(codes[i], codes[j], "{i} vs {j}");
            }
        }
    }

    #[test]
    fn row_match_iff_equal() {
        let arr = ComparatorArray::paper();
        prop::check("cmp row match", 50, |rng, _| {
            let a = prop::dna(rng, 1, 30);
            let mut b = a.clone();
            assert!(arr.row_matches(&a, &b));
            let i = rng.below(b.len());
            b[i] = (b[i] + 1 + (rng.below(3) as u8)) % 4;
            assert!(!arr.row_matches(&a, &b));
        });
    }

    #[test]
    fn longest_match_agrees_with_naive() {
        let arr = ComparatorArray::paper();
        prop::check("cmp longest match", 40, |rng, _| {
            let a = prop::dna(rng, 1, 25);
            let b = prop::dna(rng, 1, 25);
            let naive = (1..=a.len().min(b.len())).rev()
                .find(|&l| a[a.len() - l..] == b[..l])
                .unwrap_or(0);
            assert_eq!(arr.longest_match(&a, &b), naive);
        });
    }

    #[test]
    fn fig19_example() {
        // R1="ACTA", R2="CTAG": longest suffix-prefix match is "CTA" (3)
        let arr = ComparatorArray::paper();
        let r1 = [0u8, 1, 3, 0];
        let r2 = [1u8, 3, 0, 2];
        assert_eq!(arr.longest_match(&r1, &r2), 3);
    }

    #[test]
    fn paper_error_rate_reproduced() {
        // "After comparing 556 million 30-base reads, on average, our binary
        // comparator array makes 1 mistake" at 1e-11 per cell
        let e = expected_errors(556e6, 30, 1e-11);
        assert!((e - 1.0).abs() < 0.05, "{e}");
    }

    #[test]
    fn noisy_match_rarely_differs_at_design_error() {
        let arr = ComparatorArray::paper();
        let mut rng = Rng::new(3);
        let a: Vec<u8> = (0..30).map(|i| (i % 4) as u8).collect();
        let mut diffs = 0;
        for _ in 0..10_000 {
            if arr.row_matches_noisy(&a, &a, &mut rng)
                != arr.row_matches(&a, &a)
            {
                diffs += 1;
            }
        }
        assert_eq!(diffs, 0);
    }

    #[test]
    fn vote_cycles_scale() {
        let arr = ComparatorArray::paper();
        assert!(arr.cycles_per_vote(30, 50) > arr.cycles_per_vote(30, 3));
        assert!(arr.cycles_per_vote(30, 3) >= 33.0);
    }
}
