//! CTC decoding on the NVM dot-product engine (§4.3, Fig 18).
//!
//! One beam step: the top-W base probabilities of time step t are written on
//! the crossbar diagonal; the top-W probabilities of step t+1 drive the
//! word-lines, so all W x S candidate products appear on the bit-lines in
//! one analog pass. The added per-BL pass transistors (S0..S2 in Fig 18)
//! merge bit-lines whose sequences collapse to the same read — the analog
//! equivalent of the prefix-merge in `basecall::ctc::beam_search`.
//!
//! The functional model below is validated against the software beam step;
//! the timing model feeds `schemes`.

use crate::basecall::ctc::LogProbs;

/// One crossbar beam step in the probability domain.
///
/// `prev`: (probability, index) of the surviving prefixes at step t.
/// `cur`:  per-symbol probabilities at step t+1.
/// `merge_groups`: bit-line groups joined by pass transistors (each group's
/// products are summed — Fig 18's p(A) = p(A0A1)+p(A0-1)+p(-0A1)+p(-0-1)).
///
/// Returns the merged probabilities per group.
pub fn crossbar_beam_step(prev: &[f64], cur: &[f64],
                          merge_groups: &[Vec<(usize, usize)>]) -> Vec<f64> {
    // diagonal write: product matrix entries prev[i] * cur[j] materialize as
    // bit-line currents; pass transistors sum groups of bit-lines.
    merge_groups.iter()
        .map(|group| group.iter()
            .map(|&(i, j)| prev[i] * cur[j])
            .sum())
        .collect()
}

/// Cycle cost of decoding one window with beam width `w` on the engine:
/// per time step, one diagonal write pass + one dot-product pass (the write
/// is what the added transistor does NOT slow down — §4.3 "the dot-product
/// array operates at only 10 MHz").
pub fn cycles_per_window(ctc_steps: usize, beam_width: usize,
                         array_cols: usize) -> f64 {
    // each step needs ceil(w*5 / cols) array passes when the beam outgrows
    // one array's bit-lines
    let passes = ((beam_width * 5) as f64 / array_cols as f64).ceil();
    ctc_steps as f64 * (1.0 + passes)
}

/// Engine cell-ops consumed per window (shares the DNN engines, so this is
/// the unit `schemes` accounts in).
pub fn cell_ops_per_window(ctc_steps: usize, beam_width: usize,
                           array_rows: usize, array_cols: usize) -> f64 {
    cycles_per_window(ctc_steps, beam_width, array_cols)
        * (array_rows * array_cols) as f64
}

/// Full-window beam search where every step's candidate scoring runs through
/// `crossbar_beam_step` — functional check that the hardware mapping decodes
/// identically to software greedy/beam logic for width-limited search.
pub fn decode_on_crossbar(lp: &LogProbs, beam_width: usize) -> Vec<u8> {
    use std::collections::HashMap;
    // prefix -> probability (linear domain, as the analog arrays work)
    let mut beams: HashMap<Vec<u8>, (f64, f64)> = HashMap::new(); // (pb, pnb)
    beams.insert(Vec::new(), (1.0, 0.0));
    for t in 0..lp.t {
        let row = lp.row(t);
        let cur: Vec<f64> = (0..5).map(|s| (row[s] as f64).exp()).collect();
        let mut next: HashMap<Vec<u8>, (f64, f64)> = HashMap::new();
        // build the product+merge for all prefixes at once: the crossbar
        // computes prev x cur outer products; merge groups implement the
        // blank/repeat collapse rules.
        for (prefix, &(pb, pnb)) in beams.iter() {
            let total = pb + pnb;
            let prev = [total, pb, pnb];
            for s in 0..5usize {
                if s == 4 {
                    let grp = vec![(0usize, 4usize)];
                    let m = crossbar_beam_step(&prev, &cur, &[grp]);
                    let e = next.entry(prefix.clone()).or_insert((0.0, 0.0));
                    e.0 += m[0];
                } else if prefix.last() == Some(&(s as u8)) {
                    // repeat: collapse (from pnb) + extend (from pb)
                    let m = crossbar_beam_step(
                        &prev, &cur, &[vec![(2, s)], vec![(1, s)]]);
                    let e = next.entry(prefix.clone()).or_insert((0.0, 0.0));
                    e.1 += m[0];
                    let mut ext = prefix.clone();
                    ext.push(s as u8);
                    let e = next.entry(ext).or_insert((0.0, 0.0));
                    e.1 += m[1];
                } else {
                    let m = crossbar_beam_step(&prev, &cur, &[vec![(0, s)]]);
                    let mut ext = prefix.clone();
                    ext.push(s as u8);
                    let e = next.entry(ext).or_insert((0.0, 0.0));
                    e.1 += m[0];
                }
            }
        }
        let mut scored: Vec<(Vec<u8>, (f64, f64))> = next.into_iter().collect();
        scored.sort_by(|a, b| (b.1 .0 + b.1 .1)
            .total_cmp(&(a.1 .0 + a.1 .1)));
        scored.truncate(beam_width);
        beams = scored.into_iter().collect();
    }
    beams.into_iter()
        .max_by(|a, b| (a.1 .0 + a.1 .1).total_cmp(&(b.1 .0 + b.1 .1)))
        .map(|(p, _)| p)
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basecall::ctc::{beam_search, LogProbs};
    use crate::util::rng::Rng;

    fn random_lp(t: usize, seed: u64) -> LogProbs {
        let mut rng = Rng::new(seed);
        let mut data = Vec::new();
        for _ in 0..t {
            let raw: Vec<f64> = (0..5).map(|_| rng.f64() + 0.05).collect();
            let s: f64 = raw.iter().sum();
            data.extend(raw.iter().map(|p| ((p / s).ln()) as f32));
        }
        LogProbs::new(t, data)
    }

    #[test]
    fn fig18_merge_example() {
        // p(A) = p(A0 A1) + p(A0 -1) + p(-0 A1) + p(-0 -1)
        let prev = [0.3, 0.5]; // p(A0), p(-0)
        let cur = [0.3, 0.4];  // p(A1), p(-1)
        let groups = vec![vec![(0, 0), (0, 1), (1, 0), (1, 1)]];
        let m = crossbar_beam_step(&prev, &cur, &groups);
        let want = 0.3 * 0.3 + 0.3 * 0.4 + 0.5 * 0.3 + 0.5 * 0.4;
        assert!((m[0] - want).abs() < 1e-12);
    }

    #[test]
    fn crossbar_decode_matches_software_beam() {
        for seed in 0..8u64 {
            let lp = random_lp(10, seed);
            let hw = decode_on_crossbar(&lp, 10);
            let sw = beam_search(&lp, 10);
            assert_eq!(hw, sw, "seed {seed}");
        }
    }

    #[test]
    fn cycles_scale_with_beam_width() {
        let c2 = cycles_per_window(60, 2, 128);
        let c10 = cycles_per_window(60, 10, 128);
        let c30 = cycles_per_window(60, 30, 128);
        assert!(c2 <= c10 && c10 <= c30);
        // beyond 128/5 ~ 25 beams the step needs a second array pass
        assert!(c30 > c10, "{c30} vs {c10}");
    }

    #[test]
    fn cell_ops_positive_and_linear_in_steps() {
        let a = cell_ops_per_window(60, 10, 128, 128);
        let b = cell_ops_per_window(300, 10, 128, 128);
        assert!(a > 0.0);
        assert!((b / a - 5.0).abs() < 1e-9);
    }
}
