//! Chip-level configs: the ISAAC baseline and the Helix variant (Table 2 /
//! Table 5 geometry: 168 tiles x 12 IMAs x 8 arrays = 16128 crossbars, the
//! "core #" of Table 5).

use super::crossbar::ArrayConfig;
use super::power::{self, ChipBudget};

/// A full accelerator configuration (tile/IMA/array geometry + budget).
#[derive(Clone, Debug)]
pub struct Chip {
    /// config name for tables and logs.
    pub name: &'static str,
    /// tile count.
    pub tiles: usize,
    /// in-situ multiply-accumulate units per tile.
    pub imas_per_tile: usize,
    /// crossbar arrays per IMA.
    pub arrays_per_ima: usize,
    /// geometry/precision of each crossbar array.
    pub array: ArrayConfig,
    /// power/area rollup (Table 2).
    pub budget: ChipBudget,
    /// true when the ADC stage is the SOT-MRAM array design.
    pub sot_adc: bool,
    /// true when the comparator block for read voting is present.
    pub comparators: bool,
}

impl Chip {
    /// The ISAAC baseline geometry (Table 2 top: CMOS ADCs).
    pub fn isaac() -> Chip {
        Chip {
            name: "isaac",
            tiles: 168,
            imas_per_tile: 12,
            arrays_per_ima: 8,
            array: ArrayConfig::default(),
            budget: power::isaac_chip(),
            sot_adc: false,
            comparators: false,
        }
    }

    /// Helix without the comparator block (the paper's `ADC`/`CTC` schemes).
    pub fn helix_no_cmp() -> Chip {
        let budget = power::chip(168, 12, power::ima_with_sot_adc(), &[]);
        Chip {
            name: "helix-adc",
            array: ArrayConfig { adc_bits: 5, ..ArrayConfig::default() },
            budget,
            sot_adc: true,
            comparators: false,
            ..Chip::isaac()
        }
    }

    /// Full Helix (Table 2 bottom: + 1024 comparator arrays).
    pub fn helix() -> Chip {
        Chip {
            name: "helix",
            budget: power::helix_chip(),
            comparators: true,
            ..Chip::helix_no_cmp()
        }
    }

    /// Crossbar arrays on the whole chip (the "core #" of Table 5).
    pub fn total_arrays(&self) -> usize {
        self.tiles * self.imas_per_tile * self.arrays_per_ima
    }

    /// Aggregate crossbar cell-ops per second (all arrays busy).
    pub fn cell_ops_per_sec(&self) -> f64 {
        self.total_arrays() as f64
            * (self.array.rows * self.array.cols) as f64
            * self.array.freq_mhz * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isaac_has_16128_cores() {
        // Table 5: core # 16128
        assert_eq!(Chip::isaac().total_arrays(), 16128);
    }

    #[test]
    fn cell_op_rate() {
        let c = Chip::isaac();
        let want = 16128.0 * 128.0 * 128.0 * 10e6;
        assert!((c.cell_ops_per_sec() - want).abs() / want < 1e-12);
    }

    #[test]
    fn helix_has_5bit_adc_and_comparators() {
        let h = Chip::helix();
        assert!(h.sot_adc && h.comparators);
        assert_eq!(h.array.adc_bits, 5);
        assert!(h.budget.power_w < Chip::isaac().budget.power_w);
    }
}
