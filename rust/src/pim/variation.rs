//! Process-variation Monte-Carlo (§4.2 Table 1, Figs 15/16) — the in-tree
//! stand-in for the paper's 10^10-sample Cadence Spectre runs (DESIGN.md
//! §Substitutions): same Eq. 5 model, same Table 1 distributions, fewer
//! samples plus a Gaussian-tail extrapolation for the worst case.

use crate::util::rng::Rng;

use super::device::{DeviceParams, VariationSigmas};

/// One sampled device instance.
pub fn sample_device(nominal: &DeviceParams, sig: &VariationSigmas,
                     rng: &mut Rng) -> DeviceParams {
    DeviceParams {
        w_wt: rng.normal_ms(nominal.w_wt, sig.w_wt * nominal.w_wt).max(1.0),
        l_wt: rng.normal_ms(nominal.l_wt, sig.l_wt * nominal.l_wt).max(1.0),
        v_th: rng.normal_ms(nominal.v_th, sig.v_th * nominal.v_th).max(0.0),
        ra: rng.lognormal_rel(nominal.ra, sig.ra),
        area_nm2: rng.lognormal_rel(nominal.area_nm2, sig.area),
        delta: rng.normal_ms(nominal.delta, sig.delta * nominal.delta)
            .max(1.0),
    }
}

/// Result of a write-duration Monte-Carlo (Fig 15).
#[derive(Clone, Debug)]
pub struct DurationStats {
    /// Monte-Carlo sample count.
    pub samples: usize,
    /// mean write duration in ns.
    pub mean_ns: f64,
    /// standard deviation in ns.
    pub sigma_ns: f64,
    /// 99.9th percentile in ns.
    pub p999_ns: f64,
    /// extrapolated worst case at the paper's 10^10-sample scale
    /// (mean + 6.4 sigma of log-duration, the Spectre-MC equivalent).
    pub worst_ns: f64,
    /// histogram over log-spaced bins, for Fig 15.
    pub histogram: Vec<(f64, usize)>,
}

/// Cell size in F^2 -> write transistor width scaling. The paper iterates
/// transistor size until the worst-case cell switches in 1.56ns and lands on
/// 60F^2 (Fig 16); cell area is dominated by the write transistor, so width
/// scales linearly with (cell_f2 - overhead).
pub fn transistor_width_for_cell(cell_f2: f64) -> f64 {
    // 60F^2 -> the nominal 384nm transistor; 12F^2 of fixed overhead.
    let nominal = DeviceParams::default();
    nominal.w_wt * ((cell_f2 - 12.0) / 48.0).max(0.05)
}

/// Monte-Carlo of write durations at a cell size (Fig 15 for 60F^2).
pub fn duration_mc(cell_f2: f64, v_write: f64, samples: usize, seed: u64)
                   -> DurationStats {
    let mut nominal = DeviceParams::default();
    nominal.w_wt = transistor_width_for_cell(cell_f2);
    let sig = VariationSigmas::default();
    let mut rng = Rng::new(seed);
    let mut logs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let d = sample_device(&nominal, &sig, &mut rng);
        logs.push(d.duration_at_voltage(v_write).ln());
    }
    logs.sort_unstable_by(f64::total_cmp);
    let n = logs.len() as f64;
    let mean_log = logs.iter().sum::<f64>() / n;
    let var_log = logs.iter().map(|x| (x - mean_log) * (x - mean_log))
        .sum::<f64>() / n;
    let sd_log = var_log.sqrt();
    let p999 = logs[((logs.len() - 1) as f64 * 0.999) as usize].exp();
    // Worst case among 10^10 samples of a normal ~ mean + 6.4 sigma.
    let worst = (mean_log + 6.4 * sd_log).exp();

    // histogram in ns over 24 log bins
    let lo = logs[0];
    let hi = logs[logs.len() - 1];
    let bins = 24usize;
    let width = ((hi - lo) / bins as f64).max(1e-12);
    let mut histogram = vec![(0.0, 0usize); bins];
    for (i, h) in histogram.iter_mut().enumerate() {
        h.0 = (lo + width * (i as f64 + 0.5)).exp() * 1e9;
    }
    for &l in &logs {
        let b = (((l - lo) / width) as usize).min(bins - 1);
        histogram[b].1 += 1;
    }
    DurationStats {
        samples,
        mean_ns: mean_log.exp() * 1e9,
        sigma_ns: sd_log * mean_log.exp() * 1e9,
        p999_ns: p999 * 1e9,
        worst_ns: worst * 1e9,
        histogram,
    }
}

/// Fig 16: worst-case write duration vs cell size. The paper selects the
/// smallest size whose worst case is <= 1.56ns (60F^2).
pub fn worst_case_vs_cell_size(sizes_f2: &[f64], v_write: f64,
                               samples: usize, seed: u64)
                               -> Vec<(f64, f64)> {
    sizes_f2.iter()
        .map(|&s| (s, duration_mc(s, v_write, samples, seed).worst_ns))
        .collect()
}

/// Single-cell read error rate of a comparator/ADC array under variation:
/// probability that a cell's duration exceeds the pulse window (wrong
/// digitization) — the quantity behind the paper's 1e-11 figure (§4.3).
pub fn cell_error_rate(cell_f2: f64, v_write: f64, t_pulse_ns: f64,
                       samples: usize, seed: u64) -> f64 {
    let st = duration_mc(cell_f2, v_write, samples, seed);
    // Gaussian tail estimate in log space.
    let z = ((t_pulse_ns / st.mean_ns).ln())
        / ((st.sigma_ns / st.mean_ns).ln_1p().max(1e-12));
    normal_tail(z)
}

/// Upper-tail probability of the standard normal (Abramowitz-Stegun fit).
pub fn normal_tail(z: f64) -> f64 {
    if z < 0.0 {
        return 1.0 - normal_tail(-z);
    }
    let t = 1.0 / (1.0 + 0.2316419 * z);
    let poly = t * (0.319381530
        + t * (-0.356563782
        + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
    let pdf = (-z * z / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt();
    (pdf * poly).clamp(0.0, 1.0)
}

/// The operating write voltage of the ADC/comparator arrays: the Fig 13
/// point — threshold + one 50mV LSB + transistor overdrive margin.
pub const ADC_WRITE_VOLTAGE: f64 = 0.55;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worst_case_decreases_with_cell_size() {
        let curve = worst_case_vs_cell_size(&[20.0, 40.0, 60.0, 80.0],
                                            ADC_WRITE_VOLTAGE, 4000, 1);
        for w in curve.windows(2) {
            assert!(w[1].1 < w[0].1,
                    "worst case not decreasing: {curve:?}");
        }
    }

    #[test]
    fn sixty_f2_meets_the_1_56ns_anchor() {
        // The paper's design point: at 60F^2 the worst-case cell switches
        // within ~1.56ns. Accept a 3x modeling band.
        let st = duration_mc(60.0, ADC_WRITE_VOLTAGE, 20_000, 2);
        assert!(st.worst_ns < 4.7 && st.worst_ns > 0.15,
                "worst {} ns", st.worst_ns);
    }

    #[test]
    fn histogram_covers_all_samples() {
        let st = duration_mc(60.0, ADC_WRITE_VOLTAGE, 5000, 3);
        let total: usize = st.histogram.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 5000);
        assert!(st.mean_ns > 0.0 && st.sigma_ns >= 0.0);
    }

    #[test]
    fn mc_is_deterministic_per_seed() {
        let a = duration_mc(60.0, ADC_WRITE_VOLTAGE, 2000, 7);
        let b = duration_mc(60.0, ADC_WRITE_VOLTAGE, 2000, 7);
        assert_eq!(a.mean_ns, b.mean_ns);
        assert_eq!(a.worst_ns, b.worst_ns);
    }

    #[test]
    fn error_rate_is_tiny_at_design_point() {
        let e = cell_error_rate(60.0, ADC_WRITE_VOLTAGE, 1.56, 10_000, 4);
        assert!(e < 1e-3, "error rate {e}");
    }

    #[test]
    fn normal_tail_sane() {
        assert!((normal_tail(0.0) - 0.5).abs() < 1e-3);
        assert!(normal_tail(6.0) < 1e-8);
        assert!((normal_tail(-6.0) - 1.0).abs() < 1e-8);
    }
}
