//! Table 2 power/area component model (NVSim-style, 32nm) and the Fig 8
//! breakdown of NVM dot-product engines.

use super::adc::{CmosAdc, SotAdcArray};

/// One line of Table 2.
#[derive(Clone, Debug)]
pub struct Component {
    /// component label (Table 2 row).
    pub name: &'static str,
    /// power draw in mW.
    pub power_mw: f64,
    /// silicon area in mm^2.
    pub area_mm2: f64,
}

/// Tile-level peripherals shared by ISAAC and Helix (Table 2, top block).
pub fn tile_peripherals() -> Vec<Component> {
    vec![
        Component { name: "eDRAM buffer (4 banks, 64KB)", power_mw: 20.7, area_mm2: 0.083 },
        Component { name: "bus (384 wires)", power_mw: 7.0, area_mm2: 0.09 },
        Component { name: "router (flit 32)", power_mw: 10.5, area_mm2: 0.0378 },
        Component { name: "activation x2", power_mw: 0.52, area_mm2: 0.0006 },
        Component { name: "shift-&-add", power_mw: 0.05, area_mm2: 0.00006 },
        Component { name: "maxpool", power_mw: 0.4, area_mm2: 0.0024 },
        Component { name: "output reg (3KB)", power_mw: 1.68, area_mm2: 0.0032 },
    ]
}

/// In-situ multiply-accumulate unit internals minus the ADC (Table 2,
/// middle block): 8 arrays 128x128 @ 2 bits/cell + DACs + regs + S&H + S+A.
pub fn ima_common() -> Vec<Component> {
    vec![
        Component { name: "NVM arrays x8 (128x128, 2b/cell)", power_mw: 2.4, area_mm2: 0.0002 },
        Component { name: "sample & hold x1024", power_mw: 0.001, area_mm2: 0.00004 },
        Component { name: "shift-&-add x4", power_mw: 0.2, area_mm2: 0.00024 },
        Component { name: "input reg (2KB)", power_mw: 1.24, area_mm2: 0.0021 },
        Component { name: "output reg (256B)", power_mw: 0.23, area_mm2: 0.00077 },
        Component { name: "DAC x1024 (1-bit)", power_mw: 4.0, area_mm2: 0.00017 },
    ]
}

fn sum(cs: &[Component]) -> (f64, f64) {
    cs.iter().fold((0.0, 0.0), |(p, a), c| (p + c.power_mw, a + c.area_mm2))
}

/// IMA totals with a CMOS ADC bank (ISAAC-class).
pub fn ima_with_cmos_adc(adc: &CmosAdc) -> (f64, f64) {
    let (p, a) = sum(&ima_common());
    (p + adc.power_mw(), a + adc.area_mm2())
}

/// IMA totals with SOT-MRAM ADC arrays (Helix): 8x4 arrays + vref + encoders
/// (Table 2, bottom block).
pub fn ima_with_sot_adc() -> (f64, f64) {
    let (p, a) = sum(&ima_common());
    let adc = SotAdcArray::paper();
    let n = 8.0 * 4.0;
    (p + n * adc.power_mw() + 0.02 + n * 0.001,
     a + n * adc.area_mm2() + 0.00003 + n * 2e-6)
}

/// Full-chip rollup.
#[derive(Clone, Copy, Debug)]
pub struct ChipBudget {
    /// tile count.
    pub tiles: usize,
    /// IMAs per tile.
    pub imas_per_tile: usize,
    /// per-tile power in mW (peripherals + IMAs).
    pub tile_power_mw: f64,
    /// per-tile area in mm^2.
    pub tile_area_mm2: f64,
    /// whole-chip power in W (incl. chip-level extras).
    pub power_w: f64,
    /// whole-chip area in mm^2.
    pub area_mm2: f64,
}

/// Roll tiles x (peripherals + IMAs) + chip-level extras into a budget.
pub fn chip(tiles: usize, imas_per_tile: usize, ima_pa: (f64, f64),
            extra: &[Component]) -> ChipBudget {
    let (pp, pa) = sum(&tile_peripherals());
    let tile_power = pp + imas_per_tile as f64 * ima_pa.0;
    let tile_area = pa + imas_per_tile as f64 * ima_pa.1;
    let (ep, ea) = sum(extra);
    ChipBudget {
        tiles,
        imas_per_tile,
        tile_power_mw: tile_power,
        tile_area_mm2: tile_area,
        power_w: tiles as f64 * tile_power / 1000.0 + ep / 1000.0,
        area_mm2: tiles as f64 * tile_area + ea,
    }
}

/// The SOT-MRAM binary comparator block of Helix (Table 2 bottom):
/// 1024x 256x256 arrays, 1.3 W, 0.11 mm^2.
pub fn comparator_block() -> Component {
    Component { name: "SOT-MRAM binary cmp (1024x 256x256)",
                power_mw: 1300.0, area_mm2: 0.11 }
}

/// ISAAC chip (Table 2 / Table 5): 168 tiles x 12 IMAs, 8-bit CMOS ADCs.
pub fn isaac_chip() -> ChipBudget {
    chip(168, 12, ima_with_cmos_adc(&CmosAdc::isaac()), &[])
}

/// Helix chip: SOT-MRAM ADCs + comparator block.
pub fn helix_chip() -> ChipBudget {
    chip(168, 12, ima_with_sot_adc(), &[comparator_block()])
}

/// Fig 8: power/area breakdown of an NVM dot-product engine — ADC share for
/// ReRAM/PCM/STT-MRAM (array cost differs by cell size but peripherals
/// dominate, so shares are similar across technologies).
pub fn fig8_breakdown(tech: &str) -> (f64, f64) {
    // array power/area scales with cell size: ReRAM/PCM 4F^2, STT 60F^2
    let cell_f2 = match tech {
        "reram" | "pcm" => 4.0,
        _ => 60.0,
    };
    let mut common = ima_common();
    common[0].area_mm2 *= cell_f2 / 4.0;
    let adc = CmosAdc::isaac();
    let (pc, ac) = sum(&common);
    let adc_power_share = adc.power_mw() / (pc + adc.power_mw());
    let adc_area_share = adc.area_mm2() / (ac + adc.area_mm2());
    (adc_power_share, adc_area_share)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isaac_tile_matches_table2() {
        let c = isaac_chip();
        // Table 2: ISAAC tile total 330 mW / 0.372 mm^2; chip 55.4W / 62.5mm^2
        assert!((c.tile_power_mw - 330.0).abs() / 330.0 < 0.05,
                "tile power {}", c.tile_power_mw);
        assert!((c.tile_area_mm2 - 0.372).abs() / 0.372 < 0.10,
                "tile area {}", c.tile_area_mm2);
        assert!((c.power_w - 55.4).abs() / 55.4 < 0.05, "chip {}", c.power_w);
        assert!((c.area_mm2 - 62.5).abs() / 62.5 < 0.10,
                "chip area {}", c.area_mm2);
    }

    #[test]
    fn helix_chip_matches_table2() {
        let c = helix_chip();
        // Table 2: Helix 25.7 W, 43.83 mm^2 (we accept a 15% modeling band —
        // Table 2's own sub-totals do not add up exactly).
        assert!((c.power_w - 25.7).abs() / 25.7 < 0.15, "power {}", c.power_w);
        assert!((c.area_mm2 - 43.83).abs() / 43.83 < 0.15,
                "area {}", c.area_mm2);
    }

    #[test]
    fn helix_cheaper_than_isaac() {
        let h = helix_chip();
        let i = isaac_chip();
        assert!(h.power_w < i.power_w * 0.6);
        assert!(h.area_mm2 < i.area_mm2 * 0.8);
    }

    #[test]
    fn fig8_adc_dominates_engine() {
        for tech in ["reram", "pcm", "stt"] {
            let (p, a) = fig8_breakdown(tech);
            // paper: ADCs cost 82-85% of power, 87-91% of area
            assert!(p > 0.60 && p < 0.95, "{tech} power share {p}");
            assert!(a > 0.60 && a < 0.97, "{tech} area share {a}");
        }
    }

    #[test]
    fn ima_sot_much_cheaper_than_cmos() {
        let (pc, _) = ima_with_cmos_adc(&CmosAdc::isaac());
        let (ps, _) = ima_with_sot_adc();
        // Table 2: 289 mW (ISAAC IMA w/ periph share) vs 122 mW...
        // at IMA granularity we expect at least ~2x
        assert!(ps < pc * 0.6, "cmos {pc} sot {ps}");
    }
}
