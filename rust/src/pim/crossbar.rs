//! NVM dot-product engine (§2.4 Fig 5, §4.2 Fig 17): functional bit-sliced
//! analog vector-matrix multiply + the 5-stage 10 MHz pipeline model.
//!
//! The functional model computes exactly what the analog datapath sees:
//! weights split into 2-bit cell slices across bit-lines, inputs streamed as
//! 1-bit DAC slices over cycles, bit-line currents digitized by an ADC of
//! finite resolution, then shift-&-add recombination. Comparing its output
//! against the exact fixed-point product quantifies the ADC-resolution
//! fidelity loss — the effect that forces ISAAC to 8-bit ADCs and that SEAT
//! (5-bit models) exploits to tolerate the 5-bit SOT-MRAM ADC arrays.

use super::adc::ideal_quantize;

/// Geometry/precision of one crossbar array.
#[derive(Clone, Copy, Debug)]
pub struct ArrayConfig {
    /// wordlines (inputs).
    pub rows: usize,
    /// bitlines (outputs).
    pub cols: usize,
    /// storage bits per memristor cell.
    pub bits_per_cell: u32,
    /// input DAC resolution.
    pub dac_bits: u32,
    /// output ADC resolution.
    pub adc_bits: u32,
    /// array cycle frequency in MHz.
    pub freq_mhz: f64,
}

impl Default for ArrayConfig {
    /// ISAAC array: 128x128, 2-bit cells, 1-bit DACs, 8-bit ADC, 10 MHz.
    fn default() -> Self {
        ArrayConfig {
            rows: 128,
            cols: 128,
            bits_per_cell: 2,
            dac_bits: 1,
            adc_bits: 8,
            freq_mhz: 10.0,
        }
    }
}

impl ArrayConfig {
    /// Cells used per `w`-bit weight.
    pub fn cells_per_weight(&self, w_bits: u32) -> u32 {
        w_bits.div_ceil(self.bits_per_cell)
    }

    /// Input cycles per `a`-bit activation.
    pub fn cycles_per_input(&self, a_bits: u32) -> u32 {
        a_bits.div_ceil(self.dac_bits)
    }

    /// Effective MACs per cycle for (w,a)-bit operands on a full array.
    pub fn macs_per_cycle(&self, w_bits: u32, a_bits: u32) -> f64 {
        (self.rows * self.cols) as f64
            / (self.cells_per_weight(w_bits) as f64
               * self.cycles_per_input(a_bits) as f64)
    }
}

/// Functional bit-sliced VMM: returns the crossbar's result for
/// `x (rows) * w (rows x cols)` with unsigned fixed-point operands in
/// [0, 1) quantized to (a_bits, w_bits).
///
/// `adc_bits` bounds the per-bitline current resolution per slice-cycle —
/// set to 32 for an ideal (infinite-resolution) datapath.
pub fn crossbar_vmm(x: &[f64], w: &[Vec<f64>], cfg: &ArrayConfig,
                    w_bits: u32, a_bits: u32) -> Vec<f64> {
    assert!(x.len() <= cfg.rows, "input exceeds array rows");
    assert_eq!(w.len(), x.len(), "weight rows");
    let cols = w.first().map_or(0, |r| r.len());
    assert!(cols <= cfg.cols, "weights exceed array cols");

    let wq: Vec<Vec<u64>> = w.iter()
        .map(|row| row.iter()
            .map(|&v| quant_unsigned(v, w_bits))
            .collect())
        .collect();
    let xq: Vec<u64> = x.iter().map(|&v| quant_unsigned(v, a_bits)).collect();

    let n_wslices = cfg.cells_per_weight(w_bits);
    let n_aslices = cfg.cycles_per_input(a_bits);
    let cell_mask = (1u64 << cfg.bits_per_cell) - 1;
    let dac_mask = (1u64 << cfg.dac_bits) - 1;
    // max bit-line current per slice pass: rows * max_cell * max_dac
    let i_max = (x.len() as u64 * cell_mask * dac_mask) as f64;

    let mut acc = vec![0.0f64; cols];
    for a_s in 0..n_aslices {
        for w_s in 0..n_wslices {
            for (c, accc) in acc.iter_mut().enumerate() {
                // analog accumulation along the bit-line (Kirchhoff sum)
                let mut i_bl = 0.0f64;
                for r in 0..x.len() {
                    let cell = (wq[r][c] >> (w_s * cfg.bits_per_cell))
                        & cell_mask;
                    let dac = (xq[r] >> (a_s * cfg.dac_bits)) & dac_mask;
                    i_bl += (cell * dac) as f64;
                }
                // ADC digitizes the bit-line current (>=24 bits is treated
                // as an ideal, infinite-resolution datapath)
                let dig = if cfg.adc_bits >= 24 { i_bl } else {
                    ideal_quantize(i_bl, i_max, cfg.adc_bits)
                };
                // shift-&-add recombination
                let shift = (a_s * cfg.dac_bits + w_s * cfg.bits_per_cell)
                    as i32;
                *accc += dig * 2f64.powi(shift);
            }
        }
    }
    // rescale from integer grids back to the [0,1) operand domain
    let scale = (grid(w_bits) * grid(a_bits)) as f64;
    acc.into_iter().map(|v| v / scale).collect()
}

/// Exact fixed-point reference for the same quantization grids.
pub fn exact_vmm(x: &[f64], w: &[Vec<f64>], w_bits: u32, a_bits: u32)
                 -> Vec<f64> {
    let cols = w.first().map_or(0, |r| r.len());
    let mut out = vec![0.0f64; cols];
    for (c, o) in out.iter_mut().enumerate() {
        let mut acc = 0u64;
        for r in 0..x.len() {
            acc += quant_unsigned(x[r], a_bits) * quant_unsigned(w[r][c], w_bits);
        }
        *o = acc as f64 / (grid(w_bits) * grid(a_bits)) as f64;
    }
    out
}

fn grid(bits: u32) -> u64 {
    (1u64 << bits) - 1
}

fn quant_unsigned(v: f64, bits: u32) -> u64 {
    (v.clamp(0.0, 1.0) * grid(bits) as f64).round() as u64
}

/// The 5-stage pipeline of Fig 17: fetch, MAC, ADC, shift-&-add, store.
pub const PIPELINE_STAGES: usize = 5;

/// Latency (cycles) and occupancy for one full (w,a)-bit VMM on one array.
pub fn vmm_latency_cycles(cfg: &ArrayConfig, w_bits: u32, a_bits: u32)
                          -> usize {
    let passes = (cfg.cells_per_weight(w_bits)
        * cfg.cycles_per_input(a_bits)) as usize;
    // pipelined: fill + one result per pass
    PIPELINE_STAGES + passes - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn rand_problem(rng: &mut Rng, rows: usize, cols: usize)
                    -> (Vec<f64>, Vec<Vec<f64>>) {
        let x: Vec<f64> = (0..rows).map(|_| rng.f64()).collect();
        let w: Vec<Vec<f64>> = (0..rows)
            .map(|_| (0..cols).map(|_| rng.f64()).collect())
            .collect();
        (x, w)
    }

    #[test]
    fn ideal_adc_matches_exact() {
        prop::check("crossbar = exact (ideal adc)", 20, |rng, _| {
            let rows = rng.range(1, 32) as usize;
            let cols = rng.range(1, 16) as usize;
            let (x, w) = rand_problem(rng, rows, cols);
            let cfg = ArrayConfig { adc_bits: 32, ..Default::default() };
            for (w_bits, a_bits) in [(2u32, 2u32), (4, 4), (8, 8)] {
                let got = crossbar_vmm(&x, &w, &cfg, w_bits, a_bits);
                let want = exact_vmm(&x, &w, w_bits, a_bits);
                for (g, e) in got.iter().zip(&want) {
                    assert!((g - e).abs() < 1e-9, "w{w_bits}a{a_bits}: {g} vs {e}");
                }
            }
        });
    }

    #[test]
    fn adc_resolution_bounds_error() {
        // 8-bit ADC keeps the 16-bit VMM usable; a 2-bit ADC wrecks it —
        // exactly the trade-off of Fig 7 vs the ADC-free design.
        let mut rng = Rng::new(11);
        let (x, w) = rand_problem(&mut rng, 128, 8);
        let exact = exact_vmm(&x, &w, 8, 8);
        let err = |adc_bits: u32| {
            let cfg = ArrayConfig { adc_bits, ..Default::default() };
            let got = crossbar_vmm(&x, &w, &cfg, 8, 8);
            got.iter().zip(&exact)
                .map(|(g, e)| (g - e).abs())
                .fold(0.0f64, f64::max)
                / exact.iter().cloned().fold(0.0f64, f64::max)
        };
        let e8 = err(8);
        let e5 = err(5);
        let e2 = err(2);
        assert!(e8 < e5 && e5 < e2, "e8 {e8} e5 {e5} e2 {e2}");
        assert!(e8 < 0.05, "8-bit ADC relative error {e8}");
    }

    #[test]
    fn five_bit_model_tolerates_five_bit_adc() {
        // SEAT's punchline: a 5-bit quantized layer loses almost nothing
        // through a 5-bit ADC datapath (relative to its own exact result).
        let mut rng = Rng::new(13);
        let (x, w) = rand_problem(&mut rng, 64, 8);
        let cfg = ArrayConfig { adc_bits: 5, ..Default::default() };
        let got = crossbar_vmm(&x, &w, &cfg, 5, 5);
        let want = exact_vmm(&x, &w, 5, 5);
        let rel = got.iter().zip(&want)
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f64, f64::max)
            / want.iter().cloned().fold(0.0f64, f64::max);
        assert!(rel < 0.12, "rel err {rel}");
    }

    #[test]
    fn macs_per_cycle_scaling() {
        let cfg = ArrayConfig::default();
        // 16-bit x 16-bit: 8 cell slices x 16 input cycles
        assert_eq!(cfg.cells_per_weight(16), 8);
        assert_eq!(cfg.cycles_per_input(16), 16);
        let m16 = cfg.macs_per_cycle(16, 16);
        let m5 = cfg.macs_per_cycle(5, 5);
        assert!((m16 - 128.0).abs() < 1e-9);
        // 5-bit: 3 slices x 5 cycles -> 128*128/15
        assert!((m5 - 128.0 * 128.0 / 15.0).abs() < 1e-9);
    }

    #[test]
    fn latency_includes_pipeline_fill() {
        let cfg = ArrayConfig::default();
        assert_eq!(vmm_latency_cycles(&cfg, 2, 1), PIPELINE_STAGES);
        assert!(vmm_latency_cycles(&cfg, 16, 16) > vmm_latency_cycles(&cfg, 5, 5));
    }
}
