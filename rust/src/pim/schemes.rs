//! The eight evaluation schemes of §5.3 and the Fig 24/25/26 models.
//!
//! End-to-end base-calling time per base = t_dnn + t_ctc + t_vote, each term
//! computed from the component models:
//!   * DNN on CPU/GPU: MACs/base over an effective MAC rate (Table 5
//!     machines; rates calibrated so full-precision Guppy lands at the
//!     paper's ~1 Mbp/s on the T4 — §1).
//!   * DNN on PIM: crossbar cell-ops/base (mapper) over the chip cell-op
//!     rate. ISAAC's native datapath stores 16-bit fixed-point weights
//!     (2-bit cells x 8) — "32-bit" models execute with 32 input-bit cycles,
//!     quantized ones with their own bit-width.
//!   * CTC on GPU: proportional to CTC steps x beam width (constant
//!     calibrated from the Fig 9 breakdown: 16.7% of 16-bit Guppy).
//!   * CTC on PIM: engine cell-ops from `ctc_engine` (shares the crossbars).
//!   * Vote on GPU: per-base constant from Fig 9 (37% of 16-bit Guppy).
//!   * Vote on Helix comparators: compute is concurrent across 1024 arrays;
//!     the binding resource is moving sub-strings + queries over the 384-bit
//!     10 MHz tile bus into the comparator block (6L + 3C bits per base).
//! Every calibration constant is a named const below with its anchor.

use super::comparator::ComparatorArray;
use super::ctc_engine;
use super::isaac::Chip;
use super::mapper::{dnn_cell_ops_per_base, Topology};

/// Effective GPU MAC rate at fp32 (MAC/s). Anchor: full-precision Guppy
/// (36.3M MACs / 30 bases) + CTC + vote = ~1 Mbp/s on the Tesla T4 (§1).
pub const GPU_MAC_RATE_FP32: f64 = 2.0e12;
/// Effective CPU MAC rate at fp32 (8-core Xeon E5-4655 v4, Table 5).
pub const CPU_MAC_RATE_FP32: f64 = 1.0e11;
/// GPU CTC decode cost per CTC step per base-window, at beam width 10.
/// Anchor: CTC = 16.7% of 16-bit Guppy latency (Fig 9).
pub const GPU_CTC_PER_STEP: f64 = 5.45e-8; // s per step / window
/// GPU read-vote cost per base. Anchor: vote = 37% of 16-bit Guppy (Fig 9).
pub const GPU_VOTE_PER_BASE: f64 = 2.4e-7;
/// CPU CTC/vote penalty vs GPU (poorly parallelized on 8 cores).
pub const CPU_SERIAL_PENALTY: f64 = 4.0;
/// Read length (bases) per voting group.
pub const VOTE_GROUP_LEN: f64 = 30.0;
/// Coverage: reads voting on each position.
pub const VOTE_COVERAGE: f64 = 30.0;
/// Tile bus feeding the comparator block: 384 wires @ 10 MHz (Table 2).
pub const VOTE_BUS_BITS_PER_SEC: f64 = 384.0 * 10.0e6;

/// Machine envelopes (Table 5): Xeon TDP.
pub const CPU_TDP_W: f64 = 135.0;
/// Xeon die area (Table 5).
pub const CPU_AREA_MM2: f64 = 450.0;
/// Tesla T4 TDP (Table 5).
pub const GPU_TDP_W: f64 = 70.0;
/// Tesla T4 die area (Table 5).
pub const GPU_AREA_MM2: f64 = 515.0;

/// The eight evaluated configurations of Fig 24 (cumulative left to
/// right: each scheme adds one Helix technique to the previous one).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Xeon CPU, full precision, everything in software.
    Cpu,
    /// Tesla T4, full precision DNN + CTC + vote.
    Gpu,
    /// DNN (fp32 model, 16b-cell datapath x 32 input cycles) on ISAAC;
    /// CTC + vote stay on the GPU at no charged cost (§5.3).
    Isaac,
    /// 16-bit quantized base-caller (no SEAT) on ISAAC.
    Q16,
    /// 5-bit + SEAT quantized base-caller on ISAAC (CMOS ADCs).
    Seat,
    /// SEAT + SOT-MRAM ADC arrays replacing the CMOS ADCs.
    Adc,
    /// ADC + CTC decoding moved onto the dot-product engines.
    Ctc,
    /// CTC + read voting on the SOT-MRAM comparator arrays: full Helix.
    Helix,
}

impl Scheme {
    /// Every scheme, in Fig 24's cumulative order.
    pub fn all() -> [Scheme; 8] {
        [Scheme::Cpu, Scheme::Gpu, Scheme::Isaac, Scheme::Q16,
         Scheme::Seat, Scheme::Adc, Scheme::Ctc, Scheme::Helix]
    }

    /// Fig 24 x-axis label.
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Cpu => "CPU",
            Scheme::Gpu => "GPU",
            Scheme::Isaac => "ISAAC",
            Scheme::Q16 => "16-bit",
            Scheme::Seat => "SEAT",
            Scheme::Adc => "ADC",
            Scheme::Ctc => "CTC",
            Scheme::Helix => "Helix",
        }
    }

    /// (weight bits, activation/input bits) of the DNN datapath.
    fn dnn_bits(&self) -> (u32, u32) {
        match self {
            Scheme::Cpu | Scheme::Gpu | Scheme::Isaac => (16, 32),
            Scheme::Q16 => (16, 16),
            _ => (5, 5),
        }
    }
}

/// (weight bits, activation bits) the software `runtime::native`
/// executor uses for a model declared at `model_bits` — the same
/// datapath mapping the PIM schemes charge: "full-precision" models
/// execute on the 16-bit fixed-point path (ISAAC stores 16-bit
/// weights, §5.3), quantized models at their own width.
pub fn native_datapath_bits(model_bits: u32) -> (u32, u32) {
    let b = model_bits.clamp(2, 16);
    (b, b)
}

/// Evaluation output for one (scheme, base-caller) pair.
#[derive(Clone, Copy, Debug)]
pub struct Eval {
    /// seconds of DNN forward pass per called base.
    pub t_dnn: f64,
    /// seconds of CTC decode per called base.
    pub t_ctc: f64,
    /// seconds of read voting per called base.
    pub t_vote: f64,
    /// power envelope charged to the scheme.
    pub power_w: f64,
    /// area envelope charged to the scheme.
    pub area_mm2: f64,
}

impl Eval {
    /// Total seconds per called base.
    pub fn t_total(&self) -> f64 {
        self.t_dnn + self.t_ctc + self.t_vote
    }

    /// Base-calling throughput in bases/s.
    pub fn throughput(&self) -> f64 {
        1.0 / self.t_total()
    }

    /// Bases/s/W (Fig 24 middle panel).
    pub fn throughput_per_watt(&self) -> f64 {
        self.throughput() / self.power_w
    }

    /// Bases/s/mm^2 (Fig 24 right panel).
    pub fn throughput_per_mm2(&self) -> f64 {
        self.throughput() / self.area_mm2
    }
}

/// Evaluate a scheme on a base-caller at a beam width (Fig 24 uses 10).
pub fn evaluate(scheme: Scheme, topo: &Topology, beam_width: usize) -> Eval {
    evaluate_with_adc(scheme, topo, beam_width, None)
}

/// Same, overriding the CMOS ADC resolution of the PIM datapath (Fig 25's
/// IMP 5-bit / SRE 6-bit comparison).
pub fn evaluate_with_adc(scheme: Scheme, topo: &Topology, beam_width: usize,
                         cmos_adc_bits: Option<u32>) -> Eval {
    let (w_bits, a_bits) = scheme.dnn_bits();
    let bases = topo.bases_per_window;
    let gpu_ctc = GPU_CTC_PER_STEP * topo.ctc_steps as f64
        * (beam_width as f64 / 10.0) / bases;
    let gpu_vote = GPU_VOTE_PER_BASE;
    let base = PimParams {
        w_bits,
        a_bits,
        gpu_ctc,
        gpu_vote,
        ctc_on_pim: false,
        vote_on_cmp: false,
        beam_width,
    };

    match scheme {
        Scheme::Cpu => Eval {
            t_dnn: topo.macs_per_base() / CPU_MAC_RATE_FP32,
            t_ctc: gpu_ctc * CPU_SERIAL_PENALTY,
            t_vote: gpu_vote * CPU_SERIAL_PENALTY,
            power_w: CPU_TDP_W,
            area_mm2: CPU_AREA_MM2,
        },
        Scheme::Gpu => Eval {
            t_dnn: topo.macs_per_base() / GPU_MAC_RATE_FP32,
            t_ctc: gpu_ctc,
            t_vote: gpu_vote,
            power_w: GPU_TDP_W,
            area_mm2: GPU_AREA_MM2,
        },
        Scheme::Isaac | Scheme::Q16 | Scheme::Seat => {
            let mut chip = Chip::isaac();
            if let Some(bits) = cmos_adc_bits {
                let ima = super::power::ima_with_cmos_adc(
                    &super::adc::CmosAdc::with_bits(bits));
                chip.budget = super::power::chip(chip.tiles,
                                                 chip.imas_per_tile, ima, &[]);
                chip.array.adc_bits = bits;
            }
            pim_eval(&chip, topo, &base)
        }
        Scheme::Adc => {
            let chip = Chip::helix_no_cmp();
            pim_eval(&chip, topo, &base)
        }
        Scheme::Ctc => {
            let chip = Chip::helix_no_cmp();
            pim_eval(&chip, topo,
                     &PimParams { ctc_on_pim: true, ..base })
        }
        Scheme::Helix => {
            let chip = Chip::helix();
            pim_eval(&chip, topo,
                     &PimParams { ctc_on_pim: true, vote_on_cmp: true,
                                  ..base })
        }
    }
}

/// The per-scheme knobs of the shared PIM evaluation: DNN operand
/// widths, the GPU fallback costs for the stages a scheme leaves off
/// the chip, and which stages it moves on (Fig 24's ADC/CTC/Helix
/// ablation axis).
#[derive(Clone, Copy)]
struct PimParams {
    w_bits: u32,
    a_bits: u32,
    gpu_ctc: f64,
    gpu_vote: f64,
    ctc_on_pim: bool,
    vote_on_cmp: bool,
    beam_width: usize,
}

fn pim_eval(chip: &Chip, topo: &Topology, p: &PimParams) -> Eval {
    let PimParams { w_bits, a_bits, gpu_ctc, gpu_vote, ctc_on_pim,
                    vote_on_cmp, beam_width } = *p;
    let rate = chip.cell_ops_per_sec();
    let mut dnn_ops = dnn_cell_ops_per_base(topo, &chip.array, w_bits, a_bits);
    let mut t_ctc = gpu_ctc;
    if ctc_on_pim {
        // CTC shares the dot-product engines: charge its cell-ops to the
        // same budget (§4.3 — no extra power or area).
        let ctc_ops = ctc_engine::cell_ops_per_window(
            topo.ctc_steps, beam_width, chip.array.rows, chip.array.cols)
            / topo.bases_per_window;
        dnn_ops += ctc_ops;
        t_ctc = 0.0;
    }
    let t_dnn = dnn_ops / rate;
    let t_vote = if vote_on_cmp {
        // compare cycles run concurrently on 1024 arrays; the bus transfer
        // of sub-strings (6L bits/base) + queries (3C bits/base) binds.
        let bus_bits = 6.0 * VOTE_GROUP_LEN + 3.0 * VOTE_COVERAGE;
        let t_bus = bus_bits / VOTE_BUS_BITS_PER_SEC;
        let cmp = ComparatorArray::paper();
        let t_cmp = cmp.cycles_per_vote(VOTE_GROUP_LEN as usize,
                                        VOTE_COVERAGE as usize)
            / (cmp.freq_mhz * 1e6)
            / VOTE_GROUP_LEN / 1024.0;
        t_bus + t_cmp
    } else {
        gpu_vote
    };
    Eval {
        t_dnn,
        t_ctc,
        t_vote,
        power_w: chip.budget.power_w,
        area_mm2: chip.budget.area_mm2,
    }
}

/// Geometric mean of per-model ratios of `f(scheme)` vs `f(baseline)` —
/// the aggregation used for the headline claims.
pub fn geomean_ratio<F: Fn(&Eval) -> f64>(scheme: Scheme, baseline: Scheme,
                                          beam: usize, f: F) -> f64 {
    let mut acc = 1.0f64;
    let topos = Topology::all();
    for t in &topos {
        let a = f(&evaluate(scheme, t, beam));
        let b = f(&evaluate(baseline, t, beam));
        acc *= a / b;
    }
    acc.powf(1.0 / topos.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_guppy_is_about_1mbps() {
        // §1: "Guppy ... obtains only 1 million base pairs per second"
        let e = evaluate(Scheme::Gpu, &Topology::guppy(), 10);
        let mbps = e.throughput() / 1e6;
        assert!(mbps > 0.7 && mbps < 1.4, "{mbps} Mbp/s");
    }

    #[test]
    fn fig9_breakdown_16bit_guppy() {
        // Fig 9: CTC 16.7%, vote 37% of 16-bit Guppy on the GPU. Pins
        // the calibration constant directly so a "temporary" rescale of
        // GPU_CTC_PER_STEP (like the old `/ 2.0 * 2.0` leftover) can't
        // silently drift the anchor.
        assert_eq!(GPU_CTC_PER_STEP, 5.45e-8);
        let t = Topology::guppy();
        let dnn16 = t.macs_per_base() / (GPU_MAC_RATE_FP32 * 2.0);
        let ctc = GPU_CTC_PER_STEP * t.ctc_steps as f64 / t.bases_per_window;
        let total = dnn16 + ctc + GPU_VOTE_PER_BASE;
        let fc = ctc / total;
        let fv = GPU_VOTE_PER_BASE / total;
        assert!((fc - 0.167).abs() < 0.05, "ctc frac {fc}");
        assert!((fv - 0.37).abs() < 0.06, "vote frac {fv}");
    }

    #[test]
    fn native_datapath_matches_scheme_widths() {
        // the software executor and the PIM schemes must agree on how a
        // declared bit-width maps onto the executed datapath
        assert_eq!(native_datapath_bits(32), (16, 16));
        assert_eq!(native_datapath_bits(16), (16, 16));
        assert_eq!(native_datapath_bits(8), (8, 8));
        assert_eq!(native_datapath_bits(5), (5, 5));
        assert_eq!(native_datapath_bits(5), Scheme::Seat.dnn_bits());
        assert_eq!(native_datapath_bits(16), Scheme::Q16.dnn_bits());
    }

    #[test]
    fn scheme_order_is_monotone_in_throughput() {
        // Fig 24(a): each accumulated technique must not hurt throughput.
        for topo in Topology::all() {
            let tp: Vec<f64> = [Scheme::Isaac, Scheme::Q16, Scheme::Seat,
                                Scheme::Adc, Scheme::Ctc, Scheme::Helix]
                .iter()
                .map(|&s| evaluate(s, &topo, 10).throughput())
                .collect();
            for w in tp.windows(2) {
                assert!(w[1] >= w[0] * 0.999,
                        "{}: {:?}", topo.name, tp);
            }
        }
    }

    #[test]
    fn headline_helix_vs_isaac() {
        // Conclusion: Helix = ~6x throughput, ~11.9x /W, ~7.5x /mm^2 over
        // ISAAC (accept a generous modeling band; exact values are logged by
        // the fig24 bench and recorded in EXPERIMENTS.md).
        let perf = geomean_ratio(Scheme::Helix, Scheme::Isaac, 10,
                                 |e| e.throughput());
        let pw = geomean_ratio(Scheme::Helix, Scheme::Isaac, 10,
                               |e| e.throughput_per_watt());
        let pa = geomean_ratio(Scheme::Helix, Scheme::Isaac, 10,
                               |e| e.throughput_per_mm2());
        assert!(perf > 3.0 && perf < 12.0, "perf {perf}");
        assert!(pw > 6.0 && pw < 24.0, "perf/W {pw}");
        assert!(pa > 4.0 && pa < 16.0, "perf/mm2 {pa}");
    }

    #[test]
    fn isaac_beats_cpu_and_gpu() {
        // Fig 24(a): ISAAC ~25x CPU, ~2.15x GPU on average.
        let vs_cpu = geomean_ratio(Scheme::Isaac, Scheme::Cpu, 10,
                                   |e| e.throughput());
        let vs_gpu = geomean_ratio(Scheme::Isaac, Scheme::Gpu, 10,
                                   |e| e.throughput());
        assert!(vs_cpu > 8.0, "vs cpu {vs_cpu}");
        assert!(vs_gpu > 1.2 && vs_gpu < 6.0, "vs gpu {vs_gpu}");
    }

    #[test]
    fn adc_scheme_iso_perf_lower_power() {
        for topo in Topology::all() {
            let seat = evaluate(Scheme::Seat, &topo, 10);
            let adc = evaluate(Scheme::Adc, &topo, 10);
            assert!((seat.t_total() - adc.t_total()).abs()
                    / seat.t_total() < 1e-9);
            assert!(adc.power_w < seat.power_w * 0.6);
            assert!(adc.area_mm2 < seat.area_mm2);
        }
    }

    #[test]
    fn ctc_gain_grows_with_beam_width() {
        // Fig 26: larger beam width -> bigger CTC-scheme gain over ADC.
        let topo = Topology::guppy();
        let gain = |w: usize| {
            evaluate(Scheme::Ctc, &topo, w).throughput()
                / evaluate(Scheme::Adc, &topo, w).throughput()
        };
        assert!(gain(30) > gain(10));
        assert!(gain(10) > gain(2));
    }

    #[test]
    fn chiron_gains_most_from_pim() {
        // §6.1: Chiron achieves the largest speedup from ISAAC (95% of its
        // time is the DNN part).
        let speedup = |t: &Topology| {
            evaluate(Scheme::Isaac, t, 10).throughput()
                / evaluate(Scheme::Gpu, t, 10).throughput()
        };
        let all = Topology::all();
        let chiron = speedup(all.iter().find(|t| t.name == "chiron").unwrap());
        for t in &all {
            assert!(chiron >= speedup(t) - 1e-9, "{}", t.name);
        }
    }
}
