//! SOT-MRAM device physics (§2.5, §4.2 Figs 13-16, Eq. 5, Table 1).
//!
//! The write-duration model is the thermally-activated switching law of
//! Eq. 5:  t = tau0 * exp((1 - I / (A * Jc0)) * Delta).  The ADC array
//! exploits voltage-controlled magnetic anisotropy (VCMA): a larger read
//! bit-line voltage lowers the required write voltage (Fig 13), which is
//! what turns an analog input voltage into a thermometer-coded digital
//! value across cells biased with staggered reference voltages.

/// Nominal device/transistor parameters (Table 1 means).
#[derive(Clone, Copy, Debug)]
pub struct DeviceParams {
    /// write/read transistor width (nm)
    pub w_wt: f64,
    /// write/read transistor length (nm)
    pub l_wt: f64,
    /// threshold voltage (V)
    pub v_th: f64,
    /// MTJ resistance-area product (Ohm * um^2)
    pub ra: f64,
    /// MTJ cross-section area (nm^2); Table 1: 64nm x 128nm
    pub area_nm2: f64,
    /// magnetization stability energy height Delta
    pub delta: f64,
}

impl Default for DeviceParams {
    fn default() -> Self {
        DeviceParams {
            w_wt: 384.0,
            l_wt: 192.0,
            v_th: 0.2,
            ra: 25.0,
            area_nm2: 64.0 * 128.0,
            delta: 22.0,
        }
    }
}

/// Relative sigmas of Table 1 (fractions of the mean).
#[derive(Clone, Copy, Debug)]
pub struct VariationSigmas {
    /// write-transistor width sigma.
    pub w_wt: f64,
    /// write-transistor length sigma.
    pub l_wt: f64,
    /// transistor threshold-voltage sigma.
    pub v_th: f64,
    /// MTJ resistance-area product sigma.
    pub ra: f64,
    /// MTJ area sigma.
    pub area: f64,
    /// thermal stability factor sigma.
    pub delta: f64,
}

impl Default for VariationSigmas {
    fn default() -> Self {
        VariationSigmas {
            w_wt: 0.10,
            l_wt: 0.10,
            v_th: 0.10,
            ra: 0.08,
            area: 0.05,
            delta: 0.27,
        }
    }
}

/// Fitting constant tau0 of Eq. 5 (s) — thermal-activation branch.
pub const TAU0: f64 = 1.0e-9;
/// Precessional-branch constant (s): for over-driven cells (I > Ic) the
/// switching time follows t ~ TAU0_P / (I/Ic - 1). Eq. 5's thermal law only
/// governs sub-critical currents; driven designs like the 1.56ns ADC-array
/// write (§4.2) operate in the precessional regime, which is what bounds
/// the Monte-Carlo tails of Figs 15/16.
pub const TAU0_P: f64 = 0.45e-9;
/// Critical current density at zero temperature (A/nm^2).
pub const JC0: f64 = 3.0e-7;

/// Simple transistor drive model: I_d ~ k * (W/L) * (Vgs - Vth)^2, with k
/// calibrated together with TAU0_P/JC0 so the nominal 60F^2 cell switches
/// well inside the paper's 1.56ns design pulse at the 0.55V operating point.
pub const K_DRIVE: f64 = 2.3e-2;

impl DeviceParams {
    /// Drive current (A) through the write transistor at gate overdrive
    /// `v_write` (V). Saturation square-law; good enough for MC trends.
    pub fn write_current(&self, v_write: f64) -> f64 {
        let ov = (v_write - self.v_th).max(0.0);
        K_DRIVE * (self.w_wt / self.l_wt) * ov * ov
    }

    /// Write duration (s) for a given drive current (A): Eq. 5 thermal
    /// activation below the critical current, precessional 1/(r-1) law
    /// above it (see TAU0_P).
    pub fn write_duration(&self, current: f64) -> f64 {
        let ic = self.area_nm2 * JC0; // critical current (A)
        let r = current / ic;
        if r > 1.05 {
            TAU0_P / (r - 1.0)
        } else {
            TAU0 * ((1.0 - r) * self.delta).exp()
        }
    }

    /// Duration at a write voltage (composition of the two models).
    pub fn duration_at_voltage(&self, v_write: f64) -> f64 {
        self.write_duration(self.write_current(v_write))
    }

    /// Switching probability for a pulse of `t_pulse` seconds at `v_write`
    /// volts (thermal activation; Fig 14's S-curves).
    pub fn switch_probability(&self, v_write: f64, t_pulse: f64) -> f64 {
        let tau = self.duration_at_voltage(v_write);
        1.0 - (-t_pulse / tau).exp()
    }
}

/// VCMA effect (Fig 13): effective write threshold voltage seen by the WBL
/// as a function of the RBL bias. Larger RBL voltage -> lower write
/// threshold. Linear fit over the paper's operating range (2.73V..3V on the
/// RBL, ~50mV/step of write-threshold shift per reference step).
pub fn vcma_write_threshold(v_rbl: f64) -> f64 {
    // At v_rbl = 3.0V the cell writes with 0.05V on the WBL; each 90 mV of
    // RBL reduction raises the needed write voltage by one 50 mV LSB.
    let base = 0.05;
    let slope = 0.05 / 0.09; // V per V
    base + (3.0 - v_rbl) * slope
}

/// The ADC array reference-voltage ladder (Fig 12): `levels` entries from
/// 3.00V downward in 90mV steps (the paper's 2-bit example uses
/// [3.0, 2.91, 2.82, 2.73]).
pub fn reference_ladder(levels: usize) -> Vec<f64> {
    (0..levels).map(|i| 3.0 - 0.09 * i as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_cell_switches_in_about_1_56ns() {
        // Design anchor: nominal 60F^2 cell, 0.05V overdrive step above Vth
        // at the ADC operating point -> ~1.56ns write pulse.
        let d = DeviceParams::default();
        let t = d.duration_at_voltage(d.v_th + 0.05 + 0.30);
        assert!(t > 0.3e-9 && t < 3e-9, "nominal duration {t:e}");
    }

    #[test]
    fn duration_monotone_decreasing_in_voltage() {
        // within the driven (precessional) regime; the thermal->precessional
        // crossover itself is a modeling seam, not an operating point.
        let d = DeviceParams::default();
        let mut last = f64::INFINITY;
        for i in 0..16 {
            let v = 0.45 + 0.05 * i as f64;
            let t = d.duration_at_voltage(v);
            assert!(t < last, "not monotone at {v}");
            last = t;
        }
    }

    #[test]
    fn switch_probability_is_probability_and_monotone() {
        let d = DeviceParams::default();
        let mut last = 0.0;
        for i in 1..30 {
            let p = d.switch_probability(0.5, 1e-10 * i as f64);
            assert!((0.0..=1.0).contains(&p));
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn vcma_threshold_decreases_with_rbl_voltage() {
        assert!(vcma_write_threshold(3.0) < vcma_write_threshold(2.91));
        assert!(vcma_write_threshold(2.91) < vcma_write_threshold(2.73));
        assert!((vcma_write_threshold(3.0) - 0.05).abs() < 1e-9);
    }

    #[test]
    fn ladder_matches_paper_example() {
        let l = reference_ladder(4);
        assert_eq!(l.len(), 4);
        assert!((l[0] - 3.0).abs() < 1e-9);
        assert!((l[1] - 2.91).abs() < 1e-9);
        assert!((l[3] - 2.73).abs() < 1e-9);
    }

    #[test]
    fn higher_delta_is_slower_in_thermal_regime() {
        // Delta governs the sub-critical (thermal activation) branch.
        let d = DeviceParams::default();
        let hi = DeviceParams { delta: 30.0, ..d };
        assert!(hi.duration_at_voltage(0.3) > d.duration_at_voltage(0.3));
    }
}
