//! DNN-to-crossbar mapper over the FULL-SIZE Table 3 base-caller
//! topologies: array allocation, fill factors, and engine cycles per
//! base-called window.

use super::crossbar::ArrayConfig;

/// Layer kind — recurrent layers have a sequential dependence over time
/// steps that bounds single-window latency (not batched throughput).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// convolution (parallel over time steps).
    Conv,
    /// recurrent (sequential over time steps).
    Rnn,
    /// fully connected.
    Fc,
}

/// One layer of a base-caller (full-size Table 3 numbers).
#[derive(Clone, Copy, Debug)]
pub struct Layer {
    /// what kind of layer this is (drives latency accounting).
    pub kind: LayerKind,
    /// multiply-accumulates per 300-sample input window.
    pub macs: f64,
    /// weight parameters.
    pub params: f64,
    /// rows of the weight matrix as mapped (for fill estimation).
    pub rows: usize,
    /// cols of the weight matrix as mapped.
    pub cols: usize,
    /// sequential time steps (1 for Conv/FC).
    pub steps: usize,
}

/// A full-size base-caller topology (Table 3).
#[derive(Clone, Debug)]
pub struct Topology {
    /// base-caller name (Table 3 column).
    pub name: &'static str,
    /// layers in execution order.
    pub layers: Vec<Layer>,
    /// CTC decoder time steps per window (output rows of Table 3).
    pub ctc_steps: usize,
    /// mean bases called per 300-sample window (~dwell 10 samples/base).
    pub bases_per_window: f64,
}

impl Topology {
    /// Table 3, Guppy column: conv 11x1x96 s2, 5x GRU 256, FC 40x5.
    pub fn guppy() -> Topology {
        Topology {
            name: "guppy",
            layers: vec![
                Layer { kind: LayerKind::Conv, macs: 0.2736e6, params: 1.8e3,
                        rows: 11, cols: 96, steps: 1 },
                Layer { kind: LayerKind::Rnn, macs: 36.0e6, params: 0.23e6,
                        rows: 256 + 96, cols: 3 * 256, steps: 150 },
                Layer { kind: LayerKind::Fc, macs: 0.012e6, params: 0.012e6,
                        rows: 40, cols: 5, steps: 1 },
            ],
            ctc_steps: 60,
            bases_per_window: 30.0,
        }
    }

    /// Table 3, Scrappie column.
    pub fn scrappie() -> Topology {
        Topology {
            name: "scrappie",
            layers: vec![
                Layer { kind: LayerKind::Conv, macs: 0.063e6, params: 1056.0,
                        rows: 11, cols: 96, steps: 1 },
                Layer { kind: LayerKind::Rnn, macs: 8.1e6, params: 0.14e6,
                        rows: 96 + 96, cols: 3 * 96, steps: 60 },
                Layer { kind: LayerKind::Fc, macs: 0.31e6, params: 0.31e6,
                        rows: 1025, cols: 5, steps: 1 },
            ],
            ctc_steps: 60,
            bases_per_window: 30.0,
        }
    }

    /// Table 3, Chiron column: 3 convs (570M MACs!), 6x LSTM 100, FC 100x5.
    pub fn chiron() -> Topology {
        Topology {
            name: "chiron",
            layers: vec![
                Layer { kind: LayerKind::Conv, macs: 570.0e6, params: 1.9e6,
                        rows: 256 * 3, cols: 256, steps: 1 },
                Layer { kind: LayerKind::Rnn, macs: 45.0e6, params: 0.15e6,
                        rows: 100 + 256, cols: 4 * 100, steps: 300 },
                Layer { kind: LayerKind::Fc, macs: 0.15e6, params: 0.15e6,
                        rows: 100, cols: 5, steps: 1 },
            ],
            ctc_steps: 300,
            bases_per_window: 30.0,
        }
    }

    /// Every Table 3 topology.
    pub fn all() -> Vec<Topology> {
        vec![Topology::guppy(), Topology::scrappie(), Topology::chiron()]
    }

    /// Look a topology up by its Table 3 name.
    pub fn by_name(name: &str) -> Option<Topology> {
        Topology::all().into_iter().find(|t| t.name == name)
    }

    /// Multiply-accumulates per window, summed over layers.
    pub fn total_macs(&self) -> f64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Weight parameters, summed over layers.
    pub fn total_params(&self) -> f64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    /// Compute cost normalized per called base.
    pub fn macs_per_base(&self) -> f64 {
        self.total_macs() / self.bases_per_window
    }
}

/// How a layer lands on crossbar arrays.
#[derive(Clone, Copy, Debug)]
pub struct LayerMapping {
    /// arrays needed to hold one copy of the weights.
    pub arrays: usize,
    /// fraction of allocated cells actually used.
    pub fill: f64,
    /// engine cell-op cycles consumed per window (throughput cost).
    pub cell_ops: f64,
}

/// Map one layer at (w,a)-bit precision onto `cfg`-shaped arrays.
pub fn map_layer(layer: &Layer, cfg: &ArrayConfig, w_bits: u32, a_bits: u32)
                 -> LayerMapping {
    let cpw = cfg.cells_per_weight(w_bits) as f64;
    let row_tiles = layer.rows.div_ceil(cfg.rows);
    let col_cells = (layer.cols as f64 * cpw).ceil() as usize;
    let col_tiles = col_cells.div_ceil(cfg.cols);
    let arrays = row_tiles * col_tiles;
    let used_cells = layer.params * cpw;
    let fill = (used_cells / (arrays as f64 * (cfg.rows * cfg.cols) as f64))
        .min(1.0);
    // cell-ops per window: every MAC needs cpw cell-slices x a input cycles;
    // under-filled arrays still burn whole-array passes -> divide by fill.
    let cell_ops = layer.macs * cpw * cfg.cycles_per_input(a_bits) as f64
        / fill.max(1e-3);
    LayerMapping { arrays, fill, cell_ops }
}

/// Chip-level DNN cost: engine cell-ops per base-called base.
pub fn dnn_cell_ops_per_base(topo: &Topology, cfg: &ArrayConfig,
                             w_bits: u32, a_bits: u32) -> f64 {
    let total: f64 = topo.layers.iter()
        .map(|l| map_layer(l, cfg, w_bits, a_bits).cell_ops)
        .sum();
    total / topo.bases_per_window
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_totals_match_paper() {
        let g = Topology::guppy();
        assert!((g.total_macs() - 36.3e6).abs() / 36.3e6 < 0.01);
        assert!((g.total_params() - 0.244e6).abs() / 0.244e6 < 0.01);
        let s = Topology::scrappie();
        assert!((s.total_macs() - 8.47e6).abs() / 8.47e6 < 0.01);
        let c = Topology::chiron();
        assert!((c.total_macs() - 615.2e6).abs() / 615.2e6 < 0.01);
        assert!((c.total_params() - 2.2e6).abs() / 2.2e6 < 0.01);
    }

    #[test]
    fn chiron_is_the_mac_heavy_one() {
        let all = Topology::all();
        let chiron = all.iter().find(|t| t.name == "chiron").unwrap();
        for t in &all {
            assert!(chiron.total_macs() >= t.total_macs());
        }
    }

    #[test]
    fn mapping_fill_in_unit_range() {
        let cfg = ArrayConfig::default();
        for topo in Topology::all() {
            for l in &topo.layers {
                let m = map_layer(l, &cfg, 16, 16);
                assert!(m.arrays >= 1);
                assert!(m.fill > 0.0 && m.fill <= 1.0,
                        "{}: fill {}", topo.name, m.fill);
            }
        }
    }

    #[test]
    fn lower_precision_needs_fewer_cell_ops() {
        let cfg = ArrayConfig::default();
        let topo = Topology::guppy();
        let c32 = dnn_cell_ops_per_base(&topo, &cfg, 32, 32);
        let c16 = dnn_cell_ops_per_base(&topo, &cfg, 16, 16);
        let c5 = dnn_cell_ops_per_base(&topo, &cfg, 5, 5);
        assert!(c32 > c16 && c16 > c5, "{c32} {c16} {c5}");
        // 32->16 bit is ~4x fewer cell-ops (2x slices x 2x cycles)
        let ratio = c32 / c16;
        assert!(ratio > 3.0 && ratio < 5.0, "ratio {ratio}");
    }
}
