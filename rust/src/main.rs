//! `helix` CLI — the Layer-3 entrypoint.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!   basecall  — run the coordinator over a synthetic sequencing run
//!   serve     — multi-tenant TCP front-end over one shared pipeline
//!   simulate  — emit a synthetic run's stats (Table 4 workloads)
//!   figures   — regenerate paper tables/figures: `helix figures fig24`
//!   schemes   — quick Fig 24 summary
//!   mc        — device Monte-Carlo (Figs 15/16)

use anyhow::Result;

use helix::basecall::ctc::BeamPrune;
use helix::basecall::edit::identity;
use helix::bench::figures;
use helix::coordinator::{resolve_knob, AutoscaleConfig, Coordinator,
                         CoordinatorConfig, KnobSource, ServeConfig,
                         Server};
use helix::genome::pore::PoreModel;
use helix::genome::synth::{RunSpec, SequencingRun};
use helix::runtime::meta::default_artifacts_dir;
use helix::runtime::BackendKind;

fn usage() -> ! {
    eprintln!("usage: helix <command> [options]\n\
        commands:\n  \
        basecall [--model guppy] [--bits 32] [--genome 2000] [--coverage 5]\n    \
        [--backend native|xla] [--shards N]\n    \
        [--max-shards N [--min-shards N] [--autoscale-tick-ms MS]\n     \
        [--slo-ms MS] [--autoscale-decode] [--autoscale-vote]\n     \
        [--hq-min-shards N] [--hq-max-shards N]]\n    \
        [--beam-prune DELTA [--beam-floor FLOOR]]\n    \
        [--escalate-margin M [--tier-bits B]]\n  \
        serve [--model guppy] [--bits 32] [--backend native|xla] \
        [--shards N]\n    \
        [--serve-addr HOST:PORT] [--tenant-quota N] [--slo-ms MS]\n    \
        [--escalate-margin M [--tier-bits B]]\n  \
        assemble [--model guppy] [--bits 32] [--genome 2000] \
        [--coverage 5]\n    \
        [--seed S] [--backend native|xla] [--shards N]\n    \
        [--analysis-threads N] [--reject-threshold M]\n    \
        [--max-shards N [--min-shards N] [--autoscale-tick-ms MS]\n     \
        [--slo-ms MS] [--autoscale-analysis]]\n  \
        simulate [--genome 10000] [--coverage 30]\n  \
        figures <fig2|...|fig26|table1..table5|all>\n  \
        schemes\n  \
        mc [--samples 100000]\n\
        env: HELIX_ARTIFACTS=artifacts HELIX_BACKEND=native|xla \
        HELIX_SHARDS=N\n     \
        HELIX_MAX_SHARDS=N HELIX_MIN_SHARDS=N HELIX_AUTOSCALE_TICK_MS=MS\n     \
        HELIX_SLO_MS=MS HELIX_AUTOSCALE_DECODE=1 HELIX_AUTOSCALE_VOTE=1\n     \
        HELIX_BEAM_PRUNE=DELTA HELIX_BEAM_FLOOR=FLOOR\n     \
        HELIX_ESCALATE_MARGIN=M HELIX_TIER_BITS=B\n     \
        HELIX_HQ_MIN_SHARDS=N HELIX_HQ_MAX_SHARDS=N\n     \
        HELIX_SERVE_ADDR=HOST:PORT HELIX_TENANT_QUOTA=N\n     \
        HELIX_ANALYSIS_THREADS=N HELIX_REJECT_THRESHOLD=M \
        HELIX_AUTOSCALE_ANALYSIS=1\n\
        Every knob resolves flag-over-env-over-default; a flag that does \
        not\n\
        parse is an error, a malformed env value keeps the default.\n\
        --max-shards (or HELIX_MAX_SHARDS) enables adaptive autoscaling: \
        the DNN\n\
        pool resizes between the min/max bounds from observed utilization \
        and,\n\
        with --slo-ms, from the p99 read latency of the last control tick;\n\
        --autoscale-decode/--autoscale-vote put those pools under the same\n\
        controller (ceiling = their configured widths).\n\
        --beam-prune (or HELIX_BEAM_PRUNE) enables pruned beam search in \
        the\n\
        decode pool: symbols more than DELTA log-prob below the step's \
        best are\n\
        not extended, and --beam-floor drops beams more than FLOOR below \
        the\n\
        best survivor. Unset = exhaustive search (byte-identical \
        baseline).\n\
        --escalate-margin (or HELIX_ESCALATE_MARGIN) arms speculative \
        tiered\n\
        serving: windows run on a low-bit fast model (--tier-bits, auto \
        when\n\
        unset) and any window whose top-two-beam score margin falls \
        below M is\n\
        re-run on the full-precision --bits model. M=0 never escalates; \
        M=inf\n\
        escalates everything (byte-identical to a full-precision run); \
        unset\n\
        runs the single-tier pipeline. --hq-min/max-shards bound the hq \
        pool\n\
        under the autoscaler (defaults: 1 and max-shards).\n\
        assemble runs the full streaming pipeline PAST basecalling: \
        voted\n\
        reads side-feed an in-pipeline analysis stage \
        (--analysis-threads,\n\
        default 2) that assembles and polishes a consensus \
        incrementally,\n\
        and --reject-threshold (or HELIX_REJECT_THRESHOLD) arms \
        GenPIP-style\n\
        early rejection: a read whose first decoded window's top-two \
        beam\n\
        margin falls below M is dropped before further decode/vote/\
        assembly\n\
        spend. M=0 never rejects (byte-identical to unset); M=inf \
        rejects\n\
        every read with a finite margin. --autoscale-analysis puts the\n\
        analysis pool under the --max-shards controller.\n\
        serve listens on --serve-addr (or HELIX_SERVE_ADDR; default\n\
        127.0.0.1:4550) and runs every connection as a tenant over ONE\n\
        shared pipeline: --tenant-quota bounds each tenant's in-flight \
        reads\n\
        (0 = unlimited; excess refused with BUSY so a greedy client \
        blocks\n\
        only itself) and --slo-ms arms load shedding (interval p99 over \
        the\n\
        budget refuses ALL new reads with BUSY until it recovers).");
    std::process::exit(2);
}

/// Flags that may appear bare (no value token): presence records "1".
/// Kept as an explicit allowlist so a value-taking flag with a missing
/// value does NOT silently become "1" — it still consumes the next
/// token and fails (or falls back) exactly as before.
const BARE_FLAGS: &[&str] = &["autoscale-decode", "autoscale-vote",
                              "autoscale-analysis"];

/// Tiny flag parser: `--key value` pairs after the subcommand, plus
/// the [`BARE_FLAGS`] booleans, which may stand alone or take an
/// explicit `1|true|0|false`.
fn flags(args: &[String]) -> std::collections::HashMap<String, String> {
    let mut out = std::collections::HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            if BARE_FLAGS.contains(&k) {
                match args.get(i + 1).map(|s| s.as_str()) {
                    Some(v @ ("1" | "true" | "0" | "false")) => {
                        out.insert(k.to_string(), v.to_string());
                        i += 2;
                    }
                    _ => {
                        out.insert(k.to_string(), "1".to_string());
                        i += 1;
                    }
                }
            } else if i + 1 < args.len() {
                out.insert(k.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

// `resolve_knob` parser callbacks shared by the basecall and serve
// subcommands (one contract for flag AND env values — range checks
// live here, not at the call sites).

fn pos_usize(s: &str) -> Option<usize> {
    s.parse::<usize>().ok().filter(|&n| n >= 1)
}

fn nonneg_usize(s: &str) -> Option<usize> {
    s.parse::<usize>().ok()
}

fn pos_ms(s: &str) -> Option<std::time::Duration> {
    s.parse::<u64>().ok().filter(|&ms| ms >= 1)
        .map(std::time::Duration::from_millis)
}

fn boolish(s: &str) -> Option<bool> {
    match s {
        "1" | "true" => Some(true),
        "0" | "false" => Some(false),
        _ => None,
    }
}

fn nonneg_f32(s: &str) -> Option<f32> {
    s.parse::<f32>().ok().filter(|v| v.is_finite() && *v >= 0.0)
}

// escalation margins may be infinite ("inf" = escalate everything),
// just never NaN or negative
fn margin_f32(s: &str) -> Option<f32> {
    s.parse::<f32>().ok().filter(|v| !v.is_nan() && *v >= 0.0)
}

const POS_INT: &str = "a positive integer";
const NONNEG_INT: &str = "a nonnegative integer (0 = unlimited)";
const POS_MS: &str = "positive milliseconds";
const BOOLISH: &str = "bare flag, or 1|true|0|false";

/// Resolve the backend kind: an explicit `--backend` beats
/// `HELIX_BACKEND` beats native.
fn backend_kind(f: &std::collections::HashMap<String, String>)
    -> Result<BackendKind>
{
    match f.get("backend").map(|s| s.as_str()) {
        None => BackendKind::from_env(),
        Some("native") => Ok(BackendKind::Native),
        #[cfg(feature = "xla")]
        Some("xla") => Ok(BackendKind::Xla),
        Some(other) => anyhow::bail!(
            "unknown --backend '{other}' (native|xla; xla needs \
             a `--features xla` build)"),
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("");
    let rest = &args[1.min(args.len())..];
    let f = flags(rest);
    let dir = default_artifacts_dir();
    match cmd {
        "basecall" => {
            let model = f.get("model").cloned()
                .unwrap_or_else(|| "guppy".into());
            let bits: u32 = f.get("bits").map_or(32, |s| s.parse().unwrap_or(32));
            let genome: usize = f.get("genome")
                .map_or(2000, |s| s.parse().unwrap_or(2000));
            let coverage: usize = f.get("coverage")
                .map_or(5, |s| s.parse().unwrap_or(5));
            let kind = backend_kind(&f)?;
            // Every serving knob below resolves through ONE rule
            // (coordinator::config::resolve_knob): an explicit flag
            // beats the HELIX_* env var beats the default, a flag that
            // doesn't parse is an error (like --backend), and a
            // malformed env value silently keeps the default.
            // DNN shard count: --shards beats HELIX_SHARDS beats 1.
            let shards: usize =
                resolve_knob(&f, "shards", "HELIX_SHARDS", POS_INT,
                             pos_usize)?
                    .map_or(1, |(n, _)| n);
            // adaptive autoscaling: enabled by --max-shards or
            // HELIX_MAX_SHARDS; the refinement knobs each resolve
            // flag-over-env on top of whichever base enabled it.
            let autoscale: Option<AutoscaleConfig> = match resolve_knob(
                &f, "max-shards", "HELIX_MAX_SHARDS", POS_INT,
                pos_usize)?
            {
                Some((n, _)) => {
                    let mut a = AutoscaleConfig {
                        max_shards: n,
                        ..AutoscaleConfig::default()
                    };
                    if let Some((v, _)) = resolve_knob(
                        &f, "min-shards", "HELIX_MIN_SHARDS", POS_INT,
                        pos_usize)?
                    {
                        a.min_shards = v;
                    }
                    if let Some((v, _)) = resolve_knob(
                        &f, "autoscale-tick-ms",
                        "HELIX_AUTOSCALE_TICK_MS", POS_MS, pos_ms)?
                    {
                        a.tick = v;
                    }
                    // latency SLO: p99 over this budget reads as hot
                    // even when utilization is low (trickle loads)
                    if let Some((v, _)) = resolve_knob(
                        &f, "slo-ms", "HELIX_SLO_MS", POS_MS, pos_ms)?
                    {
                        a.slo = Some(v);
                    }
                    // bare flags: presence (value "1"/"true") opts the
                    // decode/vote pools into the same controller
                    if let Some((v, _)) = resolve_knob(
                        &f, "autoscale-decode", "HELIX_AUTOSCALE_DECODE",
                        BOOLISH, boolish)?
                    {
                        a.scale_decode = v;
                    }
                    if let Some((v, _)) = resolve_knob(
                        &f, "autoscale-vote", "HELIX_AUTOSCALE_VOTE",
                        BOOLISH, boolish)?
                    {
                        a.scale_vote = v;
                    }
                    // hq-tier pool bounds (used when --escalate-margin
                    // arms tiered serving; harmless otherwise)
                    if let Some((v, _)) = resolve_knob(
                        &f, "hq-min-shards", "HELIX_HQ_MIN_SHARDS",
                        POS_INT, pos_usize)?
                    {
                        a.hq_min_shards = v;
                    }
                    if let Some((v, _)) = resolve_knob(
                        &f, "hq-max-shards", "HELIX_HQ_MAX_SHARDS",
                        POS_INT, pos_usize)?
                    {
                        a.hq_max_shards = v;
                    }
                    Some(a.normalized())
                }
                None => {
                    // refinement FLAGS without a base are operator
                    // errors; the same settings arriving via env are
                    // ignored (a CI profile may export them globally)
                    for key in ["min-shards", "autoscale-tick-ms",
                                "slo-ms", "autoscale-decode",
                                "autoscale-vote", "hq-min-shards",
                                "hq-max-shards"] {
                        if f.contains_key(key) {
                            anyhow::bail!(
                                "--{key} needs autoscaling enabled via \
                                 --max-shards or HELIX_MAX_SHARDS");
                        }
                    }
                    None
                }
            };
            // pruned beam search: --beam-prune beats HELIX_BEAM_PRUNE;
            // --beam-floor refines whichever base enabled it.
            let prune: Option<BeamPrune> = match resolve_knob(
                &f, "beam-prune", "HELIX_BEAM_PRUNE",
                "a nonnegative log-prob delta", nonneg_f32)?
            {
                Some((d, _)) => {
                    let mut p = BeamPrune {
                        symbol_delta: d,
                        ..BeamPrune::defaults()
                    };
                    if let Some((fl, _)) = resolve_knob(
                        &f, "beam-floor", "HELIX_BEAM_FLOOR",
                        "a nonnegative log-prob distance", nonneg_f32)?
                    {
                        p.score_floor = fl;
                    }
                    Some(p)
                }
                None => {
                    if f.contains_key("beam-floor") {
                        anyhow::bail!(
                            "--beam-floor needs pruning enabled via \
                             --beam-prune or HELIX_BEAM_PRUNE");
                    }
                    None
                }
            };
            // speculative tiered serving: --escalate-margin arms the
            // fast/hq pair; --tier-bits optionally pins the fast
            // bit-width (auto-selected from the artifact ladder when
            // unset). A typed --tier-bits without a margin is an
            // operator error; HELIX_TIER_BITS alone is ignored.
            let escalate_margin: Option<f32> = resolve_knob(
                &f, "escalate-margin", "HELIX_ESCALATE_MARGIN",
                "a non-negative log-prob margin, or 'inf'", margin_f32)?
                .map(|(m, _)| m);
            let tier_bits: Option<u32> = match resolve_knob(
                &f, "tier-bits", "HELIX_TIER_BITS",
                "a positive bit-width",
                |s: &str| s.parse::<u32>().ok().filter(|&b| b >= 1))?
            {
                Some((_, KnobSource::Flag)) if escalate_margin.is_none() =>
                    anyhow::bail!("--tier-bits needs --escalate-margin \
                                   or HELIX_ESCALATE_MARGIN"),
                Some(_) if escalate_margin.is_none() => None,
                Some((b, _)) => Some(b),
                None => None,
            };
            kind.prepare(&dir)?;
            let pm = PoreModel::load(&format!("{dir}/pore_model.json"))?;
            let run = SequencingRun::simulate(&pm, RunSpec {
                genome_len: genome, coverage, ..Default::default()
            });
            let scale_note = match &autoscale {
                Some(a) => {
                    let mut note = format!(
                        ", autoscale {}..{} every {:?}",
                        a.min_shards, a.max_shards, a.tick);
                    if let Some(slo) = a.slo {
                        note.push_str(&format!(", slo p99<{slo:?}"));
                    }
                    if a.scale_decode {
                        note.push_str(", +decode");
                    }
                    if a.scale_vote {
                        note.push_str(", +vote");
                    }
                    note
                }
                None => String::new(),
            };
            let prune_note = match &prune {
                Some(p) => format!(", beam prune δ={} floor={}",
                                   p.symbol_delta, p.score_floor),
                None => String::new(),
            };
            println!("basecalling {} reads ({} genome, {:.1}x coverage) \
                      with {model}/{bits}b on the {} backend \
                      ({shards} dnn shard{}{scale_note}{prune_note}) ...",
                     run.reads.len(), genome, run.mean_coverage(),
                     kind.name(), if shards == 1 { "" } else { "s" });
            let mut coord = Coordinator::new(CoordinatorConfig {
                model, bits, backend: kind, artifacts_dir: dir.clone(),
                dnn_shards: shards,
                autoscale,
                prune,
                escalate_margin,
                tier_bits,
                ..Default::default()
            })?;
            if let (Some(t), Some(m)) = (coord.tier_set(),
                                         escalate_margin) {
                println!("tiered serving: fast {}b -> hq {}b, escalate \
                          when margin < {m}", t.fast_bits, t.hq_bits);
            }
            let t0 = std::time::Instant::now();
            // stream: collect reads the moment they complete, while later
            // reads are still being submitted
            let mut called = Vec::new();
            for r in &run.reads {
                coord.submit(r);
                called.extend(coord.drain_ready());
            }
            let streamed = called.len();
            let max_batch = coord.max_batch();
            let metrics = coord.metrics.clone();
            called.extend(coord.finish()?);
            called.sort_by_key(|c| c.read_id);
            let dt = t0.elapsed();
            let mut acc = 0.0;
            for c in &called {
                let truth = &run.reads.iter()
                    .find(|r| r.id == c.read_id).unwrap().seq;
                acc += identity(&c.seq,
                                &truth[..truth.len().min(c.seq.len() + 8)]);
            }
            println!("called {} reads in {:.2?} ({streamed} streamed out \
                      before the run ended)", called.len(), dt);
            println!("mean read identity: {:.4}", acc / called.len() as f64);
            println!("{}", metrics.report(max_batch));
        }
        "serve" => {
            let model = f.get("model").cloned()
                .unwrap_or_else(|| "guppy".into());
            let bits: u32 = f.get("bits").map_or(32, |s| s.parse().unwrap_or(32));
            let kind = backend_kind(&f)?;
            let shards: usize =
                resolve_knob(&f, "shards", "HELIX_SHARDS", POS_INT,
                             pos_usize)?
                    .map_or(1, |(n, _)| n);
            // listen address: any nonempty host:port; port 0 binds an
            // ephemeral port (printed once the listener is up)
            let addr: String = resolve_knob(
                &f, "serve-addr", "HELIX_SERVE_ADDR", "host:port",
                |s: &str| if s.contains(':') { Some(s.to_string()) }
                          else { None })?
                .map_or_else(|| "127.0.0.1:4550".into(), |(a, _)| a);
            let tenant_quota: usize = resolve_knob(
                &f, "tenant-quota", "HELIX_TENANT_QUOTA", NONNEG_INT,
                nonneg_usize)?
                .map_or(ServeConfig::default().tenant_quota,
                        |(n, _)| n);
            // NOTE: under `serve`, --slo-ms is the load-shedding
            // budget and stands alone (no --max-shards needed); the
            // basecall subcommand gives the same flag to the
            // autoscaler instead.
            let slo = resolve_knob(&f, "slo-ms", "HELIX_SLO_MS",
                                   POS_MS, pos_ms)?
                .map(|(v, _)| v);
            let escalate_margin: Option<f32> = resolve_knob(
                &f, "escalate-margin", "HELIX_ESCALATE_MARGIN",
                "a non-negative log-prob margin, or 'inf'", margin_f32)?
                .map(|(m, _)| m);
            let tier_bits: Option<u32> = match resolve_knob(
                &f, "tier-bits", "HELIX_TIER_BITS",
                "a positive bit-width",
                |s: &str| s.parse::<u32>().ok().filter(|&b| b >= 1))?
            {
                Some((_, KnobSource::Flag)) if escalate_margin.is_none() =>
                    anyhow::bail!("--tier-bits needs --escalate-margin \
                                   or HELIX_ESCALATE_MARGIN"),
                Some(_) if escalate_margin.is_none() => None,
                Some((b, _)) => Some(b),
                None => None,
            };
            kind.prepare(&dir)?;
            let cfg = CoordinatorConfig {
                model: model.clone(), bits, backend: kind,
                artifacts_dir: dir.clone(),
                dnn_shards: shards,
                escalate_margin,
                tier_bits,
                ..Default::default()
            };
            let max_batch = cfg.policy.max_batch;
            let server = Server::start(cfg, ServeConfig {
                addr, tenant_quota, slo,
            })?;
            println!("serving {model}/{bits}b on {} ({shards} dnn \
                      shard{}, tenant quota {}, slo {}) — kill to stop",
                     server.local_addr(),
                     if shards == 1 { "" } else { "s" },
                     if tenant_quota == 0 { "unlimited".into() }
                     else { tenant_quota.to_string() },
                     slo.map_or("off".into(), |d| format!("{d:?}")));
            // foreground forever: periodic metrics report (per-tenant
            // rows included); the process is stopped by signal
            loop {
                std::thread::sleep(std::time::Duration::from_secs(30));
                println!("{}", server.metrics().report(max_batch));
            }
        }
        "assemble" => {
            let model = f.get("model").cloned()
                .unwrap_or_else(|| "guppy".into());
            let bits: u32 = f.get("bits").map_or(32, |s| s.parse().unwrap_or(32));
            let genome: usize = f.get("genome")
                .map_or(2000, |s| s.parse().unwrap_or(2000));
            let coverage: usize = f.get("coverage")
                .map_or(5, |s| s.parse().unwrap_or(5));
            let seed: Option<u64> =
                f.get("seed").and_then(|s| s.parse().ok());
            let kind = backend_kind(&f)?;
            let shards: usize =
                resolve_knob(&f, "shards", "HELIX_SHARDS", POS_INT,
                             pos_usize)?
                    .map_or(1, |(n, _)| n);
            // streaming analysis stage width: overlap/assembly/polish
            // workers fed by the vote stage (this subcommand always
            // opens the stage; basecall/serve leave it off)
            let analysis_threads: usize = resolve_knob(
                &f, "analysis-threads", "HELIX_ANALYSIS_THREADS",
                POS_INT, pos_usize)?
                .map_or(2, |(n, _)| n);
            // GenPIP-style early rejection: margin threshold shares the
            // escalation margin's parse rule (non-negative, 'inf' ok)
            let reject_threshold: Option<f32> = resolve_knob(
                &f, "reject-threshold", "HELIX_REJECT_THRESHOLD",
                "a non-negative posterior margin, or 'inf'", margin_f32)?
                .map(|(m, _)| m);
            let autoscale: Option<AutoscaleConfig> = match resolve_knob(
                &f, "max-shards", "HELIX_MAX_SHARDS", POS_INT,
                pos_usize)?
            {
                Some((n, _)) => {
                    let mut a = AutoscaleConfig {
                        max_shards: n,
                        ..AutoscaleConfig::default()
                    };
                    if let Some((v, _)) = resolve_knob(
                        &f, "min-shards", "HELIX_MIN_SHARDS", POS_INT,
                        pos_usize)?
                    {
                        a.min_shards = v;
                    }
                    if let Some((v, _)) = resolve_knob(
                        &f, "autoscale-tick-ms",
                        "HELIX_AUTOSCALE_TICK_MS", POS_MS, pos_ms)?
                    {
                        a.tick = v;
                    }
                    if let Some((v, _)) = resolve_knob(
                        &f, "slo-ms", "HELIX_SLO_MS", POS_MS, pos_ms)?
                    {
                        a.slo = Some(v);
                    }
                    // bare flag: put the analysis pool under the same
                    // controller (ceiling = --analysis-threads)
                    if let Some((v, _)) = resolve_knob(
                        &f, "autoscale-analysis",
                        "HELIX_AUTOSCALE_ANALYSIS", BOOLISH, boolish)?
                    {
                        a.scale_analysis = v;
                    }
                    Some(a.normalized())
                }
                None => {
                    for key in ["min-shards", "autoscale-tick-ms",
                                "slo-ms", "autoscale-analysis"] {
                        if f.contains_key(key) {
                            anyhow::bail!(
                                "--{key} needs autoscaling enabled via \
                                 --max-shards or HELIX_MAX_SHARDS");
                        }
                    }
                    None
                }
            };
            kind.prepare(&dir)?;
            let pm = PoreModel::load(&format!("{dir}/pore_model.json"))?;
            let mut spec = RunSpec {
                genome_len: genome, coverage, ..Default::default()
            };
            if let Some(s) = seed {
                spec.seed = s;
            }
            let run = SequencingRun::simulate(&pm, spec);
            println!("assembling {} reads ({} genome bp, {:.1}x \
                      coverage) with {model}/{bits}b on the {} backend \
                      ({shards} dnn shard{}, {analysis_threads} \
                      analysis worker{}, reject {}) ...",
                     run.reads.len(), genome, run.mean_coverage(),
                     kind.name(),
                     if shards == 1 { "" } else { "s" },
                     if analysis_threads == 1 { "" } else { "s" },
                     reject_threshold
                         .map_or("off".into(), |m| format!("margin<{m}")));
            let mut coord = Coordinator::new(CoordinatorConfig {
                model, bits, backend: kind, artifacts_dir: dir.clone(),
                dnn_shards: shards,
                autoscale,
                analysis_threads,
                reject_threshold,
                ..Default::default()
            })?;
            let state = coord.analysis_state()
                .expect("assemble always opens the analysis stage");
            let t0 = std::time::Instant::now();
            let mut called = Vec::new();
            for r in &run.reads {
                coord.submit(r);
                called.extend(coord.drain_ready());
            }
            let max_batch = coord.max_batch();
            let metrics = coord.metrics.clone();
            called.extend(coord.finish()?);
            let dt = t0.elapsed();
            // finish() returns only after the analysis workers folded
            // every voted read, so the consensus below is complete
            let consensus = state.consensus(0);
            let rejected = metrics.rejected_reads
                .load(std::sync::atomic::Ordering::Relaxed);
            let id = if consensus.is_empty() { 0.0 }
                     else { identity(&consensus, &run.genome) };
            println!("called {} reads ({rejected} rejected) in {:.2?}",
                     called.len(), dt);
            println!("polished consensus: {} bp (genome {} bp), \
                      identity {:.4}",
                     consensus.len(), run.genome.len(), id);
            println!("{}", metrics.report(max_batch));
        }
        "simulate" => {
            let genome: usize = f.get("genome")
                .map_or(10_000, |s| s.parse().unwrap_or(10_000));
            let coverage: usize = f.get("coverage")
                .map_or(30, |s| s.parse().unwrap_or(30));
            let pm = PoreModel::load(&format!("{dir}/pore_model.json"))
                .unwrap_or_else(|_| PoreModel::synthetic(7));
            let run = SequencingRun::simulate(&pm, RunSpec {
                genome_len: genome, coverage, ..Default::default()
            });
            let samples: usize = run.reads.iter()
                .map(|r| r.signal.len()).sum();
            println!("genome {} bp, {} reads, {:.1}x coverage, {} raw \
                      samples", genome, run.reads.len(),
                     run.mean_coverage(), samples);
        }
        "figures" => {
            let which = rest.first().map(|s| s.as_str()).unwrap_or("all");
            figures::run(which, &dir)?;
        }
        "schemes" => figures::run("fig24", &dir)?,
        "mc" => {
            let samples: usize = f.get("samples")
                .map_or(100_000, |s| s.parse().unwrap_or(100_000));
            let st = helix::pim::variation::duration_mc(
                60.0, helix::pim::variation::ADC_WRITE_VOLTAGE, samples, 7);
            println!("60F^2 @{} samples: mean {:.3}ns sigma {:.3}ns \
                      worst {:.3}ns", st.samples, st.mean_ns, st.sigma_ns,
                     st.worst_ns);
        }
        _ => usage(),
    }
    Ok(())
}
