//! Read mapping (Fig 1 stage 4): place each base-called read on the draft
//! assembly with seed-and-extend, returning the aligned interval.

use std::collections::HashMap;

use crate::basecall::edit::{edit_distance_banded, identity};

use super::overlap::SEED_K;

/// A read mapped onto the draft.
#[derive(Clone, Copy, Debug)]
pub struct Mapping {
    /// start position on the draft.
    pub pos: usize,
    /// length of the draft interval.
    pub len: usize,
    /// identity of the read vs that interval.
    pub identity: f64,
}

/// Seed index over the draft.
pub struct DraftIndex {
    k: usize,
    index: HashMap<u64, Vec<usize>>,
}

impl DraftIndex {
    /// Index every k-mer position of the draft for seed lookups.
    pub fn build(draft: &[u8]) -> DraftIndex {
        let k = SEED_K;
        let mut index: HashMap<u64, Vec<usize>> = HashMap::new();
        if draft.len() >= k {
            for (i, w) in draft.windows(k).enumerate() {
                let mut h = 0u64;
                for &b in w {
                    h = h * 4 + b as u64;
                }
                index.entry(h).or_default().push(i);
            }
        }
        DraftIndex { k, index }
    }
}

/// Map a read onto the draft: vote on the offset implied by each shared
/// seed, then score the best candidate with banded alignment.
pub fn map_read(read: &[u8], draft: &[u8], idx: &DraftIndex)
                -> Option<Mapping> {
    if read.len() < idx.k || draft.len() < idx.k {
        return None;
    }
    let mut offset_votes: HashMap<i64, u32> = HashMap::new();
    for (i, w) in read.windows(idx.k).enumerate() {
        let mut h = 0u64;
        for &b in w {
            h = h * 4 + b as u64;
        }
        if let Some(hits) = idx.index.get(&h) {
            for &p in hits.iter().take(8) {
                *offset_votes.entry(p as i64 - i as i64).or_insert(0) += 1;
            }
        }
    }
    // allow nearby offsets to pool (indels shift seeds slightly)
    let (&best_off, _) = offset_votes.iter()
        .max_by_key(|&(off, &v)| {
            let near: u32 = (-3..=3i64)
                .filter_map(|d| offset_votes.get(&(off + d)))
                .sum();
            (near, v, std::cmp::Reverse(*off))
        })?;
    let pos = best_off.max(0) as usize;
    if pos >= draft.len() {
        return None;
    }
    let len = read.len().min(draft.len() - pos);
    let interval = &draft[pos..pos + len];
    let band = (read.len() / 6).max(4);
    let d = edit_distance_banded(read, interval, band);
    let id = 1.0 - (d as f64 / read.len().max(1) as f64);
    if id < 0.5 {
        return None;
    }
    Some(Mapping { pos, len, identity: id.max(0.0) })
}

/// Mean mapping identity over a read set — the "draft" series of Fig 23.
pub fn mean_mapping_identity(reads: &[Vec<u8>], draft: &[u8]) -> f64 {
    let idx = DraftIndex::build(draft);
    let mut acc = 0.0;
    let mut n = 0usize;
    for r in reads {
        if let Some(m) = map_read(r, draft, &idx) {
            acc += m.identity;
            n += 1;
        }
    }
    if n == 0 { 0.0 } else { acc / n as f64 }
}

/// Identity of the draft against the true genome (aligned at the best
/// seed offset) — the quality metric Fig 23 reports for "draft".
pub fn draft_vs_truth(draft: &[u8], genome: &[u8]) -> f64 {
    let n = draft.len().min(genome.len());
    if n == 0 {
        return 0.0;
    }
    identity(&draft[..n], &genome[..n])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn maps_exact_read() {
        let mut rng = Rng::new(7);
        let draft: Vec<u8> = (0..300).map(|_| rng.base()).collect();
        let idx = DraftIndex::build(&draft);
        let read = draft[100..180].to_vec();
        let m = map_read(&read, &draft, &idx).unwrap();
        assert_eq!(m.pos, 100);
        assert!(m.identity > 0.99);
    }

    #[test]
    fn maps_noisy_read() {
        let mut rng = Rng::new(8);
        let draft: Vec<u8> = (0..300).map(|_| rng.base()).collect();
        let idx = DraftIndex::build(&draft);
        let mut read = draft[50..140].to_vec();
        for _ in 0..6 {
            let i = rng.below(read.len());
            read[i] = (read[i] + 1) % 4;
        }
        let m = map_read(&read, &draft, &idx).unwrap();
        assert!(m.pos.abs_diff(50) <= 3, "pos {}", m.pos);
        assert!(m.identity > 0.85, "{}", m.identity);
    }

    #[test]
    fn rejects_unrelated_read() {
        let mut rng = Rng::new(9);
        let draft: Vec<u8> = (0..200).map(|_| rng.base()).collect();
        let idx = DraftIndex::build(&draft);
        let read: Vec<u8> = (0..80).map(|_| rng.base()).collect();
        if let Some(m) = map_read(&read, &draft, &idx) {
            assert!(m.identity < 0.8, "spurious {m:?}");
        }
    }
}
