//! Overlap finding (Fig 1 stage 2): all suffix-prefix matches between read
//! pairs, seeded by shared k-mers and verified with banded alignment.

use std::collections::HashMap;

use crate::basecall::vote::best_overlap;

/// One suffix(a)-prefix(b) overlap edge of the overlap graph.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Overlap {
    /// index of the read whose suffix matches.
    pub a: usize,
    /// index of the read whose prefix matches.
    pub b: usize,
    /// overlap length in bases.
    pub len: usize,
}

/// Seed size for candidate generation. 8 bases = 16 bits of specificity,
/// enough at nanopore error rates over the read lengths we simulate.
pub const SEED_K: usize = 8;

fn seeds(read: &[u8]) -> impl Iterator<Item = (u64, usize)> + '_ {
    read.windows(SEED_K).enumerate().map(|(i, w)| {
        let mut h = 0u64;
        for &b in w {
            h = h * 4 + b as u64;
        }
        (h, i)
    })
}

/// Seed hashes of `read` in position order — shared with the
/// coordinator's streaming analysis stage so its incremental k-mer
/// index hashes exactly like `find_overlaps` (same `SEED_K`, same
/// rolling encode), which is what keeps the two overlap graphs
/// identical.
pub(crate) fn seed_hashes(read: &[u8])
                          -> impl Iterator<Item = u64> + '_ {
    seeds(read).map(|(h, _)| h)
}

/// Find suffix-prefix overlaps of length >= `min_len` between all pairs.
///
/// Candidates come from a k-mer index (a seed of `a`'s tail matching a seed
/// of `b`'s head); each candidate pair is verified with the banded
/// suffix-prefix aligner of `basecall::vote` — the same "longest match"
/// primitive the SOT-MRAM comparator arrays accelerate.
pub fn find_overlaps(reads: &[Vec<u8>], min_len: usize) -> Vec<Overlap> {
    // index k-mers of every read head (first min_len*2 bases)
    let mut head_index: HashMap<u64, Vec<usize>> = HashMap::new();
    for (id, read) in reads.iter().enumerate() {
        let head = &read[..read.len().min(min_len * 2)];
        for (h, _) in seeds(head) {
            head_index.entry(h).or_default().push(id);
        }
    }
    let mut out = Vec::new();
    for (a, read) in reads.iter().enumerate() {
        if read.len() < min_len {
            continue;
        }
        let tail = &read[read.len() - read.len().min(min_len * 2)..];
        let mut cands: Vec<usize> = seeds(tail)
            .filter_map(|(h, _)| head_index.get(&h))
            .flatten()
            .copied()
            .filter(|&b| b != a)
            .collect();
        cands.sort_unstable();
        cands.dedup();
        for b in cands {
            if let Some(len) = best_overlap(read, &reads[b], min_len) {
                out.push(Overlap { a, b, len });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn shredded(genome_len: usize, read_len: usize, step: usize, seed: u64)
                -> (Vec<u8>, Vec<Vec<u8>>) {
        let mut rng = Rng::new(seed);
        let genome: Vec<u8> = (0..genome_len).map(|_| rng.base()).collect();
        let mut reads = Vec::new();
        let mut s = 0;
        while s + read_len <= genome.len() {
            reads.push(genome[s..s + read_len].to_vec());
            s += step;
        }
        (genome, reads)
    }

    #[test]
    fn finds_consecutive_overlaps() {
        let (_, reads) = shredded(400, 60, 30, 1);
        let ovl = find_overlaps(&reads, 15);
        // every consecutive pair overlaps by 30
        for i in 0..reads.len() - 1 {
            assert!(ovl.iter().any(|o| o.a == i && o.b == i + 1
                                       && o.len >= 25),
                    "missing overlap {i}->{}", i + 1);
        }
    }

    #[test]
    fn no_overlaps_between_unrelated_reads() {
        let mut rng = Rng::new(2);
        let r1: Vec<u8> = (0..80).map(|_| rng.base()).collect();
        let r2: Vec<u8> = (0..80).map(|_| rng.base()).collect();
        let ovl = find_overlaps(&[r1, r2], 20);
        assert!(ovl.is_empty(), "{ovl:?}");
    }

    #[test]
    fn zero_length_and_short_reads_are_skipped_not_panicked() {
        let mut rng = Rng::new(5);
        let real: Vec<u8> = (0..80).map(|_| rng.base()).collect();
        let reads = vec![Vec::new(), real.clone(), vec![1u8, 2, 3],
                         real.clone()];
        let ovl = find_overlaps(&reads, 20);
        // the empty and sub-min_len reads appear in no edge; the two
        // identical full reads overlap both ways
        assert!(ovl.iter().all(|o| o.a != 0 && o.b != 0
                               && o.a != 2 && o.b != 2), "{ovl:?}");
        assert!(ovl.contains(&Overlap { a: 1, b: 3, len: 80 }));
        assert!(ovl.contains(&Overlap { a: 3, b: 1, len: 80 }));
        // degenerate whole-input shapes
        assert!(find_overlaps(&[], 10).is_empty());
        assert!(find_overlaps(&[Vec::new()], 10).is_empty());
    }

    #[test]
    fn single_read_has_no_self_overlap() {
        let mut rng = Rng::new(6);
        let read: Vec<u8> = (0..100).map(|_| rng.base()).collect();
        assert!(find_overlaps(&[read], 20).is_empty(),
                "a read must never overlap itself");
    }

    #[test]
    fn identical_reads_overlap_pairwise_in_canonical_order() {
        let mut rng = Rng::new(7);
        let read: Vec<u8> = (0..60).map(|_| rng.base()).collect();
        let reads = vec![read.clone(), read.clone(), read.clone()];
        let ovl = find_overlaps(&reads, 15);
        // every ordered pair, full length, grouped by a then b — the
        // canonical order the streaming assembler reproduces
        let expect: Vec<Overlap> = [(0, 1), (0, 2), (1, 0), (1, 2),
                                    (2, 0), (2, 1)]
            .iter()
            .map(|&(a, b)| Overlap { a, b, len: 60 })
            .collect();
        assert_eq!(ovl, expect);
    }

    #[test]
    fn no_overlap_above_threshold_yields_empty_graph() {
        // consecutive reads DO overlap by 20, but min_len 40 must
        // reject every candidate pair
        let (_, reads) = shredded(400, 60, 40, 8);
        assert!(find_overlaps(&reads, 40).is_empty());
        // and lowering the bar back down finds them again
        assert!(!find_overlaps(&reads, 15).is_empty());
    }

    #[test]
    fn tolerates_read_errors() {
        let (_, mut reads) = shredded(300, 60, 30, 3);
        // corrupt ~5% of bases
        let mut rng = Rng::new(4);
        for r in reads.iter_mut() {
            for _ in 0..3 {
                let i = rng.below(r.len());
                r[i] = (r[i] + 1) % 4;
            }
        }
        let ovl = find_overlaps(&reads, 15);
        let consecutive = (0..reads.len() - 1)
            .filter(|&i| ovl.iter().any(|o| o.a == i && o.b == i + 1))
            .count();
        assert!(consecutive >= reads.len() - 2,
                "{consecutive}/{}", reads.len() - 1);
    }
}
