//! Draft assembly (Fig 1 stage 3): traverse the overlap graph along its
//! best path and merge reads into a draft contig.

use super::overlap::{find_overlaps, Overlap};

/// Assemble reads into a draft contig. Greedy unitig layout: start from the
/// read with no good incoming overlap, repeatedly follow the longest
/// outgoing overlap, splicing each read's non-overlapping suffix.
pub fn assemble(reads: &[Vec<u8>], min_overlap: usize) -> Vec<u8> {
    if reads.is_empty() {
        return Vec::new();
    }
    let overlaps = find_overlaps(reads, min_overlap);
    assemble_with_overlaps(reads, &overlaps)
}

/// Assembly from precomputed overlaps (lets benches separate the stages).
pub fn assemble_with_overlaps(reads: &[Vec<u8>], overlaps: &[Overlap])
                              -> Vec<u8> {
    let n = reads.len();
    let mut best_out: Vec<Option<Overlap>> = vec![None; n];
    let mut has_in = vec![false; n];
    for o in overlaps {
        if best_out[o.a].map_or(true, |b| o.len > b.len) {
            best_out[o.a] = Some(*o);
        }
    }
    for o in overlaps {
        // mark incoming only for edges that will actually be followed
        if best_out[o.a] == Some(*o) {
            has_in[o.b] = true;
        }
    }
    // start: longest read without an incoming best-edge
    let start = (0..n)
        .filter(|&i| !has_in[i])
        .max_by_key(|&i| reads[i].len())
        .unwrap_or(0);
    let mut contig = reads[start].clone();
    let mut visited = vec![false; n];
    visited[start] = true;
    let mut cur = start;
    while let Some(o) = best_out[cur] {
        if visited[o.b] {
            break;
        }
        contig.extend_from_slice(&reads[o.b][o.len.min(reads[o.b].len())..]);
        visited[o.b] = true;
        cur = o.b;
    }
    contig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basecall::edit::identity;
    use crate::util::rng::Rng;

    fn shred(genome: &[u8], read_len: usize, step: usize) -> Vec<Vec<u8>> {
        let mut reads = Vec::new();
        let mut s = 0;
        while s + read_len <= genome.len() {
            reads.push(genome[s..s + read_len].to_vec());
            s += step;
        }
        reads
    }

    #[test]
    fn perfect_reads_reassemble_exactly() {
        let mut rng = Rng::new(5);
        let genome: Vec<u8> = (0..500).map(|_| rng.base()).collect();
        let reads = shred(&genome, 80, 40);
        let draft = assemble(&reads, 20);
        // tail may be truncated by read granularity; compare covered prefix
        let covered = 80 + (reads.len() - 1) * 40;
        assert_eq!(&draft[..], &genome[..covered]);
    }

    #[test]
    fn noisy_reads_assemble_to_high_identity() {
        let mut rng = Rng::new(6);
        let genome: Vec<u8> = (0..600).map(|_| rng.base()).collect();
        let mut reads = shred(&genome, 90, 45);
        for r in reads.iter_mut() {
            for _ in 0..4 {
                let i = rng.below(r.len());
                r[i] = (r[i] + 1) % 4;
            }
        }
        let draft = assemble(&reads, 20);
        let id = identity(&draft, &genome[..draft.len().min(genome.len())]);
        assert!(id > 0.9, "draft identity {id}");
    }

    #[test]
    fn empty_and_single() {
        assert!(assemble(&[], 10).is_empty());
        let one = vec![vec![0u8, 1, 2, 3]];
        assert_eq!(assemble(&one, 2), one[0]);
    }
}
