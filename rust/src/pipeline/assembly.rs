//! Draft assembly (Fig 1 stage 3): traverse the overlap graph along its
//! best path and merge reads into a draft contig.

use super::overlap::{find_overlaps, Overlap};

/// Assemble reads into a draft contig. Greedy unitig layout: start from the
/// read with no good incoming overlap, repeatedly follow the longest
/// outgoing overlap, splicing each read's non-overlapping suffix.
pub fn assemble(reads: &[Vec<u8>], min_overlap: usize) -> Vec<u8> {
    if reads.is_empty() {
        return Vec::new();
    }
    let overlaps = find_overlaps(reads, min_overlap);
    assemble_with_overlaps(reads, &overlaps)
}

/// Assembly from precomputed overlaps (lets benches separate the stages).
pub fn assemble_with_overlaps(reads: &[Vec<u8>], overlaps: &[Overlap])
                              -> Vec<u8> {
    let n = reads.len();
    let mut best_out: Vec<Option<Overlap>> = vec![None; n];
    let mut has_in = vec![false; n];
    for o in overlaps {
        if best_out[o.a].map_or(true, |b| o.len > b.len) {
            best_out[o.a] = Some(*o);
        }
    }
    for o in overlaps {
        // mark incoming only for edges that will actually be followed
        if best_out[o.a] == Some(*o) {
            has_in[o.b] = true;
        }
    }
    // start: longest read without an incoming best-edge
    let start = (0..n)
        .filter(|&i| !has_in[i])
        .max_by_key(|&i| reads[i].len())
        .unwrap_or(0);
    let mut contig = reads[start].clone();
    let mut visited = vec![false; n];
    visited[start] = true;
    let mut cur = start;
    while let Some(o) = best_out[cur] {
        if visited[o.b] {
            break;
        }
        contig.extend_from_slice(&reads[o.b][o.len.min(reads[o.b].len())..]);
        visited[o.b] = true;
        cur = o.b;
    }
    contig
}

/// Assemble ALL reads into contigs: the same greedy unitig walk as
/// [`assemble_with_overlaps`], repeated until every read is placed.
/// Reads with no overlap above threshold come out as singleton contigs
/// instead of silently disappearing (the first contig is exactly what
/// `assemble` returns). Contigs are ordered by the walk: path heads
/// (no incoming best-edge) longest-first, then any leftover cycle
/// members longest-first.
pub fn assemble_contigs(reads: &[Vec<u8>], min_overlap: usize)
                        -> Vec<Vec<u8>> {
    if reads.is_empty() {
        return Vec::new();
    }
    let overlaps = find_overlaps(reads, min_overlap);
    assemble_contigs_with_overlaps(reads, &overlaps)
}

/// Multi-contig assembly from precomputed overlaps (see
/// [`assemble_contigs`]).
pub fn assemble_contigs_with_overlaps(reads: &[Vec<u8>],
                                      overlaps: &[Overlap])
                                      -> Vec<Vec<u8>> {
    let n = reads.len();
    let mut best_out: Vec<Option<Overlap>> = vec![None; n];
    let mut has_in = vec![false; n];
    for o in overlaps {
        if best_out[o.a].map_or(true, |b| o.len > b.len) {
            best_out[o.a] = Some(*o);
        }
    }
    for o in overlaps {
        if best_out[o.a] == Some(*o) {
            has_in[o.b] = true;
        }
    }
    let mut visited = vec![false; n];
    let mut contigs = Vec::new();
    loop {
        // same start rule as the single-contig walk, restricted to
        // unplaced reads; once no path head is left, break cycles by
        // taking the longest unplaced read
        let start = (0..n)
            .filter(|&i| !visited[i] && !has_in[i])
            .max_by_key(|&i| reads[i].len())
            .or_else(|| (0..n)
                .filter(|&i| !visited[i])
                .max_by_key(|&i| reads[i].len()));
        let Some(start) = start else { break };
        let mut contig = reads[start].clone();
        visited[start] = true;
        let mut cur = start;
        while let Some(o) = best_out[cur] {
            if visited[o.b] {
                break;
            }
            contig.extend_from_slice(
                &reads[o.b][o.len.min(reads[o.b].len())..]);
            visited[o.b] = true;
            cur = o.b;
        }
        contigs.push(contig);
    }
    contigs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basecall::edit::identity;
    use crate::util::rng::Rng;

    fn shred(genome: &[u8], read_len: usize, step: usize) -> Vec<Vec<u8>> {
        let mut reads = Vec::new();
        let mut s = 0;
        while s + read_len <= genome.len() {
            reads.push(genome[s..s + read_len].to_vec());
            s += step;
        }
        reads
    }

    #[test]
    fn perfect_reads_reassemble_exactly() {
        let mut rng = Rng::new(5);
        let genome: Vec<u8> = (0..500).map(|_| rng.base()).collect();
        let reads = shred(&genome, 80, 40);
        let draft = assemble(&reads, 20);
        // tail may be truncated by read granularity; compare covered prefix
        let covered = 80 + (reads.len() - 1) * 40;
        assert_eq!(&draft[..], &genome[..covered]);
    }

    #[test]
    fn noisy_reads_assemble_to_high_identity() {
        let mut rng = Rng::new(6);
        let genome: Vec<u8> = (0..600).map(|_| rng.base()).collect();
        let mut reads = shred(&genome, 90, 45);
        for r in reads.iter_mut() {
            for _ in 0..4 {
                let i = rng.below(r.len());
                r[i] = (r[i] + 1) % 4;
            }
        }
        let draft = assemble(&reads, 20);
        let id = identity(&draft, &genome[..draft.len().min(genome.len())]);
        assert!(id > 0.9, "draft identity {id}");
    }

    #[test]
    fn empty_and_single() {
        assert!(assemble(&[], 10).is_empty());
        let one = vec![vec![0u8, 1, 2, 3]];
        assert_eq!(assemble(&one, 2), one[0]);
    }

    #[test]
    fn zero_length_read_does_not_panic() {
        // a read the rejection gate (or a hopeless decode) left empty
        // must flow through both assemblers without panicking
        let mut rng = Rng::new(7);
        let genome: Vec<u8> = (0..300).map(|_| rng.base()).collect();
        let mut reads = shred(&genome, 80, 40);
        reads.insert(1, Vec::new());
        let draft = assemble(&reads, 20);
        assert!(!draft.is_empty());
        let contigs = assemble_contigs(&reads, 20);
        // every read is placed: the empty read rides as a singleton
        assert!(contigs.iter().any(|c| c.is_empty()), "{contigs:?}");
        assert_eq!(contigs[0], draft);
        // all-empty input is also fine
        assert_eq!(assemble(&[Vec::new(), Vec::new()], 10), Vec::new());
        assert_eq!(assemble_contigs(&[Vec::new()], 10),
                   vec![Vec::new()]);
    }

    #[test]
    fn single_read_is_a_singleton_contig() {
        let one = vec![vec![3u8, 2, 1, 0, 3, 2]];
        assert_eq!(assemble_contigs(&one, 3), one);
        assert!(assemble_contigs(&[], 3).is_empty());
    }

    #[test]
    fn identical_reads_collapse_to_one_contig() {
        // all reads identical: full-length mutual overlaps, and the
        // walk must terminate (visited check) at one copy's length
        let mut rng = Rng::new(8);
        let read: Vec<u8> = (0..60).map(|_| rng.base()).collect();
        let reads = vec![read.clone(); 4];
        let draft = assemble(&reads, 20);
        assert_eq!(draft, read);
        let contigs = assemble_contigs(&reads, 20);
        assert!(!contigs.is_empty() && contigs.len() < reads.len(),
                "walks must merge at least one pair: {}", contigs.len());
        assert!(contigs.iter().all(|c| c == &read), "{contigs:?}");
    }

    #[test]
    fn disjoint_reads_emit_singleton_contigs() {
        // no overlap above threshold anywhere: the assembler must emit
        // one singleton contig per read, not panic or drop reads
        let mut rng = Rng::new(9);
        let reads: Vec<Vec<u8>> = (0..3)
            .map(|_| (0..50).map(|_| rng.base()).collect())
            .collect();
        let contigs = assemble_contigs(&reads, 25);
        assert_eq!(contigs.len(), reads.len());
        let mut sorted = contigs.clone();
        sorted.sort();
        let mut expect = reads.clone();
        expect.sort();
        assert_eq!(sorted, expect, "every read survives as-is");
        // the single-contig entry point returns the longest read
        assert_eq!(assemble(&reads, 25).len(), 50);
    }
}
