//! Polishing (Fig 1 stage 5): column-wise majority vote of mapped reads
//! over the draft (a racon-style pileup consensus, simplified).

use crate::basecall::vote::align_onto;

use super::mapping::{map_read, DraftIndex};

/// Polish the draft with the read pileup: every mapped read votes on the
/// draft positions it aligns to; majority wins (ties keep the draft base).
pub fn polish(draft: &[u8], reads: &[Vec<u8>]) -> Vec<u8> {
    if draft.is_empty() {
        return Vec::new();
    }
    let idx = DraftIndex::build(draft);
    let mut votes = vec![[0u32; 4]; draft.len()];
    for (i, &b) in draft.iter().enumerate() {
        votes[i][b as usize] += 1;
    }
    for read in reads {
        if let Some(m) = map_read(read, draft, &idx) {
            let interval = &draft[m.pos..m.pos + m.len];
            for (k, sym) in align_onto(interval, read).into_iter().enumerate()
            {
                if let Some(s) = sym {
                    if s < 4 {
                        votes[m.pos + k][s as usize] += 1;
                    }
                }
            }
        }
    }
    draft.iter()
        .enumerate()
        .map(|(i, &orig)| {
            let v = &votes[i];
            let (mut best, mut cnt) = (orig as usize, v[orig as usize]);
            for (s, &c) in v.iter().enumerate() {
                if c > cnt {
                    best = s;
                    cnt = c;
                }
            }
            best as u8
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basecall::edit::identity;
    use crate::util::rng::Rng;

    #[test]
    fn polishing_fixes_draft_errors() {
        let mut rng = Rng::new(11);
        let genome: Vec<u8> = (0..400).map(|_| rng.base()).collect();
        // draft with scattered errors
        let mut draft = genome.clone();
        for _ in 0..20 {
            let i = rng.below(draft.len());
            draft[i] = (draft[i] + 1) % 4;
        }
        // clean overlapping reads
        let mut reads = Vec::new();
        let mut s = 0;
        while s + 80 <= genome.len() {
            reads.push(genome[s..s + 80].to_vec());
            s += 20;
        }
        let polished = polish(&draft, &reads);
        let before = identity(&draft, &genome);
        let after = identity(&polished, &genome);
        assert!(after > before, "before {before} after {after}");
        assert!(after > 0.99, "after {after}");
    }

    #[test]
    fn polish_without_reads_is_identity() {
        let draft = vec![0u8, 1, 2, 3, 2, 1];
        assert_eq!(polish(&draft, &[]), draft);
    }

    #[test]
    fn systematic_read_errors_survive_polish() {
        // all reads share the same wrong base -> polishing keeps it wrong
        let mut rng = Rng::new(12);
        let genome: Vec<u8> = (0..200).map(|_| rng.base()).collect();
        let mut corrupt = genome.clone();
        corrupt[100] = (corrupt[100] + 1) % 4;
        let mut reads = Vec::new();
        let mut s = 0;
        while s + 60 <= corrupt.len() {
            reads.push(corrupt[s..s + 60].to_vec());
            s += 20;
        }
        let polished = polish(&genome, &reads); // draft correct here
        // majority of reads vote the systematic error INTO the draft
        assert_eq!(polished[100], corrupt[100]);
    }
}
