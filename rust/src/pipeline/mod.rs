//! Downstream nanopore sequencing pipeline (Fig 1): overlap finding,
//! assembly, read mapping, polishing — the consumers of base-called reads
//! that Fig 23 pushes quantized base-callers through ("base-call" ->
//! "draft" -> "polished" accuracy).

pub mod assembly;
pub mod mapping;
pub mod overlap;
pub mod polish;

pub use assembly::{assemble, assemble_contigs};
pub use mapping::map_read;
pub use overlap::find_overlaps;
pub use polish::polish;

/// The offline reads→polished-consensus entry point: greedy unitig
/// assembly of `reads` into a draft, then pileup-polish the draft with
/// the same reads. This is the reference the coordinator's streaming
/// analysis stage (`coordinator::analysis`) is byte-identity-pinned
/// against: same reads in the same order → identical bytes out.
pub fn consensus(reads: &[Vec<u8>], min_overlap: usize) -> Vec<u8> {
    let draft = assemble(reads, min_overlap);
    polish(&draft, reads)
}
