//! Downstream nanopore sequencing pipeline (Fig 1): overlap finding,
//! assembly, read mapping, polishing — the consumers of base-called reads
//! that Fig 23 pushes quantized base-callers through ("base-call" ->
//! "draft" -> "polished" accuracy).

pub mod assembly;
pub mod mapping;
pub mod overlap;
pub mod polish;

pub use assembly::assemble;
pub use mapping::map_read;
pub use overlap::find_overlaps;
pub use polish::polish;
