//! helix-lint: in-tree source scanner for the crate's known concurrency
//! and float-ordering footguns (on-brand with `util::json` — no syn, no
//! regex crate, just a small line scanner with a string/comment state
//! machine). Run by `./ci.sh check` over `rust/src`; hard-fails CI on
//! any finding.
//!
//! Rules (each scoped to NON-test code — `#[cfg(test)]` regions are
//! tracked by brace depth and skipped):
//!
//! * `float-partial-cmp-unwrap` — `partial_cmp(..).unwrap()`: panics on
//!   NaN; use `f64::total_cmp`.
//! * `mpsc` — any `sync::mpsc` use: the pipeline's channel vocabulary
//!   is `util::bounded` (backpressure + introspection + the model-check
//!   shim); mpsc bypasses all three.
//! * `thread-spawn` — bare `thread::spawn(` outside the whitelisted
//!   pool/backend modules: ad-hoc threads dodge pool lifecycle,
//!   shutdown draining, and the `util::check` scheduler.
//! * `channel-unwrap` — `.unwrap()` directly on a channel
//!   `send`/`recv`/`try_recv`/`recv_timeout` result in production
//!   code: disconnects are expected lifecycle events, not bugs.
//! * `instant-now-in-tick` — `Instant::now()` inside the autoscale
//!   controller: tick logic must flow through `SampleClock` so the
//!   control loop stays deterministic under test.
//!
//! A finding can be waived where genuinely intended with a trailing or
//! preceding comment: `// helix-lint: allow(rule-name)`.
//!
//! `helix_lint --self-test` runs the scanner over embedded fixture
//! snippets (each rule must fire on its bad fixture and stay quiet on
//! its good twin) and exits non-zero on any miss — wired into
//! `./ci.sh check` ahead of the real scan.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules allowed to call `thread::spawn` directly: the worker pools
/// and serving back-ends that own thread lifecycle, plus the model
/// scheduler itself.
const SPAWN_WHITELIST: &[&str] = &[
    "coordinator/pool.rs",
    "coordinator/dispatch.rs",
    "coordinator/server.rs",
    "coordinator/collector.rs",
    "coordinator/analysis.rs",
    "coordinator/net/mod.rs",
    "util/check.rs",
];

/// Files whose control-tick logic must use the sampled clock.
const TICK_FILES: &[&str] = &["coordinator/autoscale.rs"];

struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    message: String,
}

/// Strip comments and neutralize string/char literals so pattern and
/// brace scanning cannot be fooled by `"{"`, `"// not a comment"`, or
/// doc text. Returns one stripped line per input line (block comments
/// and multi-line strings keep the line structure).
fn strip_source(src: &str) -> Vec<String> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        Char,
    }
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut st = St::Code;
    let chars: Vec<char> = src.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            out.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => match (c, next) {
                ('/', Some('/')) => st = St::LineComment,
                ('/', Some('*')) => {
                    st = St::BlockComment(1);
                    i += 1;
                }
                ('r', Some('"')) | ('r', Some('#')) => {
                    // raw string: count the # fence
                    let mut hashes = 0;
                    let mut j = i + 1;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        st = St::RawStr(hashes);
                        cur.push('"');
                        i = j + 1;
                        continue;
                    }
                    cur.push(c);
                }
                ('"', _) => {
                    st = St::Str;
                    cur.push('"');
                }
                ('\'', _) => {
                    // char literal vs lifetime: a literal is exactly
                    // 'x', or starts with an escape ('\n', '\u{..}').
                    // Anything else ('a in generics, &'a borrows) is a
                    // lifetime — scanning ahead for a closing quote
                    // would mis-eat `<'a>(x: &'a T)` as one literal.
                    if next == Some('\\') {
                        st = St::Char;
                        cur.push('\'');
                    } else if next.is_some()
                        && chars.get(i + 2) == Some(&'\'')
                    {
                        cur.push('\'');
                        cur.push('\'');
                        i += 3;
                        continue;
                    } else {
                        cur.push('\''); // lifetime: keep, no state
                    }
                }
                _ => cur.push(c),
            },
            St::LineComment => {}
            St::BlockComment(d) => match (c, next) {
                ('*', Some('/')) => {
                    st = if d == 1 {
                        St::Code
                    } else {
                        St::BlockComment(d - 1)
                    };
                    i += 1;
                }
                ('/', Some('*')) => {
                    st = St::BlockComment(d + 1);
                    i += 1;
                }
                _ => {}
            },
            St::Str => match (c, next) {
                ('\\', Some(_)) => i += 1,
                ('"', _) => {
                    st = St::Code;
                    cur.push('"');
                }
                _ => {}
            },
            St::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes {
                        if chars.get(i + 1 + k as usize) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        st = St::Code;
                        cur.push('"');
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
            }
            St::Char => {
                if c == '\\' {
                    i += 1;
                } else if c == '\'' {
                    st = St::Code;
                    cur.push('\'');
                }
            }
        }
        i += 1;
    }
    if !cur.is_empty() || st == St::LineComment {
        out.push(cur);
    }
    out
}

/// True when `win` contains `pat` starting before `line_len` (i.e. on
/// the current line, not the lookahead line) followed by `.unwrap()`
/// with no statement boundary (`;`) in between.
fn call_then_unwrap(win: &str, line_len: usize, pat: &str) -> bool {
    let mut from = 0;
    while let Some(rel) = win[from..].find(pat) {
        if from + rel >= line_len {
            return false;
        }
        let start = from + rel + pat.len();
        if let Some(u) = win[start..].find(".unwrap()") {
            if !win[start..start + u].contains(';') {
                return true;
            }
        }
        from = start;
    }
    false
}

fn relpath(path: &Path) -> String {
    path.to_string_lossy().replace('\\', "/")
}

fn scan_file(path: &Path, src: &str, findings: &mut Vec<Finding>) {
    let rel = relpath(path);
    let raw_lines: Vec<&str> = src.lines().collect();
    let stripped = strip_source(src);
    let is_tick_file = TICK_FILES.iter().any(|f| rel.ends_with(f));
    let spawn_ok = SPAWN_WHITELIST.iter().any(|f| rel.ends_with(f));

    let mut depth: i64 = 0;
    let mut pending_test = false;
    let mut test_end: Option<i64> = None;

    let push = |findings: &mut Vec<Finding>, idx: usize,
                rule: &'static str, message: String| {
        // waiver: `helix-lint: allow(rule)` on this or the previous
        // raw line (comments are stripped from the scan lines, so
        // look at the raw source)
        let waived = [idx, idx.saturating_sub(1)].iter().any(|&i| {
            raw_lines.get(i).is_some_and(|l| {
                l.contains("helix-lint: allow(")
                    && l.contains(rule)
            })
        });
        if !waived {
            findings.push(Finding {
                file: rel.clone(),
                line: idx + 1,
                rule,
                message,
            });
        }
    };

    for (idx, line) in stripped.iter().enumerate() {
        if test_end.is_none()
            && (line.contains("#[cfg(test)]")
                || line.contains("#[cfg(all(test"))
        {
            pending_test = true;
        }
        if test_end.is_none() && pending_test && line.contains('{') {
            test_end = Some(depth);
            pending_test = false;
        }
        let in_test = test_end.is_some();

        if !in_test {
            // two-line window so a call split across a line break is
            // still seen as one expression
            let mut win = line.clone();
            if let Some(nl) = stripped.get(idx + 1) {
                win.push(' ');
                win.push_str(nl);
            }
            if call_then_unwrap(&win, line.len(), "partial_cmp") {
                push(findings, idx, "float-partial-cmp-unwrap",
                     "partial_cmp(..).unwrap() panics on NaN; use \
                      f64::total_cmp".to_string());
            }
            if line.contains("sync::mpsc") {
                push(findings, idx, "mpsc",
                     "std::sync::mpsc is banned; use util::bounded \
                      (backpressure + model-check shim)".to_string());
            }
            if line.contains("thread::spawn(") && !spawn_ok {
                push(findings, idx, "thread-spawn",
                     "bare thread::spawn outside the pool/backend \
                      whitelist; route threads through a pool or \
                      whitelist the module".to_string());
            }
            for pat in [".send(", ".recv()", ".try_recv()",
                        ".recv_timeout("] {
                if call_then_unwrap(&win, line.len(), pat) {
                    push(findings, idx, "channel-unwrap",
                         format!("{pat}..).unwrap() in production \
                                  code: channel disconnects are \
                                  lifecycle events, handle the Err"));
                    break;
                }
            }
            if is_tick_file && line.contains("Instant::now()") {
                push(findings, idx, "instant-now-in-tick",
                     "controller tick logic must read time through \
                      SampleClock, not Instant::now()".to_string());
            }
        }

        for ch in line.chars() {
            match ch {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        if let Some(end) = test_end {
            if depth <= end {
                test_end = None;
            }
        }
    }
}

fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn scan_roots(roots: &[PathBuf]) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    for root in roots {
        collect_rs(root, &mut files)
            .map_err(|e| format!("helix-lint: cannot read {}: {e}",
                                 root.display()))?;
    }
    if files.is_empty() {
        return Err(format!("helix-lint: no .rs files under {roots:?}"));
    }
    let mut findings = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)
            .map_err(|e| format!("helix-lint: cannot read {}: {e}",
                                 f.display()))?;
        scan_file(f, &src, &mut findings);
    }
    Ok(findings)
}

/// (fixture name, source, rule that must fire — or None for clean)
const FIXTURES: &[(&str, &str, Option<&str>)] = &[
    ("bad_partial_cmp.rs",
     "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| \
      a.partial_cmp(b).unwrap());\n}\n",
     Some("float-partial-cmp-unwrap")),
    ("bad_partial_cmp_split.rs",
     "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b)\n\
      \x20       .unwrap());\n}\n",
     Some("float-partial-cmp-unwrap")),
    ("good_total_cmp.rs",
     "fn f(v: &mut Vec<f64>) {\n    v.sort_by(f64::total_cmp);\n}\n",
     None),
    ("bad_mpsc.rs",
     "use std::sync::mpsc;\nfn f() { let (_t, _r) = mpsc::channel::\
      <u8>(); }\n",
     Some("mpsc")),
    ("good_mpsc_comment.rs",
     "//! we use util::bounded instead of std::sync::mpsc here\n\
      fn f() {}\n",
     None),
    ("bad_spawn.rs",
     "fn f() {\n    std::thread::spawn(|| {});\n}\n",
     Some("thread-spawn")),
    ("good_spawn_in_test.rs",
     "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
      std::thread::spawn(|| {});\n    }\n}\n",
     None),
    ("bad_channel_unwrap.rs",
     "fn f(tx: &Sender<u8>) {\n    tx.send(1).unwrap();\n}\n",
     Some("channel-unwrap")),
    ("good_channel_handled.rs",
     "fn f(tx: &Sender<u8>) {\n    let _ = tx.send(1);\n    \
      other.unwrap();\n}\n",
     None),
    ("good_lock_unwrap.rs",
     "fn f(m: &Mutex<u8>) {\n    *m.lock().unwrap() += 1;\n}\n",
     None),
    ("coordinator/autoscale.rs",
     "fn tick() {\n    let _now = Instant::now();\n}\n",
     Some("instant-now-in-tick")),
    ("good_waiver.rs",
     "fn f(tx: &Sender<u8>) {\n    // helix-lint: allow(channel-unwrap)\
      \n    tx.send(1).unwrap();\n}\n",
     None),
    ("good_lifetimes.rs",
     "fn wait<'a>(core: &'a Core, g: Guard<'a, T>) -> Guard<'a, T> \
      {\n    let _c = '{';\n    let _d = '\\n';\n    g\n}\n",
     None),
    ("good_string_brace.rs",
     "fn f() {\n    let _s = \"not a // comment, and a { brace\";\n}\n\
      #[cfg(test)]\nmod tests {\n    fn t(tx: &Sender<u8>) { \
      tx.send(1).unwrap(); }\n}\n",
     None),
];

fn self_test() -> Result<(), String> {
    let mut errors = String::new();
    for (name, src, expect) in FIXTURES {
        let mut findings = Vec::new();
        scan_file(Path::new(name), src, &mut findings);
        match expect {
            Some(rule) => {
                if !findings.iter().any(|f| f.rule == *rule) {
                    let _ = writeln!(
                        errors,
                        "fixture {name}: expected rule '{rule}' to \
                         fire, got {:?}",
                        findings.iter().map(|f| f.rule)
                            .collect::<Vec<_>>());
                }
            }
            None => {
                if !findings.is_empty() {
                    let _ = writeln!(
                        errors,
                        "fixture {name}: expected clean, got {:?}",
                        findings.iter()
                            .map(|f| (f.rule, f.line))
                            .collect::<Vec<_>>());
                }
            }
        }
    }
    if errors.is_empty() {
        println!("helix-lint: self-test OK ({} fixtures)",
                 FIXTURES.len());
        Ok(())
    } else {
        Err(errors)
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--self-test") {
        return match self_test() {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                eprintln!("helix-lint: self-test FAILED\n{e}");
                ExitCode::FAILURE
            }
        };
    }
    let roots: Vec<PathBuf> = if args.is_empty() {
        vec![PathBuf::from("src")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };
    match scan_roots(&roots) {
        Ok(findings) if findings.is_empty() => {
            println!("helix-lint: OK ({} rule(s), clean tree)", 5);
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                eprintln!("{}:{}: [{}] {}", f.file, f.line, f.rule,
                          f.message);
            }
            eprintln!("helix-lint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}
