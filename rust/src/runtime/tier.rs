//! Tiered model serving: a fast (low-bit) / hq (full-precision) model
//! pair drawn from one artifact ladder.
//!
//! The quantization sweep (`pim::schemes`, realized in-tree as the
//! native backend's per-bit-width `QuantModel`s) exports the *same*
//! model family at several bit-widths. A [`TierSet`] picks two rungs of
//! that ladder — the configured `bits` as the **hq** tier and a
//! lower-precision rung as the **fast** tier — so the coordinator can
//! route every window through the cheap model first and escalate only
//! the low-confidence ones to the expensive one (RUBICON-style
//! speculative serving). Both tiers come from the *same*
//! `ShardFactory`: a native backend replica holds every exported
//! bit-width and `warm(model, bits)` selects one, so a tier pool costs
//! exactly what a same-size single-tier pool costs.

use anyhow::Result;

use super::meta::Meta;

/// Which model tier a window is routed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// the low-bit speculative tier every fresh window runs through
    /// when tiered serving is on.
    Fast,
    /// the full-precision tier: the only tier of an untiered pipeline,
    /// and the escalation target of a tiered one.
    Hq,
}

impl Tier {
    /// Stable lowercase name for logs and the bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Fast => "fast",
            Tier::Hq => "hq",
        }
    }
}

/// Preferred fast-tier bit-width when the operator does not pick one:
/// the classic int8 rung balances speed against escalation rate.
const PREFERRED_FAST_BITS: u32 = 8;

/// A fast/hq model pair resolved against an artifact ladder.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TierSet {
    /// model family both tiers execute.
    pub model: String,
    /// bit-width of the speculative fast tier (strictly below
    /// `hq_bits`).
    pub fast_bits: u32,
    /// bit-width of the full-precision hq tier (the pipeline's
    /// configured `bits`).
    pub hq_bits: u32,
}

impl TierSet {
    /// Resolve a tier pair from the artifact ladder: `hq_bits` is the
    /// configured model width, and the fast tier is `fast_override`
    /// when given (it must exist in the ladder and sit strictly below
    /// `hq_bits`) or else auto-picked — the preferred
    /// [`PREFERRED_FAST_BITS`] rung when the ladder exports it below
    /// `hq_bits`, otherwise the *largest* exported width below
    /// `hq_bits` (closest precision, smallest accuracy gap). Errors
    /// when the ladder has no rung below `hq_bits` at all.
    pub fn from_meta(meta: &Meta, model: &str, hq_bits: u32,
                     fast_override: Option<u32>) -> Result<TierSet> {
        let mut ladder: Vec<u32> = meta.entries.iter()
            .filter(|e| e.model == model)
            .map(|e| e.bits)
            .collect();
        ladder.sort_unstable();
        ladder.dedup();
        anyhow::ensure!(ladder.contains(&hq_bits),
                        "no artifacts for {model}/{hq_bits}b");
        let fast_bits = match fast_override {
            Some(b) => {
                anyhow::ensure!(
                    ladder.contains(&b),
                    "no artifacts for {model}/{b}b (--tier-bits; ladder \
                     exports {ladder:?})");
                anyhow::ensure!(
                    b < hq_bits,
                    "--tier-bits {b} must be below the hq width \
                     {hq_bits} (the fast tier is the cheaper model)");
                b
            }
            None => {
                let below: Vec<u32> = ladder.iter().copied()
                    .filter(|&b| b < hq_bits)
                    .collect();
                let Some(b) = below.iter().copied()
                    .find(|&b| b == PREFERRED_FAST_BITS)
                    .or_else(|| below.last().copied())
                else {
                    anyhow::bail!(
                        "tiered serving needs a ladder rung below \
                         {hq_bits}b for {model}, but the artifacts only \
                         export {ladder:?}")
                };
                b
            }
        };
        Ok(TierSet {
            model: model.to_string(),
            fast_bits,
            hq_bits,
        })
    }

    /// Bit-width the given tier executes at.
    pub fn bits_for(&self, tier: Tier) -> u32 {
        match tier {
            Tier::Fast => self.fast_bits,
            Tier::Hq => self.hq_bits,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Backend, NativeBackend};

    fn builtin_meta() -> Meta {
        NativeBackend::builtin().meta().clone()
    }

    #[test]
    fn default_fast_tier_prefers_int8() {
        // builtin ladder: [5, 8, 16, 32]
        let ts = TierSet::from_meta(&builtin_meta(), "guppy", 32, None)
            .unwrap();
        assert_eq!(ts, TierSet {
            model: "guppy".into(),
            fast_bits: 8,
            hq_bits: 32,
        });
        assert_eq!(ts.bits_for(Tier::Fast), 8);
        assert_eq!(ts.bits_for(Tier::Hq), 32);
        // 8 also wins under a 16b hq tier
        let ts16 = TierSet::from_meta(&builtin_meta(), "guppy", 16, None)
            .unwrap();
        assert_eq!(ts16.fast_bits, 8);
    }

    #[test]
    fn default_falls_back_to_largest_rung_below_hq() {
        // hq = 8: the preferred 8b rung is not below it, so the fast
        // tier takes the largest remaining rung (5)
        let ts = TierSet::from_meta(&builtin_meta(), "guppy", 8, None)
            .unwrap();
        assert_eq!(ts.fast_bits, 5);
    }

    #[test]
    fn no_rung_below_hq_is_an_error() {
        let err = TierSet::from_meta(&builtin_meta(), "guppy", 5, None)
            .unwrap_err();
        assert!(err.to_string().contains("ladder rung below"),
                "{err}");
    }

    #[test]
    fn override_must_exist_and_sit_below_hq() {
        let meta = builtin_meta();
        let ts = TierSet::from_meta(&meta, "guppy", 32, Some(5)).unwrap();
        assert_eq!(ts.fast_bits, 5);
        // a rung the ladder does not export
        let err = TierSet::from_meta(&meta, "guppy", 32, Some(7))
            .unwrap_err();
        assert!(err.to_string().contains("no artifacts"), "{err}");
        // a rung at or above the hq width
        let err = TierSet::from_meta(&meta, "guppy", 16, Some(32))
            .unwrap_err();
        assert!(err.to_string().contains("below the hq width"), "{err}");
        let err = TierSet::from_meta(&meta, "guppy", 16, Some(16))
            .unwrap_err();
        assert!(err.to_string().contains("below the hq width"), "{err}");
    }

    #[test]
    fn unknown_model_is_an_error() {
        let err = TierSet::from_meta(&builtin_meta(), "nope", 32, None)
            .unwrap_err();
        assert!(err.to_string().contains("no artifacts"), "{err}");
    }

    #[test]
    fn tier_names_are_stable() {
        assert_eq!(Tier::Fast.name(), "fast");
        assert_eq!(Tier::Hq.name(), "hq");
    }
}
