//! Native inference backend: a pure-Rust quantized executor.
//!
//! The default `Backend` (no cargo features, no network, no pre-built
//! artifacts): a small deterministic base-caller DNN — int8/int16 conv +
//! matmul kernels whose bit-width semantics follow the PIM datapath
//! model (`pim::schemes::native_datapath_bits`: "32-bit" models execute
//! on the 16-bit fixed-point path, quantized ones at their own width) —
//! producing real, normalized `LogProbs` for the CTC decoders.
//!
//! Weights are generated from `util::rng` with a fixed seed, so every
//! build of the crate computes bit-identical outputs; `write_artifacts`
//! exports the same model through the `meta.json` artifact contract
//! (qmodel weight files + pore model), which is what `ci.sh bench` and
//! the examples materialize on first run.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use anyhow::{Context, Result};

use crate::basecall::ctc::LogProbs;
use crate::basecall::{BLANK, NUM_SYMBOLS};
use crate::genome::pore::PoreModel;
use crate::pim::schemes::native_datapath_bits;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::swar::{self, PackedMat};

use super::backend::Backend;
use super::meta::{artifacts_available, ArtifactEntry, Meta};

/// Seed base for the deterministic in-tree weights ("HELIX" << 8).
pub const NATIVE_SEED: u64 = 0x4845_4C49_5800;
/// Pore model seed shared with `PoreModel::synthetic` test usage.
const PORE_SEED: u64 = 7;
/// qmodel file format tag checked by the loader.
const QMODEL_FORMAT: &str = "helix-qmodel-v1";

/// One model family in a native artifact set.
#[derive(Clone, Debug)]
pub struct NativeModelSpec {
    /// model family name (e.g. "guppy").
    pub model: String,
    /// declared bit-widths to export (quantization follows
    /// `native_datapath_bits`).
    pub bits: Vec<u32>,
    /// batch sizes to expose in the meta (ascending).
    pub batches: Vec<usize>,
    /// input window length in samples.
    pub window: usize,
    /// conv kernel width.
    pub kernel: usize,
    /// conv stride (sets the CTC time-step count).
    pub stride: usize,
    /// conv channel count / matmul input width.
    pub hidden: usize,
}

impl NativeModelSpec {
    /// Spec with the default conv shape (kernel 12, stride 2, hidden
    /// 16) for the given family/bit-widths/batch-sizes/window.
    pub fn new(model: &str, bits: &[u32], batches: &[usize],
               window: usize) -> NativeModelSpec {
        NativeModelSpec {
            model: model.to_string(),
            bits: bits.to_vec(),
            batches: batches.to_vec(),
            window,
            kernel: 12,
            stride: 2,
            hidden: 16,
        }
    }

    fn time_steps(&self) -> usize {
        assert!(self.window > self.kernel && self.stride > 0,
                "window {} too small for kernel {}", self.window,
                self.kernel);
        (self.window - self.kernel) / self.stride + 1
    }
}

/// A full native artifact set (what the writer exports and the builtin
/// in-memory fallback instantiates).
#[derive(Clone, Debug)]
pub struct NativeSpec {
    /// weight-generation seed (`NATIVE_SEED` for the builtin).
    pub seed: u64,
    /// top-level default window recorded in meta.json.
    pub window: usize,
    /// the model families this artifact set exports.
    pub models: Vec<NativeModelSpec>,
}

impl NativeSpec {
    /// The in-tree default: one "guppy" family at the bit-widths the
    /// paper evaluates, batch sizes 1/8/32, window 300 → 145 CTC steps
    /// (the same shape the AOT export uses).
    pub fn builtin() -> NativeSpec {
        NativeSpec {
            seed: NATIVE_SEED,
            window: 300,
            models: vec![NativeModelSpec::new(
                "guppy", &[32, 16, 8, 5], &[1, 8, 32], 300)],
        }
    }

    /// The `Meta` this spec exposes — derivable without generating any
    /// weights (used by `BackendKind::probe_meta` for cheap
    /// caller-thread validation).
    pub fn meta(&self, root: &Path) -> Meta {
        let mut entries = Vec::new();
        for ms in &self.models {
            for &bits in &ms.bits {
                push_entries(&mut entries, ms, bits);
            }
        }
        Meta {
            root: root.to_path_buf(),
            window: self.window,
            entries,
        }
    }
}

/// Float weights as generated/exported (pre-quantization).
#[derive(Clone, Debug)]
struct RawModel {
    window: usize,
    time_steps: usize,
    hidden: usize,
    kernel: usize,
    stride: usize,
    /// conv filters, row-major `[hidden][kernel]` (in-channels = 1).
    conv_w: Vec<f32>,
    conv_b: Vec<f32>,
    /// output projection, row-major `[NUM_SYMBOLS][hidden]`.
    out_w: Vec<f32>,
    out_b: Vec<f32>,
}

fn model_seed(base: u64, model: &str, bits: u32) -> u64 {
    let mut h = base ^ (bits as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for b in model.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl RawModel {
    /// Deterministic weights for (model, bits). Different bit-widths get
    /// different weights — standing in for the per-width finetuned
    /// checkpoints of the AOT export — and the blank logit bias is
    /// pinned below every base logit bias so a degenerate input can
    /// never collapse the decode to the empty read.
    fn generate(spec: &NativeModelSpec, seed_base: u64, bits: u32)
                -> RawModel {
        let mut rng = Rng::new(model_seed(seed_base, &spec.model, bits));
        let hk = spec.hidden * spec.kernel;
        let wscale = 1.0 / (spec.kernel as f64).sqrt();
        let conv_w: Vec<f32> =
            (0..hk).map(|_| (rng.normal() * wscale) as f32).collect();
        let conv_b: Vec<f32> =
            (0..spec.hidden).map(|_| (rng.normal() * 0.1) as f32).collect();
        let oscale = 1.0 / (spec.hidden as f64).sqrt();
        let out_w: Vec<f32> = (0..NUM_SYMBOLS * spec.hidden)
            .map(|_| (rng.normal() * oscale) as f32)
            .collect();
        let mut out_b: Vec<f32> = (0..NUM_SYMBOLS)
            .map(|_| (rng.normal() * 0.2) as f32)
            .collect();
        let min_base = out_b[..BLANK].iter().cloned().fold(f32::MAX, f32::min);
        out_b[BLANK] = min_base - 2.0;
        RawModel {
            window: spec.window,
            time_steps: spec.time_steps(),
            hidden: spec.hidden,
            kernel: spec.kernel,
            stride: spec.stride,
            conv_w,
            conv_b,
            out_w,
            out_b,
        }
    }

    fn to_json(&self, model: &str, bits: u32) -> Json {
        let mut o = BTreeMap::new();
        o.insert("format".to_string(), Json::Str(QMODEL_FORMAT.into()));
        o.insert("model".to_string(), Json::Str(model.into()));
        o.insert("bits".to_string(), Json::Num(bits as f64));
        o.insert("window".to_string(), Json::Num(self.window as f64));
        o.insert("time_steps".to_string(),
                 Json::Num(self.time_steps as f64));
        o.insert("hidden".to_string(), Json::Num(self.hidden as f64));
        o.insert("kernel".to_string(), Json::Num(self.kernel as f64));
        o.insert("stride".to_string(), Json::Num(self.stride as f64));
        o.insert("conv_w".to_string(), jarr(&self.conv_w));
        o.insert("conv_b".to_string(), jarr(&self.conv_b));
        o.insert("out_w".to_string(), jarr(&self.out_w));
        o.insert("out_b".to_string(), jarr(&self.out_b));
        Json::Obj(o)
    }

    fn from_json(j: &Json) -> Result<RawModel> {
        let fmt = j.get("format").and_then(Json::as_str).unwrap_or("");
        anyhow::ensure!(fmt == QMODEL_FORMAT,
                        "not a native qmodel artifact (format '{fmt}')");
        let field = |k: &str| j.get(k).and_then(Json::as_usize)
            .with_context(|| format!("qmodel field {k}"));
        let arr = |k: &str| j.get(k).and_then(Json::as_f32_vec)
            .with_context(|| format!("qmodel field {k}"));
        let m = RawModel {
            window: field("window")?,
            time_steps: field("time_steps")?,
            hidden: field("hidden")?,
            kernel: field("kernel")?,
            stride: field("stride")?,
            conv_w: arr("conv_w")?,
            conv_b: arr("conv_b")?,
            out_w: arr("out_w")?,
            out_b: arr("out_b")?,
        };
        anyhow::ensure!(m.conv_w.len() == m.hidden * m.kernel
                        && m.conv_b.len() == m.hidden
                        && m.out_w.len() == NUM_SYMBOLS * m.hidden
                        && m.out_b.len() == NUM_SYMBOLS,
                        "qmodel weight shapes inconsistent");
        Ok(m)
    }
}

fn jarr(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// Symmetric per-tensor quantization: `w ≈ q * scale`, |q| <= qmax.
fn quantize(w: &[f32], qmax: i32) -> (Vec<i32>, f32) {
    let max = w.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-12);
    let scale = max / qmax as f32;
    let q = w.iter()
        .map(|&x| (x / scale).round()
             .clamp(-(qmax as f32), qmax as f32) as i32)
        .collect();
    (q, scale)
}

/// One (model, bits) executable: weights quantized to the datapath
/// width, run with integer accumulation. Carries both the scalar
/// weight rows (the bit-exactness oracle) and their SWAR packing
/// (the hot path).
#[derive(Clone)]
struct QuantModel {
    window: usize,
    time_steps: usize,
    hidden: usize,
    kernel: usize,
    stride: usize,
    conv_q: Vec<i32>,
    conv_scale: f32,
    conv_b: Vec<f32>,
    out_q: Vec<i32>,
    out_scale: f32,
    out_b: Vec<f32>,
    /// activation clamp from the datapath's activation bits.
    a_qmax: i32,
    /// conv filters packed into u64 SWAR lanes (rows = channels).
    conv_packed: PackedMat,
    /// output projection packed into u64 SWAR lanes (rows = symbols).
    out_packed: PackedMat,
}

/// Reusable per-backend scratch for the SWAR forward pass: quantize
/// output, hidden activations, and dot-product accumulators all live
/// here, so a steady-state batch allocates nothing but each window's
/// `LogProbs` payload. One `Scratch` per backend replica — shard
/// threads own their backend exclusively, so there is no contention.
#[derive(Clone, Default)]
struct Scratch {
    /// biased quantized input window (SWAR activations `q + a_qmax`).
    xb: Vec<u64>,
    /// ReLU'd conv activations, pre-requantization.
    hidden: Vec<f32>,
    /// biased quantized hidden activations.
    hb: Vec<u64>,
    /// per-row integer dot accumulators (conv channels / symbols).
    acc: Vec<i64>,
}

impl QuantModel {
    fn from_raw(raw: &RawModel, bits: u32) -> QuantModel {
        let (w_bits, a_bits) = native_datapath_bits(bits);
        let w_qmax = (1i32 << (w_bits - 1)) - 1;
        let a_qmax = (1i32 << (a_bits - 1)) - 1;
        let (conv_q, conv_scale) = quantize(&raw.conv_w, w_qmax);
        let (out_q, out_scale) = quantize(&raw.out_w, w_qmax);
        let conv_packed =
            PackedMat::pack(&conv_q, raw.hidden, raw.kernel, w_qmax,
                            a_qmax);
        let out_packed =
            PackedMat::pack(&out_q, NUM_SYMBOLS, raw.hidden, w_qmax,
                            a_qmax);
        QuantModel {
            window: raw.window,
            time_steps: raw.time_steps,
            hidden: raw.hidden,
            kernel: raw.kernel,
            stride: raw.stride,
            conv_q,
            conv_scale,
            conv_b: raw.conv_b.clone(),
            out_q,
            out_scale,
            out_b: raw.out_b.clone(),
            a_qmax,
            conv_packed,
            out_packed,
        }
    }

    /// SWAR forward: integer conv → ReLU → integer matmul →
    /// log-softmax, with every integer accumulator computed over
    /// u64-packed lanes (`runtime::swar`) and every intermediate
    /// buffer drawn from `scratch`. Bit-identical to
    /// [`QuantModel::forward_reference`]: the SWAR dot products
    /// reproduce the scalar i64 accumulators exactly, and the float
    /// expressions are evaluated in the same order. Activations are
    /// quantized per window (dynamic symmetric scale), so a window's
    /// output never depends on its batch neighbours.
    fn forward(&self, sig: &[f32], scratch: &mut Scratch) -> LogProbs {
        debug_assert_eq!(sig.len(), self.window);
        let sx = swar::quantize_biased(sig, self.a_qmax,
                                       &mut scratch.xb);
        scratch.hidden.clear();
        scratch.hidden.resize(self.time_steps * self.hidden, 0.0);
        scratch.acc.clear();
        scratch.acc.resize(self.hidden.max(NUM_SYMBOLS), 0);
        for t in 0..self.time_steps {
            let base = t * self.stride;
            let win = &scratch.xb[base..base + self.kernel];
            let xsum: i64 = win.iter().map(|&x| x as i64).sum();
            self.conv_packed.dot_into(win, xsum, &mut scratch.acc);
            let row = &mut scratch.hidden
                [t * self.hidden..(t + 1) * self.hidden];
            for (c, h) in row.iter_mut().enumerate() {
                let v = scratch.acc[c] as f32 * self.conv_scale * sx
                    + self.conv_b[c];
                *h = v.max(0.0);
            }
        }
        let sh = swar::quantize_biased(&scratch.hidden, self.a_qmax,
                                       &mut scratch.hb);
        let mut data = Vec::with_capacity(self.time_steps * NUM_SYMBOLS);
        for t in 0..self.time_steps {
            let row = &scratch.hb[t * self.hidden..(t + 1) * self.hidden];
            let hsum: i64 = row.iter().map(|&x| x as i64).sum();
            self.out_packed.dot_into(row, hsum, &mut scratch.acc);
            let mut logits = [0f32; NUM_SYMBOLS];
            for (s, logit) in logits.iter_mut().enumerate() {
                *logit = scratch.acc[s] as f32 * self.out_scale * sh
                    + self.out_b[s];
            }
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = m
                + logits.iter().map(|z| (z - m).exp()).sum::<f32>().ln();
            data.extend(logits.iter().map(|z| z - lse));
        }
        LogProbs::new(self.time_steps, data)
    }

    /// Scalar reference forward — the pre-SWAR implementation, kept
    /// verbatim as the bit-exactness oracle: property tests and the
    /// kernel bench pin `forward` against this, element for element,
    /// by `f32::to_bits`.
    fn forward_reference(&self, sig: &[f32]) -> LogProbs {
        debug_assert_eq!(sig.len(), self.window);
        let (qx, sx) = quantize(sig, self.a_qmax);
        let mut hidden = vec![0f32; self.time_steps * self.hidden];
        for t in 0..self.time_steps {
            let base = t * self.stride;
            for c in 0..self.hidden {
                let w = &self.conv_q[c * self.kernel..(c + 1) * self.kernel];
                let mut acc: i64 = 0;
                for (k, &wk) in w.iter().enumerate() {
                    acc += wk as i64 * qx[base + k] as i64;
                }
                let v = acc as f32 * self.conv_scale * sx + self.conv_b[c];
                hidden[t * self.hidden + c] = v.max(0.0);
            }
        }
        let (qh, sh) = quantize(&hidden, self.a_qmax);
        let mut data = Vec::with_capacity(self.time_steps * NUM_SYMBOLS);
        for t in 0..self.time_steps {
            let row = &qh[t * self.hidden..(t + 1) * self.hidden];
            let mut logits = [0f32; NUM_SYMBOLS];
            for (s, logit) in logits.iter_mut().enumerate() {
                let w = &self.out_q[s * self.hidden..(s + 1) * self.hidden];
                let mut acc: i64 = 0;
                for (c, &wc) in w.iter().enumerate() {
                    acc += wc as i64 * row[c] as i64;
                }
                *logit = acc as f32 * self.out_scale * sh + self.out_b[s];
            }
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = m
                + logits.iter().map(|z| (z - m).exp()).sum::<f32>().ln();
            data.extend(logits.iter().map(|z| z - lse));
        }
        LogProbs::new(self.time_steps, data)
    }
}

/// The native backend: artifact metadata + quantized executables keyed
/// by (model, bits). Plain data — `Send` and `Clone`, unlike the PJRT
/// client, so shard replicas can be stamped out in memory.
#[derive(Clone)]
pub struct NativeBackend {
    meta: Meta,
    models: HashMap<(String, u32), QuantModel>,
    /// per-backend scratch arena for the SWAR forward pass — reused
    /// across every window of every `run_batch`, so the steady-state
    /// batch path allocates nothing but the `LogProbs` payloads.
    scratch: Scratch,
}

impl NativeBackend {
    /// Load from an artifacts dir when `meta.json` exists there (it must
    /// be a native qmodel export), otherwise fall back to the builtin
    /// in-memory model — the zero-config path the coordinator uses when
    /// nothing has been materialized on disk.
    pub fn open(artifacts_dir: &str) -> Result<NativeBackend> {
        if artifacts_available(artifacts_dir) {
            NativeBackend::load(artifacts_dir)
        } else {
            Ok(NativeBackend::builtin())
        }
    }

    /// The zero-config in-memory backend (`NativeSpec::builtin`).
    pub fn builtin() -> NativeBackend {
        NativeBackend::from_spec(&NativeSpec::builtin())
    }

    /// Instantiate a spec fully in memory (no filesystem).
    pub fn from_spec(spec: &NativeSpec) -> NativeBackend {
        let mut models = HashMap::new();
        for ms in &spec.models {
            for &bits in &ms.bits {
                let raw = RawModel::generate(ms, spec.seed, bits);
                models.insert((ms.model.clone(), bits),
                              QuantModel::from_raw(&raw, bits));
            }
        }
        NativeBackend {
            meta: spec.meta(Path::new(".")),
            models,
            scratch: Scratch::default(),
        }
    }

    /// Scalar reference execution — the pre-SWAR forward pass, kept as
    /// the public bit-exactness oracle. `run_windows`/`run_batch` (the
    /// hot path) must produce byte-identical `LogProbs`; the property
    /// tests and `benches/basecall_hot.rs` assert exactly that, and
    /// the bench's `kernel_rows` report the SWAR speedup against this
    /// path.
    pub fn run_reference(&self, model: &str, bits: u32,
                         windows: &[Vec<f32>]) -> Result<Vec<LogProbs>> {
        let qm = self.models
            .get(&(model.to_string(), bits))
            .with_context(|| format!("no native model for \
                                      {model}/{bits}b"))?;
        let mut out = Vec::with_capacity(windows.len());
        for w in windows {
            anyhow::ensure!(w.len() == qm.window,
                            "window length {} != {}", w.len(),
                            qm.window);
            out.push(qm.forward_reference(w));
        }
        Ok(out)
    }

    /// Replicate this backend for another DNN shard: duplicates the
    /// already-quantized weights in memory, so a replica is cheaper
    /// than a fresh `open()` (no disk reads, no re-quantization) and
    /// guaranteed bit-identical — every shard computes the same
    /// `LogProbs` for the same window, which is what lets the
    /// coordinator promise shard-count-independent output. This is how
    /// the coordinator builds its native shard pool (one `open()`, N-1
    /// clones); non-`Send` backends go through the
    /// `BackendKind::open_shard` factory inside each shard thread
    /// instead.
    pub fn clone_for_shard(&self) -> NativeBackend {
        self.clone()
    }

    fn load(dir: &str) -> Result<NativeBackend> {
        let meta = Meta::load(dir)?;
        let mut models: HashMap<(String, u32), QuantModel> =
            HashMap::new();
        // validate EVERY entry (not just the first per (model, bits)):
        // conflicting metadata must fail here, at init, not surface as
        // a run_batch error deep in the DNN thread
        for e in &meta.entries {
            anyhow::ensure!(
                e.file.ends_with(".qmodel.json"),
                "artifact entry {} is '{}', not a native qmodel — these \
                 are HLO artifacts; build with `--features xla` and \
                 HELIX_BACKEND=xla, or regenerate native artifacts",
                e.name, e.file);
            let key = (e.model.clone(), e.bits);
            if !models.contains_key(&key) {
                let path = meta.path_of(e);
                let text = std::fs::read_to_string(&path)
                    .with_context(|| format!("reading {path:?}"))?;
                let j = Json::parse(&text).map_err(
                    |err| anyhow::anyhow!("parse {path:?}: {err}"))?;
                let raw = RawModel::from_json(&j)?;
                models.insert(key.clone(),
                              QuantModel::from_raw(&raw, e.bits));
            }
            let qm = &models[&key];
            anyhow::ensure!(qm.window == e.window
                            && qm.time_steps == e.time_steps,
                            "qmodel {} shape ({}, {}) disagrees with meta \
                             ({}, {})", e.name, qm.window, qm.time_steps,
                            e.window, e.time_steps);
        }
        Ok(NativeBackend { meta, models, scratch: Scratch::default() })
    }
}

fn qmodel_file(model: &str, bits: u32) -> String {
    format!("{model}_{bits}.qmodel.json")
}

fn push_entries(entries: &mut Vec<ArtifactEntry>, ms: &NativeModelSpec,
                bits: u32) {
    for &batch in &ms.batches {
        entries.push(ArtifactEntry {
            name: format!("{}_{}_b{}", ms.model, bits, batch),
            model: ms.model.clone(),
            bits,
            batch,
            window: ms.window,
            time_steps: ms.time_steps(),
            pallas: false,
            file: qmodel_file(&ms.model, bits),
        });
    }
}

impl Backend for NativeBackend {
    fn meta(&self) -> &Meta {
        &self.meta
    }

    fn warm(&mut self, model: &str, bits: u32) -> Result<()> {
        anyhow::ensure!(
            self.models.contains_key(&(model.to_string(), bits)),
            "no native model for {model}/{bits}b");
        Ok(())
    }

    fn run_batch(&mut self, entry: &ArtifactEntry, signals: &[&[f32]])
                 -> Result<Vec<LogProbs>> {
        anyhow::ensure!(signals.len() == entry.batch,
                        "batch mismatch: got {}, entry wants {}",
                        signals.len(), entry.batch);
        let qm = self.models
            .get(&(entry.model.clone(), entry.bits))
            .with_context(|| format!("no native model for {}/{}b",
                                     entry.model, entry.bits))?;
        anyhow::ensure!(qm.window == entry.window
                        && qm.time_steps == entry.time_steps,
                        "entry {} shape disagrees with loaded model",
                        entry.name);
        let w = entry.window;
        let mut out = Vec::with_capacity(signals.len());
        for s in signals {
            anyhow::ensure!(s.len() == w, "window length {} != {w}",
                            s.len());
            out.push(qm.forward(s, &mut self.scratch));
        }
        Ok(out)
    }
}

/// Export `spec` through the `meta.json` artifact contract: qmodel
/// weight files, `meta.json`, and a `pore_model.json` (so the synth /
/// example / bench paths that read the pore model from the artifacts
/// dir work without the python export). Overwrites deterministically.
pub fn write_artifacts(dir: &str, spec: &NativeSpec) -> Result<Meta> {
    let root = Path::new(dir);
    std::fs::create_dir_all(root)
        .with_context(|| format!("creating artifacts dir {dir}"))?;
    for ms in &spec.models {
        for &bits in &ms.bits {
            let raw = RawModel::generate(ms, spec.seed, bits);
            let path = root.join(qmodel_file(&ms.model, bits));
            std::fs::write(&path, raw.to_json(&ms.model, bits).to_string())
                .with_context(|| format!("writing {path:?}"))?;
        }
    }
    let meta = spec.meta(root);
    meta.save()?;
    let mut pm = PoreModel::synthetic(PORE_SEED);
    pm.window = spec.window;
    pm.save(meta.pore_model_path().to_str().context("pore path")?)?;
    Ok(meta)
}

/// Materialize the builtin native artifacts in `dir` unless a meta.json
/// (native or xla) is already there. Idempotent; returns the meta.
pub fn ensure_artifacts(dir: &str) -> Result<Meta> {
    if artifacts_available(dir) {
        Meta::load(dir)
    } else {
        write_artifacts(dir, &NativeSpec::builtin())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basecall::ctc::greedy_decode;

    fn sig(window: usize, phase: f32) -> Vec<f32> {
        (0..window).map(|i| ((i as f32) * 0.21 + phase).sin()).collect()
    }

    #[test]
    fn builtin_outputs_are_normalized_log_probs() {
        let mut b = NativeBackend::builtin();
        let w = b.meta().window;
        let lps = b.run_windows("guppy", 32, &[sig(w, 0.0)]).unwrap();
        assert_eq!(lps.len(), 1);
        assert_eq!(lps[0].t, 145);
        for t in 0..lps[0].t {
            let total: f32 = lps[0].row(t).iter().map(|x| x.exp()).sum();
            assert!((total - 1.0).abs() < 1e-3, "t={t}: sum {total}");
        }
    }

    #[test]
    fn shard_replica_is_bit_identical() {
        let mut a = NativeBackend::builtin();
        let mut b = a.clone_for_shard();
        let w = a.meta().window;
        let x = sig(w, 0.9);
        let la = a.run_windows("guppy", 8, &[x.clone()]).unwrap();
        let lb = b.run_windows("guppy", 8, &[x]).unwrap();
        assert_eq!(la[0].data, lb[0].data,
                   "replica diverged from its source backend");
    }

    #[test]
    fn outputs_are_deterministic_across_instances() {
        let mut a = NativeBackend::builtin();
        let mut b = NativeBackend::builtin();
        let w = a.meta().window;
        let x = sig(w, 1.3);
        let la = a.run_windows("guppy", 5, &[x.clone()]).unwrap();
        let lb = b.run_windows("guppy", 5, &[x]).unwrap();
        assert_eq!(la[0].data, lb[0].data);
    }

    #[test]
    fn bit_widths_are_distinct_models() {
        let mut b = NativeBackend::builtin();
        let w = b.meta().window;
        let x = sig(w, 0.7);
        let fp = b.run_windows("guppy", 32, &[x.clone()]).unwrap();
        let q5 = b.run_windows("guppy", 5, &[x]).unwrap();
        let diff: f32 = fp[0].data.iter().zip(&q5[0].data)
            .map(|(a, c)| (a - c).abs())
            .sum();
        assert!(diff > 1e-3, "5-bit model identical to 32-bit?");
    }

    #[test]
    fn writer_roundtrip_matches_builtin() {
        let dir = std::env::temp_dir().join("helix_native_writer_test");
        let dir = dir.to_str().unwrap().to_string();
        let meta = write_artifacts(&dir, &NativeSpec::builtin()).unwrap();
        assert_eq!(meta.batches("guppy", 32), vec![1, 8, 32]);
        let mut disk = NativeBackend::open(&dir).unwrap();
        let mut mem = NativeBackend::builtin();
        let w = mem.meta().window;
        let x = sig(w, 2.1);
        let ld = disk.run_windows("guppy", 16, &[x.clone()]).unwrap();
        let lm = mem.run_windows("guppy", 16, &[x]).unwrap();
        for (d, m) in ld[0].data.iter().zip(&lm[0].data) {
            assert!((d - m).abs() < 1e-6, "disk {d} vs builtin {m}");
        }
        // the pore model written alongside is loadable and shape-matched
        let pm = PoreModel::load(
            meta.pore_model_path().to_str().unwrap()).unwrap();
        assert_eq!(pm.window, meta.window);
        // idempotent: a second ensure leaves it loadable
        let again = ensure_artifacts(&dir).unwrap();
        assert_eq!(again.entries.len(), meta.entries.len());
    }

    #[test]
    fn zero_window_executes() {
        // the pad path: all-zero activations must not divide by zero
        let mut b = NativeBackend::builtin();
        let w = b.meta().window;
        let lps = b.run_windows("guppy", 8, &[vec![0f32; w]]).unwrap();
        assert!(lps[0].data.iter().all(|x| x.is_finite() && *x <= 0.0));
    }

    /// The SWAR rewrite's core contract: at every datapath width, on
    /// random, all-zero, saturating, and tiny-magnitude signals, the
    /// vectorized forward equals the scalar reference *bit for bit* —
    /// not approximately. This is what lets the shard/determinism pins
    /// elsewhere stay byte-identical across the rewrite.
    #[test]
    fn swar_forward_is_bit_exact_vs_scalar_reference() {
        let mut b = NativeBackend::builtin();
        let w = b.meta().window;
        let mut cases: Vec<Vec<f32>> = vec![
            vec![0.0; w], // all-zero (the tail-pad path)
            (0..w).map(|i| if i % 2 == 0 { 1e30 } else { -1e30 })
                .collect(), // saturating: every activation at ±a_qmax
            vec![5.0; w], // constant (max == every sample)
            (0..w).map(|i| (i as f32 * 0.17).sin() * 1e-6)
                .collect(), // tiny magnitudes
        ];
        let mut rng = Rng::new(0xD00D);
        for _ in 0..4 {
            cases.push((0..w).map(|_| rng.normal() as f32).collect());
        }
        for &bits in &[32u32, 16, 8, 5] {
            for (ci, sig) in cases.iter().enumerate() {
                let fast =
                    b.run_windows("guppy", bits, &[sig.clone()]).unwrap();
                let slow =
                    b.run_reference("guppy", bits, &[sig.clone()])
                    .unwrap();
                assert_eq!(fast[0].t, slow[0].t);
                for (i, (x, y)) in fast[0].data.iter()
                    .zip(&slow[0].data).enumerate()
                {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "bits={bits} case={ci} elem={i}: \
                                SWAR {x} != scalar {y}");
                }
            }
        }
    }

    /// Randomized variant of the bit-exactness pin (prop-test seeds, so
    /// a failure names a replayable case).
    #[test]
    fn swar_forward_bit_exactness_holds_on_random_signals() {
        let mut b = NativeBackend::builtin();
        let w = b.meta().window;
        crate::util::prop::check("swar forward == scalar", 8,
                                 |rng, i| {
            let amp = [1e-3f32, 1.0, 1e4][i % 3];
            let sig: Vec<f32> = (0..w)
                .map(|_| rng.normal() as f32 * amp)
                .collect();
            let bits = [32u32, 16, 8, 5][i % 4];
            let fast =
                b.run_windows("guppy", bits, &[sig.clone()]).unwrap();
            let slow =
                b.run_reference("guppy", bits, &[sig]).unwrap();
            for (x, y) in fast[0].data.iter().zip(&slow[0].data) {
                assert_eq!(x.to_bits(), y.to_bits(), "bits={bits}");
            }
        });
    }

    #[test]
    fn scratch_reuse_does_not_leak_state_across_windows() {
        // a batch mixing degenerate and normal windows through the
        // shared scratch must give each window the same answer it gets
        // alone (the arena is per-call state, not per-window state)
        let mut b = NativeBackend::builtin();
        let w = b.meta().window;
        let windows: Vec<Vec<f32>> = vec![
            sig(w, 0.3),
            vec![0.0; w],
            sig(w, 1.1),
            vec![1e30; w],
            sig(w, 2.2),
        ];
        let batched = b.run_windows("guppy", 8, &windows).unwrap();
        for (i, win) in windows.iter().enumerate() {
            let solo =
                b.run_windows("guppy", 8, &[win.clone()]).unwrap();
            for (x, y) in batched[i].data.iter().zip(&solo[0].data) {
                assert_eq!(x.to_bits(), y.to_bits(),
                           "window {i} depends on batch neighbours");
            }
        }
    }

    #[test]
    fn pore_signal_decodes_nonempty() {
        // the blank-bias construction guarantees real (non-empty) decodes
        let pm = PoreModel::synthetic(PORE_SEED);
        let mut rng = Rng::new(11);
        let seq: Vec<u8> = (0..80).map(|_| rng.base()).collect();
        let (signal, _) = pm.simulate(&seq, &mut rng);
        let mut b = NativeBackend::builtin();
        let w = b.meta().window;
        let lps = b.run_windows(
            "guppy", 32, &[signal[..w].to_vec()]).unwrap();
        assert!(!greedy_decode(&lps[0]).is_empty());
    }
}
