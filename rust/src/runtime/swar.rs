//! SWAR (SIMD-within-a-register) integer kernels for the native
//! quantized executor.
//!
//! The scalar reference path multiplies one `(weight, activation)` pair
//! per instruction. Here the quantized weight matrix is packed into
//! `u64` words carrying several *lanes* (independent unsigned
//! sub-accumulators), so one 64-bit multiply-add advances several
//! output rows at once:
//!
//! * weights are biased to unsigned, `w' = w + w_qmax ∈ [0, 2·w_qmax]`,
//!   and likewise activations `x' = x + a_qmax` — a broadcast multiply
//!   `(w0' + w1'·2^L)·x'` then yields `w0'x'` and `w1'x'` in disjoint
//!   lanes with no cross-lane carry, as long as every lane's
//!   accumulated sum stays below `2^L`;
//! * the exact signed dot product is recovered from the biased one by
//!   the identity `Σw·x = Σw'x' − a_qmax·Σw' − w_qmax·Σx' +
//!   n·w_qmax·a_qmax`, which is all-integer and therefore exact — the
//!   SWAR path produces the *same* `i64` accumulator as the scalar
//!   loop, bit for bit;
//! * the lane layout is chosen from the worst-case lane sum
//!   `n·(2·w_qmax)·(2·a_qmax)`: 4×16-bit lanes for narrow models,
//!   3×21-bit for the 8-bit datapath, 2×32-bit beyond that. The
//!   16-bit datapath (what `native_datapath_bits` maps 32- and 16-bit
//!   models to) overflows even 32-bit lanes, so its weights are split
//!   into hi/lo byte *planes* (`w' = 256·hi + lo`, both ≤ 255) and the
//!   two plane sums are recombined — still exact.
//!
//! `pim::schemes::native_datapath_bits` caps both operand widths at 16
//! bits, so every reachable configuration packs; `PackedMat::pack`
//! asserts the capacity proof at construction time.

/// Lane layouts in preference order: most lanes first. A layout is
/// usable when the worst-case per-lane sum fits its lane width.
const LANE_CFGS: &[(u32, usize)] = &[(16, 4), (21, 3), (32, 2)];

/// Pick the widest (most-lanes) layout whose lanes can hold
/// `max_lane_sum` without overflowing into the neighbour lane.
fn lane_cfg(max_lane_sum: u64) -> Option<(u32, usize)> {
    LANE_CFGS.iter().copied()
        .find(|&(bits, _)| max_lane_sum < (1u64 << bits))
}

/// One packed copy of the (biased) weight matrix. For most widths a
/// matrix has a single plane holding `w'` directly (`mult == 1`); the
/// 16-bit datapath carries two byte planes (`mult` 256 and 1) whose
/// lane sums are recombined as `256·hi + lo`.
#[derive(Clone, Debug)]
struct Plane {
    /// weight of this plane in the recombination (1 or 256).
    mult: i64,
    /// lane width in bits.
    lane_bits: u32,
    /// lanes (rows) per u64 word.
    lanes: usize,
    /// packed words, `[group][term]` row-major: group `g`, term `k` at
    /// `words[g*n + k]`, where group `g` covers rows
    /// `g*lanes .. (g+1)*lanes`.
    words: Vec<u64>,
}

impl Plane {
    /// Pack per-row plane values (`vals`, row-major `[rows][n]`, every
    /// value `< 2^lane_bits`) into lane-parallel words.
    fn pack(vals: &[u64], rows: usize, n: usize, mult: i64,
            lane_bits: u32, lanes: usize) -> Plane {
        let groups = rows.div_ceil(lanes);
        let mut words = vec![0u64; groups * n];
        for r in 0..rows {
            let shift = ((r % lanes) as u32) * lane_bits;
            let g = r / lanes;
            for k in 0..n {
                words[g * n + k] |= vals[r * n + k] << shift;
            }
        }
        Plane { mult, lane_bits, lanes, words }
    }
}

/// A quantized weight matrix (`rows` × `n`, entries in
/// `[-w_qmax, w_qmax]`) packed for lane-parallel dot products against
/// activations in `[-a_qmax, a_qmax]`.
///
/// `dot_into` computes, for every row, exactly the `i64` the scalar
/// loop `Σ_k w[r][k]·x[k]` computes — same value, bit for bit — which
/// is what lets the native backend keep its byte-identical determinism
/// contract while running vectorized.
#[derive(Clone, Debug)]
pub struct PackedMat {
    rows: usize,
    n: usize,
    w_qmax: i64,
    a_qmax: i64,
    /// per-row biased weight sums `Σ_k (w[r][k] + w_qmax)`.
    wsum: Vec<i64>,
    /// the constant `n · w_qmax · a_qmax` of the unbiasing identity.
    nwa: i64,
    planes: Vec<Plane>,
}

impl PackedMat {
    /// Pack a row-major quantized matrix. Panics (with the capacity
    /// proof) if no lane layout can hold the worst-case lane sum —
    /// unreachable for the ≤16-bit widths `native_datapath_bits`
    /// produces.
    pub fn pack(q: &[i32], rows: usize, n: usize, w_qmax: i32,
                a_qmax: i32) -> PackedMat {
        assert_eq!(q.len(), rows * n, "packed matrix shape mismatch");
        assert!(rows > 0 && n > 0, "empty matrix");
        assert!(w_qmax > 0 && a_qmax > 0 && w_qmax <= 32767
                && a_qmax <= 32767,
                "SWAR packing needs 2..=16-bit operands \
                 (w_qmax {w_qmax}, a_qmax {a_qmax})");
        let wq = w_qmax as i64;
        let aq = a_qmax as i64;
        let biased: Vec<u64> = q.iter().map(|&w| {
            debug_assert!((-w_qmax..=w_qmax).contains(&w),
                          "weight {w} outside ±{w_qmax}");
            (w as i64 + wq) as u64
        }).collect();
        let wsum: Vec<i64> = (0..rows)
            .map(|r| biased[r * n..(r + 1) * n].iter()
                 .map(|&w| w as i64).sum())
            .collect();
        let xmax = 2 * aq as u64; // biased activation ceiling
        let wmax = 2 * wq as u64; // biased weight ceiling
        let planes = match lane_cfg(n as u64 * wmax * xmax) {
            Some((bits, lanes)) => {
                vec![Plane::pack(&biased, rows, n, 1, bits, lanes)]
            }
            None => {
                // byte-plane split: w' = 256·hi + lo, both planes ≤ 255
                let (bits, lanes) = lane_cfg(n as u64 * 255 * xmax)
                    .expect("byte planes must fit a lane layout \
                             (n too large for SWAR packing)");
                let hi: Vec<u64> = biased.iter().map(|&w| w >> 8)
                    .collect();
                let lo: Vec<u64> = biased.iter().map(|&w| w & 0xFF)
                    .collect();
                vec![Plane::pack(&hi, rows, n, 256, bits, lanes),
                     Plane::pack(&lo, rows, n, 1, bits, lanes)]
            }
        };
        PackedMat {
            rows,
            n,
            w_qmax: wq,
            a_qmax: aq,
            wsum,
            nwa: n as i64 * wq * aq,
            planes,
        }
    }

    /// Number of output rows (`out` must hold at least this many).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Terms per row (`xb` must be exactly this long).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Lane-parallel dot products: `out[r] = Σ_k w[r][k]·x[k]` for
    /// every row, exactly (bit-identical to the scalar i64 loop).
    ///
    /// `xb` holds the *biased* activations `x[k] + a_qmax` (as produced
    /// by [`quantize_biased`]) and `xsum` their sum `Σ_k xb[k]`.
    pub fn dot_into(&self, xb: &[u64], xsum: i64, out: &mut [i64]) {
        assert_eq!(xb.len(), self.n, "activation length mismatch");
        assert!(out.len() >= self.rows, "output buffer too small");
        for o in out[..self.rows].iter_mut() {
            *o = 0;
        }
        for plane in &self.planes {
            let lanes = plane.lanes;
            let lane_bits = plane.lane_bits;
            let mask = (1u64 << lane_bits) - 1;
            let groups = self.rows.div_ceil(lanes);
            for g in 0..groups {
                // the hot loop: one u64 multiply-add advances `lanes`
                // rows at once; lane sums provably stay below
                // 2^lane_bits (asserted at pack time), so no cross-lane
                // carry and no u64 wrap can occur
                let mut acc = 0u64;
                let words = &plane.words[g * self.n..(g + 1) * self.n];
                for (w, &x) in words.iter().zip(xb) {
                    acc = acc.wrapping_add(w.wrapping_mul(x));
                }
                let r0 = g * lanes;
                let live = lanes.min(self.rows - r0);
                for j in 0..live {
                    let lane = ((acc >> (j as u32 * lane_bits)) & mask)
                        as i64;
                    out[r0 + j] += plane.mult * lane;
                }
            }
        }
        // unbias: Σw·x = Σw'x' − a_qmax·Σw' − w_qmax·Σx' + n·W·A
        for (o, &ws) in out[..self.rows].iter_mut().zip(&self.wsum) {
            *o += self.nwa - self.a_qmax * ws - self.w_qmax * xsum;
        }
    }
}

/// Quantize a float signal symmetrically — *the same rounding as the
/// scalar reference* (`max-abs / qmax` scale, round-half-away, clamp) —
/// directly into biased-unsigned SWAR activations
/// `out[k] = q[k] + a_qmax`. Returns the dequantization scale.
///
/// Callers slice `out` and sum the slice for `dot_into`'s `xsum`.
pub fn quantize_biased(sig: &[f32], a_qmax: i32, out: &mut Vec<u64>)
                       -> f32 {
    let max = sig.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-12);
    let scale = max / a_qmax as f32;
    let lim = a_qmax as f32;
    out.clear();
    out.reserve(sig.len());
    for &x in sig {
        let q = (x / scale).round().clamp(-lim, lim) as i32;
        out.push((q + a_qmax) as u64);
    }
    scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn naive_dot(q: &[i32], rows: usize, n: usize, x: &[i64])
                 -> Vec<i64> {
        (0..rows).map(|r| {
            q[r * n..(r + 1) * n].iter().zip(x)
                .map(|(&w, &xx)| w as i64 * xx)
                .sum()
        }).collect()
    }

    fn check_exact(q: &[i32], rows: usize, n: usize, w_qmax: i32,
                   a_qmax: i32, x: &[i32]) {
        let pm = PackedMat::pack(q, rows, n, w_qmax, a_qmax);
        assert_eq!(pm.rows(), rows);
        assert_eq!(pm.n(), n);
        let xb: Vec<u64> =
            x.iter().map(|&v| (v + a_qmax) as u64).collect();
        let xsum: i64 = xb.iter().map(|&v| v as i64).sum();
        let mut out = vec![0i64; rows];
        pm.dot_into(&xb, xsum, &mut out);
        let xi: Vec<i64> = x.iter().map(|&v| v as i64).collect();
        let want = naive_dot(q, rows, n, &xi);
        assert_eq!(out, want,
                   "rows={rows} n={n} w_qmax={w_qmax} a_qmax={a_qmax}");
    }

    #[test]
    fn packed_dot_is_exact_at_every_width() {
        // every operand width 2..=16 bits, random shapes/values —
        // covers the 4-lane, 3-lane, 2-lane and byte-split layouts
        for bits in 2..=16u32 {
            let qmax = (1i32 << (bits - 1)) - 1;
            prop::check(&format!("swar dot {bits}b"), 6, |rng, _| {
                let rows = 1 + rng.below(17);
                let n = 1 + rng.below(20);
                let q: Vec<i32> = (0..rows * n)
                    .map(|_| rng.range(-(qmax as i64), qmax as i64)
                         as i32)
                    .collect();
                let x: Vec<i32> = (0..n)
                    .map(|_| rng.range(-(qmax as i64), qmax as i64)
                         as i32)
                    .collect();
                check_exact(&q, rows, n, qmax, qmax, &x);
            });
        }
    }

    #[test]
    fn packed_dot_is_exact_at_saturation() {
        // all-extreme operands: the worst case the capacity proof is
        // about — every lane at its maximum sum simultaneously
        for &(w_bits, a_bits) in
            &[(5u32, 5u32), (8, 8), (12, 12), (16, 16), (16, 8)]
        {
            let wq = (1i32 << (w_bits - 1)) - 1;
            let aq = (1i32 << (a_bits - 1)) - 1;
            for (rows, n) in [(16usize, 12usize), (5, 16), (1, 1),
                              (3, 7)] {
                for wv in [wq, -wq, 0] {
                    for xv in [aq, -aq, 0] {
                        let q = vec![wv; rows * n];
                        let x = vec![xv; n];
                        check_exact(&q, rows, n, wq, aq, &x);
                    }
                }
            }
        }
    }

    #[test]
    fn model_shapes_use_expected_layouts() {
        // the builtin model's shapes: conv 16×12, matmul 5×16
        let mut rng = Rng::new(42);
        for &(bits, want_planes) in &[(5u32, 1usize), (8, 1), (16, 2)] {
            let qmax = (1i32 << (bits - 1)) - 1;
            for (rows, n) in [(16usize, 12usize), (5, 16)] {
                let q: Vec<i32> = (0..rows * n)
                    .map(|_| rng.range(-(qmax as i64), qmax as i64)
                         as i32)
                    .collect();
                let pm = PackedMat::pack(&q, rows, n, qmax, qmax);
                assert_eq!(pm.planes.len(), want_planes,
                           "{bits}b {rows}x{n}");
            }
        }
    }

    #[test]
    fn quantize_biased_matches_scalar_rounding() {
        let mut rng = Rng::new(7);
        let sig: Vec<f32> =
            (0..64).map(|_| rng.normal() as f32).collect();
        for qmax in [15i32, 127, 32767] {
            let mut xb = Vec::new();
            let scale = quantize_biased(&sig, qmax, &mut xb);
            // re-derive the scalar quantization and compare
            let max = sig.iter().fold(0f32, |m, &x| m.max(x.abs()))
                .max(1e-12);
            let want_scale = max / qmax as f32;
            assert_eq!(scale.to_bits(), want_scale.to_bits());
            for (&b, &x) in xb.iter().zip(&sig) {
                let q = (x / want_scale).round()
                    .clamp(-(qmax as f32), qmax as f32) as i32;
                assert_eq!(b, (q + qmax) as u64);
            }
        }
    }
}
