//! The inference backend abstraction: compile/load + batched execution.
//!
//! The coordinator's DNN stage is written against `Backend`, not a
//! concrete engine, so the same submit→window→batch→DNN→decode→collect
//! →vote pipeline runs on either:
//!
//!   * `native` (default) — the pure-Rust quantized executor
//!     (`runtime::native`), self-contained: no network, no pre-built
//!     artifacts, deterministic weights. This is what CI runs.
//!   * `xla` (cargo feature `xla`) — the PJRT engine executing the
//!     HLO-text artifacts of `make artifacts` (`runtime::executable`).
//!
//! Backends are constructed *inside* their owner thread (the PJRT client
//! is not `Send`), so the coordinator carries a `BackendKind` and calls
//! `open()` from the DNN thread; `probe_meta()` gives the caller thread
//! early validation without constructing the real backend where that is
//! expensive.

use anyhow::{Context, Result};

use crate::basecall::ctc::LogProbs;

use super::meta::{ArtifactEntry, Meta};

/// A loaded inference backend: owns the artifact metadata and executes
/// fixed-shape batches.
pub trait Backend {
    /// Artifact metadata (models, bit-widths, batch sizes, windows).
    fn meta(&self) -> &Meta;

    /// Prepare every (model, bits) executable up front so failures
    /// surface at init, not mid-run (compile cache warm-up on xla,
    /// weight quantization + existence check on native).
    fn warm(&mut self, model: &str, bits: u32) -> Result<()>;

    /// Run exactly one batch: `signals.len()` must equal `entry.batch`
    /// and every row must be `entry.window` samples. Returns one
    /// `LogProbs` (time_steps x NUM_SYMBOLS) per row.
    fn run_batch(&mut self, entry: &ArtifactEntry, signals: &[&[f32]])
                 -> Result<Vec<LogProbs>>;

    /// Basecall an arbitrary number of windows by tiling over the
    /// available batch sizes (smallest batch that covers the tail,
    /// else the largest).
    ///
    /// Contract: the tail batch is padded with zero windows sized to
    /// the SELECTED entry's window — not the top-level `meta.window`
    /// default — so artifacts whose per-entry window differs from the
    /// meta default still execute (regression: `run_windows` used to
    /// pad with `meta.window` and every tail batch of such an artifact
    /// failed `run_batch`'s row-length validation).
    fn run_windows(&mut self, model: &str, bits: u32,
                   windows: &[Vec<f32>]) -> Result<Vec<LogProbs>> {
        let batches = self.meta().batches(model, bits);
        anyhow::ensure!(!batches.is_empty(),
                        "no artifacts for {model}/{bits}b");
        let bmax = *batches.last().unwrap();
        // per-call scratch, shared by every batch of this call: the
        // refs table and the tail zero-pad used to be rebuilt on every
        // loop iteration of the hot path. `refs` holds borrows of the
        // pad across iterations, so the pad is sized up front to the
        // largest matching entry window (a tail batch slices it down
        // to ITS entry's window — the padding contract below). Only a
        // call that will actually pad allocates it: the tiling ends
        // with a short batch exactly when the final remainder is not
        // itself an available batch size.
        let needs_pad = {
            let r = windows.len() % bmax;
            r != 0 && !batches.contains(&r)
        };
        let zero: Vec<f32> = if needs_pad {
            let wmax = self.meta().entries.iter()
                .filter(|e| e.model == model && e.bits == bits)
                .map(|e| e.window)
                .max()
                .unwrap_or(0);
            vec![0f32; wmax]
        } else {
            Vec::new()
        };
        let mut refs: Vec<&[f32]> = Vec::with_capacity(bmax);
        let mut out = Vec::with_capacity(windows.len());
        let mut i = 0;
        while i < windows.len() {
            let remaining = windows.len() - i;
            // pick the smallest batch size that covers the tail
            let b = *batches.iter().find(|&&x| x >= remaining)
                .unwrap_or(&bmax);
            let entry = self.meta().find(model, bits, b)
                .with_context(|| format!("no artifact for \
                                          {model}/{bits}b/b{b}"))?
                .clone();
            let take = remaining.min(b);
            refs.clear();
            for w in &windows[i..i + take] {
                refs.push(w.as_slice());
            }
            // contract: the tail batch is padded with zero windows
            // sized to the SELECTED entry's window — not the top-level
            // `meta.window` default (see the doc comment above)
            for _ in take..b {
                refs.push(&zero[..entry.window]);
            }
            let lps = self.run_batch(&entry, &refs)?;
            out.extend(lps.into_iter().take(take));
            i += take;
        }
        Ok(out)
    }
}

/// Which backend the coordinator (or an example/bench) should open.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust quantized executor; zero external dependencies.
    #[default]
    Native,
    /// PJRT engine over the AOT HLO artifacts (`make artifacts`).
    #[cfg(feature = "xla")]
    Xla,
}

impl BackendKind {
    /// Stable lowercase name ("native" / "xla") for logs and env vars.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            #[cfg(feature = "xla")]
            BackendKind::Xla => "xla",
        }
    }

    /// Backend selected by `HELIX_BACKEND` (`native` | `xla`), default
    /// native. Errors when `xla` is requested but the crate was built
    /// without the `xla` feature.
    pub fn from_env() -> Result<BackendKind> {
        match std::env::var("HELIX_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("native") => Ok(BackendKind::Native),
            #[cfg(feature = "xla")]
            Ok("xla") => Ok(BackendKind::Xla),
            #[cfg(not(feature = "xla"))]
            Ok("xla") => anyhow::bail!(
                "HELIX_BACKEND=xla but this build has no PJRT runtime — \
                 rebuild with `--features xla`"),
            Ok(other) => anyhow::bail!(
                "unknown HELIX_BACKEND '{other}' (native|xla)"),
        }
    }

    /// Construct the backend. Call from the thread that will own it:
    /// the xla PJRT client is not `Send`.
    pub fn open(&self, artifacts_dir: &str) -> Result<Box<dyn Backend>> {
        match self {
            BackendKind::Native => Ok(Box::new(
                super::native::NativeBackend::open(artifacts_dir)?)),
            #[cfg(feature = "xla")]
            BackendKind::Xla => Ok(Box::new(
                super::executable::Engine::new(artifacts_dir)?)),
        }
    }

    /// Factory path for the sharded DNN executor pool: construct this
    /// shard's own backend replica from scratch. MUST be called from
    /// the shard thread that will own the replica (PJRT clients are
    /// not `Send`); `xla` opens an independent engine handle over the
    /// same artifacts. The coordinator only uses this for backends it
    /// cannot pre-build on the caller thread — `native` replicas are
    /// plain `Send` data and are stamped out in memory with
    /// `NativeBackend::clone_for_shard` instead (one artifact load for
    /// N shards). Either way every replica computes bit-identical
    /// `LogProbs` for the same window.
    pub fn open_shard(&self, artifacts_dir: &str, shard: usize)
                      -> Result<Box<dyn Backend>> {
        self.open(artifacts_dir).with_context(
            || format!("opening {} backend replica for shard {shard}",
                       self.name()))
    }

    /// Caller-thread validation: the metadata `open()` would see,
    /// without constructing the backend (no weight generation, no
    /// PJRT). On-disk artifacts read `meta.json`; the native builtin
    /// fallback derives its meta from the spec alone.
    pub fn probe_meta(&self, artifacts_dir: &str) -> Result<Meta> {
        match self {
            BackendKind::Native => {
                if super::meta::artifacts_available(artifacts_dir) {
                    Meta::load(artifacts_dir)
                } else {
                    Ok(super::native::NativeSpec::builtin()
                        .meta(std::path::Path::new(artifacts_dir)))
                }
            }
            #[cfg(feature = "xla")]
            BackendKind::Xla => Meta::load(artifacts_dir),
        }
    }

    /// Make sure the artifacts the backend needs exist: the native
    /// backend materializes its deterministic in-tree model (meta.json,
    /// qmodel weights, pore model) on first use; the xla backend
    /// requires `make artifacts` to have run.
    pub fn prepare(&self, artifacts_dir: &str) -> Result<()> {
        match self {
            BackendKind::Native => {
                super::native::ensure_artifacts(artifacts_dir)?;
                Ok(())
            }
            #[cfg(feature = "xla")]
            BackendKind::Xla => {
                anyhow::ensure!(
                    super::meta::artifacts_available(artifacts_dir),
                    "no artifacts in {artifacts_dir} — run `make artifacts`");
                Ok(())
            }
        }
    }
}

/// Replica factory for the DNN shard pool — the piece that makes *late*
/// shard construction possible: the coordinator's autoscaler spawns
/// shards mid-run, long after `Coordinator::new` returned, so the
/// recipe for building a replica has to outlive construction and be
/// shippable to a controller thread.
///
/// For the native backend the factory opens ONE prototype up front
/// (one artifact load + quantization) and every replica — initial or
/// autoscaled — is an in-memory `NativeBackend::clone_for_shard` of
/// it, guaranteed bit-identical. For non-`Send` backends (the PJRT
/// client) the factory carries only `(kind, artifacts_dir)` and
/// `replica()` constructs the engine from scratch; it MUST then be
/// called on the shard thread that will own the replica.
///
/// A pool that will never build another replica (fixed shard count, no
/// autoscaler) should call `discard_prototype()` once its initial
/// replicas are up, so the run carries N model copies instead of N+1;
/// a replica requested afterwards anyway falls back to a fresh
/// `open_shard`, which is bit-identical because the native weights are
/// deterministic.
pub struct ShardFactory {
    kind: BackendKind,
    artifacts_dir: String,
    prototype: std::sync::Mutex<Option<super::native::NativeBackend>>,
}

impl ShardFactory {
    /// Build the factory; for the native backend this performs the one
    /// artifact load every replica will be cloned from, so open errors
    /// surface here (at coordinator construction), not mid-run.
    pub fn new(kind: BackendKind, artifacts_dir: &str)
               -> Result<ShardFactory> {
        let prototype = match kind {
            BackendKind::Native => {
                Some(super::native::NativeBackend::open(artifacts_dir)?)
            }
            #[cfg(feature = "xla")]
            BackendKind::Xla => None,
        };
        Ok(ShardFactory {
            kind,
            artifacts_dir: artifacts_dir.to_string(),
            prototype: std::sync::Mutex::new(prototype),
        })
    }

    /// The backend kind replicas are built for.
    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// Construct one shard replica. Native: a cheap in-memory clone of
    /// the prototype (no disk, no re-quantization). Otherwise this
    /// falls through to `BackendKind::open_shard` and must run on the
    /// thread that will own the replica (PJRT clients are not `Send`).
    pub fn replica(&self, shard: usize) -> Result<Box<dyn Backend>> {
        {
            let proto = self.prototype.lock().unwrap();
            if let Some(p) = proto.as_ref() {
                return Ok(Box::new(p.clone_for_shard()));
            }
        }
        self.kind.open_shard(&self.artifacts_dir, shard)
    }

    /// Release the native prototype. Call when no further replica will
    /// (normally) be built — a fixed pool after its initial shards are
    /// up — so the run does not carry an extra model copy for its
    /// whole lifetime. Safe even if a replica is requested later: the
    /// `open_shard` fallback rebuilds the same deterministic model.
    pub fn discard_prototype(&self) {
        *self.prototype.lock().unwrap() = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::{NativeBackend, NativeModelSpec,
                                 NativeSpec};

    /// Regression for the tail-batch padding bug: an artifact whose
    /// per-entry window differs from the top-level meta default must
    /// still run ragged window counts — the zero pad has to be sized by
    /// the selected entry, not `meta.window`.
    #[test]
    fn tail_batch_pads_with_entry_window() {
        let spec = NativeSpec {
            models: vec![
                NativeModelSpec::new("guppy", &[32], &[1, 8], 300),
                // entry window 64 != meta default window 300
                NativeModelSpec::new("tiny", &[8], &[2], 64),
            ],
            ..NativeSpec::builtin()
        };
        let mut b = NativeBackend::from_spec(&spec);
        assert_eq!(b.meta().window, 300);
        assert_eq!(b.meta().find("tiny", 8, 2).unwrap().window, 64);
        // 5 windows over batch 2: the third batch is a tail of 1 + 1 pad
        let windows: Vec<Vec<f32>> = (0..5)
            .map(|k| (0..64).map(|i| ((i + k) as f32 * 0.3).sin()).collect())
            .collect();
        let lps = b.run_windows("tiny", 8, &windows).unwrap();
        assert_eq!(lps.len(), 5);
        let t = b.meta().find("tiny", 8, 2).unwrap().time_steps;
        for lp in &lps {
            assert_eq!(lp.t, t);
        }
        // same window decoded alone must match its batched result
        let single = b.run_windows("tiny", 8, &windows[4..5]).unwrap();
        for (a, s) in lps[4].data.iter().zip(&single[0].data) {
            assert!((a - s).abs() < 1e-5, "batch-position dependence");
        }
    }

    #[test]
    fn run_windows_rejects_unknown_model() {
        let mut b = NativeBackend::builtin();
        assert!(b.run_windows("nope", 32, &[]).is_err());
    }

    /// Regression for the scratch hoist: `run_windows` reuses one refs
    /// table and one zero pad across every batch of a call now — the
    /// output must stay bit-identical to decoding each window alone,
    /// at every ragged length (exact batch, padded tail, multi-batch,
    /// and the short-batch-then-pad shapes).
    #[test]
    fn run_windows_scratch_reuse_keeps_output_identical() {
        let mut b = NativeBackend::builtin();
        let w = b.meta().window;
        for len in [1usize, 2, 7, 8, 9, 33] {
            let windows: Vec<Vec<f32>> = (0..len)
                .map(|k| (0..w)
                     .map(|i| ((i + 31 * k) as f32 * 0.13).sin())
                     .collect())
                .collect();
            let batched = b.run_windows("guppy", 16, &windows).unwrap();
            assert_eq!(batched.len(), len);
            for (k, win) in windows.iter().enumerate() {
                let solo = b.run_windows("guppy", 16,
                                         &[win.clone()]).unwrap();
                for (x, y) in batched[k].data.iter()
                    .zip(&solo[0].data)
                {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "len={len} window={k} diverged");
                }
            }
        }
    }

    /// The autoscaler's late-construction contract: every replica the
    /// factory hands out — whenever it is built — computes bit-identical
    /// LogProbs, so scaling mid-run can never change called output.
    #[test]
    fn shard_factory_builds_identical_native_replicas() {
        let f = ShardFactory::new(BackendKind::Native,
                                  "does-not-exist-factory").unwrap();
        assert_eq!(f.kind(), BackendKind::Native);
        let mut a = f.replica(0).unwrap();
        let mut b = f.replica(7).unwrap();
        a.warm("guppy", 32).unwrap();
        b.warm("guppy", 32).unwrap();
        let w = a.meta().window;
        let sig: Vec<Vec<f32>> =
            vec![(0..w).map(|i| (i as f32 * 0.1).sin()).collect()];
        let la = a.run_windows("guppy", 32, &sig).unwrap();
        let lb = b.run_windows("guppy", 32, &sig).unwrap();
        assert_eq!(la.len(), 1);
        assert_eq!(la[0].t, lb[0].t);
        for (x, y) in la[0].data.iter().zip(&lb[0].data) {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "replicas must be bit-identical");
        }
        // after discarding the prototype (fixed-pool memory release),
        // the open_shard fallback must still produce the same model
        f.discard_prototype();
        let mut c = f.replica(3).unwrap();
        c.warm("guppy", 32).unwrap();
        let lc = c.run_windows("guppy", 32, &sig).unwrap();
        for (x, y) in la[0].data.iter().zip(&lc[0].data) {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "fallback replica must be bit-identical too");
        }
    }

    #[test]
    fn env_default_is_native() {
        // (HELIX_BACKEND is unset in the test environment)
        if std::env::var("HELIX_BACKEND").is_err() {
            assert_eq!(BackendKind::from_env().unwrap(),
                       BackendKind::Native);
        }
        assert_eq!(BackendKind::default().name(), "native");
    }
}
