//! PJRT executable wrapper: HLO text -> compile -> batched execution
//! (cargo feature `xla`; the default build uses `runtime::native`).
//!
//! Follows the /opt/xla-example/load_hlo pattern: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Outputs are 1-tuples (the export lowers
//! with return_tuple=True), unwrapped with `to_tuple1`.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::basecall::ctc::LogProbs;
use crate::basecall::NUM_SYMBOLS;

use super::backend::Backend;
use super::meta::{ArtifactEntry, Meta};

/// One compiled model variant at a fixed batch size.
pub struct ModelExecutable {
    /// the artifact this executable was compiled from.
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl ModelExecutable {
    /// Run one batch of signal windows (rows of `entry.window` f32 samples).
    /// `signals.len()` must equal `entry.batch`. Returns per-window
    /// log-probabilities (time_steps x NUM_SYMBOLS each).
    pub fn run(&self, signals: &[&[f32]]) -> Result<Vec<LogProbs>> {
        anyhow::ensure!(signals.len() == self.entry.batch,
                        "batch mismatch: got {}, executable wants {}",
                        signals.len(), self.entry.batch);
        let w = self.entry.window;
        let mut flat = Vec::with_capacity(signals.len() * w);
        for s in signals {
            anyhow::ensure!(s.len() == w, "window length {} != {w}", s.len());
            flat.extend_from_slice(s);
        }
        let input = xla::Literal::vec1(&flat)
            .reshape(&[signals.len() as i64, w as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        let t = self.entry.time_steps;
        anyhow::ensure!(values.len() == signals.len() * t * NUM_SYMBOLS,
                        "unexpected output size {}", values.len());
        Ok(values
            .chunks(t * NUM_SYMBOLS)
            .map(|c| LogProbs::new(t, c.to_vec()))
            .collect())
    }
}

/// The runtime engine: one PJRT client + a cache of compiled executables.
pub struct Engine {
    /// the artifact set this engine compiles from.
    pub meta: Meta,
    client: xla::PjRtClient,
    cache: HashMap<String, ModelExecutable>,
}

impl Engine {
    /// Open a CPU PJRT client over `make artifacts` output. Not `Send`:
    /// construct inside the thread that will run it.
    pub fn new(artifacts_dir: &str) -> Result<Engine> {
        let meta = Meta::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { meta, client, cache: HashMap::new() })
    }

    /// Compile (or fetch from cache) the artifact for (model, bits, batch).
    pub fn load(&mut self, model: &str, bits: u32, batch: usize)
                -> Result<&ModelExecutable> {
        let entry = self.meta.find(model, bits, batch)
            .with_context(|| format!("no artifact for {model}/{bits}b/b{batch} \
                                      — run `make artifacts`"))?
            .clone();
        if !self.cache.contains_key(&entry.name) {
            let path = self.meta.path_of(&entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("path")?)
                .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", entry.name))?;
            self.cache.insert(entry.name.clone(),
                              ModelExecutable { entry: entry.clone(), exe });
        }
        Ok(&self.cache[&entry.name])
    }
}

/// Batched execution via the shared `Backend` contract: `run_windows`
/// (the trait's default) tiles over the exported batch sizes and pads
/// the tail batch with zero windows sized by the SELECTED entry's
/// window — `ModelExecutable::run` validates each row against
/// `entry.window`, so padding by the top-level `meta.window` default
/// broke every tail batch of an artifact whose per-entry window
/// differed from it.
impl Backend for Engine {
    fn meta(&self) -> &Meta {
        &self.meta
    }

    /// Warm the executable cache for every exported batch size so
    /// compile failures surface at init, not mid-run.
    fn warm(&mut self, model: &str, bits: u32) -> Result<()> {
        let batches = self.meta.batches(model, bits);
        anyhow::ensure!(!batches.is_empty(),
                        "no artifacts for {model}/{bits}b");
        for b in batches {
            self.load(model, bits, b)?;
        }
        Ok(())
    }

    fn run_batch(&mut self, entry: &ArtifactEntry, signals: &[&[f32]])
                 -> Result<Vec<LogProbs>> {
        let exe = self.load(&entry.model, entry.bits, entry.batch)?;
        exe.run(signals)
    }
}
