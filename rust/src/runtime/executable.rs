//! PJRT executable wrapper: HLO text -> compile -> batched execution.
//!
//! Follows the /opt/xla-example/load_hlo pattern: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Outputs are 1-tuples (the export lowers
//! with return_tuple=True), unwrapped with `to_tuple1`.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::basecall::ctc::LogProbs;
use crate::basecall::NUM_SYMBOLS;

use super::meta::{ArtifactEntry, Meta};

/// One compiled model variant at a fixed batch size.
pub struct ModelExecutable {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl ModelExecutable {
    /// Run one batch of signal windows (rows of `entry.window` f32 samples).
    /// `signals.len()` must equal `entry.batch`. Returns per-window
    /// log-probabilities (time_steps x NUM_SYMBOLS each).
    pub fn run(&self, signals: &[&[f32]]) -> Result<Vec<LogProbs>> {
        anyhow::ensure!(signals.len() == self.entry.batch,
                        "batch mismatch: got {}, executable wants {}",
                        signals.len(), self.entry.batch);
        let w = self.entry.window;
        let mut flat = Vec::with_capacity(signals.len() * w);
        for s in signals {
            anyhow::ensure!(s.len() == w, "window length {} != {w}", s.len());
            flat.extend_from_slice(s);
        }
        let input = xla::Literal::vec1(&flat)
            .reshape(&[signals.len() as i64, w as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        let t = self.entry.time_steps;
        anyhow::ensure!(values.len() == signals.len() * t * NUM_SYMBOLS,
                        "unexpected output size {}", values.len());
        Ok(values
            .chunks(t * NUM_SYMBOLS)
            .map(|c| LogProbs::new(t, c.to_vec()))
            .collect())
    }
}

/// The runtime engine: one PJRT client + a cache of compiled executables.
pub struct Engine {
    pub meta: Meta,
    client: xla::PjRtClient,
    cache: HashMap<String, ModelExecutable>,
}

impl Engine {
    pub fn new(artifacts_dir: &str) -> Result<Engine> {
        let meta = Meta::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { meta, client, cache: HashMap::new() })
    }

    /// Compile (or fetch from cache) the artifact for (model, bits, batch).
    pub fn load(&mut self, model: &str, bits: u32, batch: usize)
                -> Result<&ModelExecutable> {
        let entry = self.meta.find(model, bits, batch)
            .with_context(|| format!("no artifact for {model}/{bits}b/b{batch} \
                                      — run `make artifacts`"))?
            .clone();
        if !self.cache.contains_key(&entry.name) {
            let path = self.meta.path_of(&entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("path")?)
                .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", entry.name))?;
            self.cache.insert(entry.name.clone(),
                              ModelExecutable { entry: entry.clone(), exe });
        }
        Ok(&self.cache[&entry.name])
    }

    /// Basecall an arbitrary number of windows by tiling over the largest
    /// available batch executable (padding the tail batch with zeros).
    pub fn run_windows(&mut self, model: &str, bits: u32,
                       windows: &[Vec<f32>]) -> Result<Vec<LogProbs>> {
        let batches = self.meta.batches(model, bits);
        anyhow::ensure!(!batches.is_empty(), "no artifacts for {model}");
        let bmax = *batches.last().unwrap();
        let window = self.meta.window;
        let zero = vec![0f32; window];
        let mut out = Vec::with_capacity(windows.len());
        let mut i = 0;
        while i < windows.len() {
            let remaining = windows.len() - i;
            // pick the smallest batch size that covers the tail
            let b = *batches.iter().find(|&&b| b >= remaining)
                .unwrap_or(&bmax);
            let exe = self.load(model, bits, b)?;
            let mut refs: Vec<&[f32]> = Vec::with_capacity(b);
            for k in 0..b {
                refs.push(windows.get(i + k).map(|w| w.as_slice())
                          .unwrap_or(&zero));
            }
            let lps = exe.run(&refs)?;
            let take = remaining.min(b);
            out.extend(lps.into_iter().take(take));
            i += take;
        }
        Ok(out)
    }
}
