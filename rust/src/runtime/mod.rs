//! PJRT runtime: loads the HLO-text artifacts produced by `make artifacts`
//! (python/compile/aot.py) and executes them on the CPU PJRT client.
//! Python is never on this path — the rust binary is self-contained once
//! artifacts exist.

pub mod executable;
pub mod meta;

pub use executable::{Engine, ModelExecutable};
pub use meta::{ArtifactEntry, Meta};
