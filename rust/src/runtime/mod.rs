//! Model runtime: artifact metadata plus pluggable inference backends
//! behind the `Backend` trait.
//!
//! * `native` (default build): pure-Rust quantized executor with a
//!   deterministic in-tree model — no network, no pre-built artifacts.
//! * `executable` (cargo feature `xla`): the PJRT engine that loads the
//!   HLO-text artifacts produced by `make artifacts`
//!   (python/compile/aot.py) and executes them on the CPU PJRT client.
//! * `swar`: the u64 lane-parallel integer kernels the native
//!   executor's hot path runs on, bit-exact against its scalar
//!   reference.
//! * `tier`: fast/hq model-pair selection over one artifact ladder,
//!   backing the coordinator's speculative tiered serving.
//!
//! Either way, python is never on the serving path.

pub mod backend;
#[cfg(feature = "xla")]
pub mod executable;
pub mod meta;
pub mod native;
pub mod swar;
pub mod tier;

pub use backend::{Backend, BackendKind, ShardFactory};
#[cfg(feature = "xla")]
pub use executable::{Engine, ModelExecutable};
pub use meta::{ArtifactEntry, Meta};
pub use native::NativeBackend;
pub use tier::{Tier, TierSet};
