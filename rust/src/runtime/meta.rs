//! Artifact metadata: `artifacts/meta.json` written by the AOT export.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One exported (model, bits, seat, batch) HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub model: String,
    pub bits: u32,
    pub batch: usize,
    pub window: usize,
    pub time_steps: usize,
    pub pallas: bool,
    pub file: String,
}

/// Parsed meta.json + artifact directory root.
#[derive(Clone, Debug)]
pub struct Meta {
    pub root: PathBuf,
    pub window: usize,
    pub entries: Vec<ArtifactEntry>,
}

impl Meta {
    pub fn load(dir: &str) -> Result<Meta> {
        let root = PathBuf::from(dir);
        let text = std::fs::read_to_string(root.join("meta.json"))
            .with_context(|| format!("reading {dir}/meta.json — run \
                                      `make artifacts` first"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse meta.json: {e}"))?;
        let window = j.get("window").and_then(Json::as_usize)
            .context("window")?;
        let mut entries = Vec::new();
        for e in j.get("entries").and_then(Json::as_arr).context("entries")? {
            entries.push(ArtifactEntry {
                name: e.get("name").and_then(Json::as_str)
                    .context("name")?.to_string(),
                model: e.get("model").and_then(Json::as_str)
                    .context("model")?.to_string(),
                bits: e.get("bits").and_then(Json::as_usize)
                    .context("bits")? as u32,
                batch: e.get("batch").and_then(Json::as_usize)
                    .context("batch")?,
                window: e.get("window").and_then(Json::as_usize)
                    .context("window")?,
                time_steps: e.get("time_steps").and_then(Json::as_usize)
                    .context("time_steps")?,
                pallas: e.get("pallas").and_then(Json::as_bool)
                    .unwrap_or(false),
                file: e.get("file").and_then(Json::as_str)
                    .context("file")?.to_string(),
            });
        }
        Ok(Meta { root, window, entries })
    }

    /// Find the artifact for (model, bits, batch), preferring the pallas
    /// build (the kernel-bearing HLO).
    pub fn find(&self, model: &str, bits: u32, batch: usize)
                -> Option<&ArtifactEntry> {
        self.entries.iter()
            .filter(|e| e.model == model && e.bits == bits
                        && e.batch == batch)
            .max_by_key(|e| e.pallas)
    }

    /// Batch sizes available for (model, bits), ascending.
    pub fn batches(&self, model: &str, bits: u32) -> Vec<usize> {
        let mut b: Vec<usize> = self.entries.iter()
            .filter(|e| e.model == model && e.bits == bits)
            .map(|e| e.batch)
            .collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.root.join(&e.file)
    }

    pub fn pore_model_path(&self) -> PathBuf {
        self.root.join("pore_model.json")
    }
}

/// Default artifacts directory (relative to the repo root).
pub fn default_artifacts_dir() -> String {
    std::env::var("HELIX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
}

/// True when artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_available(dir: &str) -> bool {
    Path::new(dir).join("meta.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_meta(dir: &Path) {
        let meta = r#"{"window": 300, "alphabet": "ACGT-", "blank": 4,
          "entries": [
            {"name": "guppy_32_b1", "model": "guppy", "bits": 32,
             "batch": 1, "window": 300, "time_steps": 145,
             "pallas": true, "file": "guppy_32_b1.hlo.txt"},
            {"name": "guppy_32_b8", "model": "guppy", "bits": 32,
             "batch": 8, "window": 300, "time_steps": 145,
             "pallas": false, "file": "guppy_32_b8.hlo.txt"}
          ]}"#;
        let mut f = std::fs::File::create(dir.join("meta.json")).unwrap();
        f.write_all(meta.as_bytes()).unwrap();
    }

    #[test]
    fn parses_and_finds() {
        let dir = std::env::temp_dir().join("helix_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_meta(&dir);
        let m = Meta::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.window, 300);
        assert_eq!(m.entries.len(), 2);
        let e = m.find("guppy", 32, 1).unwrap();
        assert!(e.pallas);
        assert_eq!(e.time_steps, 145);
        assert_eq!(m.batches("guppy", 32), vec![1, 8]);
        assert!(m.find("guppy", 5, 1).is_none());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Meta::load("/nonexistent/helix").is_err());
        assert!(!artifacts_available("/nonexistent/helix"));
    }
}
