//! Artifact metadata: `artifacts/meta.json` written by the AOT export.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One exported (model, bits, seat, batch) HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// unique artifact name, e.g. `guppy_32_b8`.
    pub name: String,
    /// model family this executable belongs to.
    pub model: String,
    /// bit-width variant.
    pub bits: u32,
    /// fixed batch size the executable was exported with.
    pub batch: usize,
    /// input window length in samples.
    pub window: usize,
    /// CTC time steps the executable emits.
    pub time_steps: usize,
    /// whether this is the pallas (kernel-bearing) build.
    pub pallas: bool,
    /// weight/HLO file name relative to the artifacts root.
    pub file: String,
}

/// Parsed meta.json + artifact directory root.
#[derive(Clone, Debug)]
pub struct Meta {
    /// artifacts directory the entries' files live in.
    pub root: PathBuf,
    /// default window length (entries may override per-artifact).
    pub window: usize,
    /// every exported executable.
    pub entries: Vec<ArtifactEntry>,
}

impl Meta {
    /// Parse `<dir>/meta.json` (the schema `save` writes).
    pub fn load(dir: &str) -> Result<Meta> {
        let root = PathBuf::from(dir);
        let text = std::fs::read_to_string(root.join("meta.json"))
            .with_context(|| format!("reading {dir}/meta.json — run \
                                      `make artifacts` first"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse meta.json: {e}"))?;
        let window = j.get("window").and_then(Json::as_usize)
            .context("window")?;
        let mut entries = Vec::new();
        for e in j.get("entries").and_then(Json::as_arr).context("entries")? {
            entries.push(ArtifactEntry {
                name: e.get("name").and_then(Json::as_str)
                    .context("name")?.to_string(),
                model: e.get("model").and_then(Json::as_str)
                    .context("model")?.to_string(),
                bits: e.get("bits").and_then(Json::as_usize)
                    .context("bits")? as u32,
                batch: e.get("batch").and_then(Json::as_usize)
                    .context("batch")?,
                window: e.get("window").and_then(Json::as_usize)
                    .context("window")?,
                time_steps: e.get("time_steps").and_then(Json::as_usize)
                    .context("time_steps")?,
                pallas: e.get("pallas").and_then(Json::as_bool)
                    .unwrap_or(false),
                file: e.get("file").and_then(Json::as_str)
                    .context("file")?.to_string(),
            });
        }
        Ok(Meta { root, window, entries })
    }

    /// Find the artifact for (model, bits, batch), preferring the pallas
    /// build (the kernel-bearing HLO).
    pub fn find(&self, model: &str, bits: u32, batch: usize)
                -> Option<&ArtifactEntry> {
        self.entries.iter()
            .filter(|e| e.model == model && e.bits == bits
                        && e.batch == batch)
            .max_by_key(|e| e.pallas)
    }

    /// Batch sizes available for (model, bits), ascending.
    pub fn batches(&self, model: &str, bits: u32) -> Vec<usize> {
        let mut b: Vec<usize> = self.entries.iter()
            .filter(|e| e.model == model && e.bits == bits)
            .map(|e| e.batch)
            .collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    /// Absolute path of an entry's artifact file.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.root.join(&e.file)
    }

    /// Write `meta.json` into `self.root` — the writer half of the
    /// artifact contract. The native backend's exporter uses this; the
    /// python AOT export writes the same schema.
    pub fn save(&self) -> Result<PathBuf> {
        use std::collections::BTreeMap;
        let mut entries = Vec::new();
        for e in &self.entries {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(e.name.clone()));
            o.insert("model".to_string(), Json::Str(e.model.clone()));
            o.insert("bits".to_string(), Json::Num(e.bits as f64));
            o.insert("batch".to_string(), Json::Num(e.batch as f64));
            o.insert("window".to_string(), Json::Num(e.window as f64));
            o.insert("time_steps".to_string(),
                     Json::Num(e.time_steps as f64));
            o.insert("pallas".to_string(), Json::Bool(e.pallas));
            o.insert("file".to_string(), Json::Str(e.file.clone()));
            entries.push(Json::Obj(o));
        }
        let mut top = BTreeMap::new();
        top.insert("window".to_string(), Json::Num(self.window as f64));
        top.insert("entries".to_string(), Json::Arr(entries));
        let path = self.root.join("meta.json");
        std::fs::write(&path, Json::Obj(top).to_string())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }

    /// Where the artifact set keeps its pore model.
    pub fn pore_model_path(&self) -> PathBuf {
        self.root.join("pore_model.json")
    }
}

/// Default artifacts directory (relative to the repo root).
pub fn default_artifacts_dir() -> String {
    std::env::var("HELIX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
}

/// True when artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_available(dir: &str) -> bool {
    Path::new(dir).join("meta.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_meta(dir: &Path) {
        let meta = r#"{"window": 300, "alphabet": "ACGT-", "blank": 4,
          "entries": [
            {"name": "guppy_32_b1", "model": "guppy", "bits": 32,
             "batch": 1, "window": 300, "time_steps": 145,
             "pallas": true, "file": "guppy_32_b1.hlo.txt"},
            {"name": "guppy_32_b8", "model": "guppy", "bits": 32,
             "batch": 8, "window": 300, "time_steps": 145,
             "pallas": false, "file": "guppy_32_b8.hlo.txt"}
          ]}"#;
        let mut f = std::fs::File::create(dir.join("meta.json")).unwrap();
        f.write_all(meta.as_bytes()).unwrap();
    }

    #[test]
    fn parses_and_finds() {
        let dir = std::env::temp_dir().join("helix_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_meta(&dir);
        let m = Meta::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.window, 300);
        assert_eq!(m.entries.len(), 2);
        let e = m.find("guppy", 32, 1).unwrap();
        assert!(e.pallas);
        assert_eq!(e.time_steps, 145);
        assert_eq!(m.batches("guppy", 32), vec![1, 8]);
        assert!(m.find("guppy", 5, 1).is_none());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Meta::load("/nonexistent/helix").is_err());
        assert!(!artifacts_available("/nonexistent/helix"));
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("helix_meta_save_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_meta(&dir);
        let m = Meta::load(dir.to_str().unwrap()).unwrap();
        let out = std::env::temp_dir().join("helix_meta_save_test_out");
        std::fs::create_dir_all(&out).unwrap();
        let saved = Meta { root: out.clone(), ..m.clone() };
        saved.save().unwrap();
        let back = Meta::load(out.to_str().unwrap()).unwrap();
        assert_eq!(back.window, m.window);
        assert_eq!(back.entries.len(), m.entries.len());
        for (a, b) in back.entries.iter().zip(&m.entries) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.model, b.model);
            assert_eq!(a.bits, b.bits);
            assert_eq!(a.batch, b.batch);
            assert_eq!(a.window, b.window);
            assert_eq!(a.time_steps, b.time_steps);
            assert_eq!(a.pallas, b.pallas);
            assert_eq!(a.file, b.file);
        }
    }
}
