//! Synthetic genome substrate: the rust twin of `python/compile/pore.py`
//! (DESIGN.md §Substitutions — stands in for the R9.4 datasets of Table 4).
//! The pore model table is loaded from `artifacts/pore_model.json` written by
//! the python build path, so both languages synthesize statistically
//! identical signals.

pub mod dataset;
pub mod pore;
pub mod synth;

pub use pore::PoreModel;
pub use synth::{random_genome, Read};
