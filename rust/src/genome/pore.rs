//! Pore model: k-mer current table + dwell/noise parameters, shared with the
//! python training path through `artifacts/pore_model.json`.

use anyhow::{Context, Result};

use crate::util::{json::Json, rng::Rng};

/// k-mer current table + dwell/noise parameters of a simulated pore.
#[derive(Clone, Debug)]
pub struct PoreModel {
    /// k-mer context length.
    pub k: usize,
    /// 4^k standardized current levels, indexed by k-mer id.
    pub levels: Vec<f32>,
    /// minimum samples the pore dwells on one base.
    pub dwell_min: u32,
    /// maximum samples the pore dwells on one base.
    pub dwell_max: u32,
    /// gaussian noise added to each emitted sample.
    pub noise_sigma: f32,
    /// samples per base-calling window (the model input length).
    pub window: usize,
}

impl PoreModel {
    /// Load the `pore_model.json` schema written by `save` (and by the
    /// python training path).
    pub fn load(path: &str) -> Result<PoreModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading pore model {path}"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {path}: {e}"))?;
        let k = j.get("k").and_then(Json::as_usize).context("k")?;
        let levels = j.get("levels").and_then(Json::as_f32_vec)
            .context("levels")?;
        anyhow::ensure!(levels.len() == 4usize.pow(k as u32),
                        "pore table size {} != 4^{k}", levels.len());
        Ok(PoreModel {
            k,
            levels,
            dwell_min: j.get("dwell_min").and_then(Json::as_usize)
                .context("dwell_min")? as u32,
            dwell_max: j.get("dwell_max").and_then(Json::as_usize)
                .context("dwell_max")? as u32,
            noise_sigma: j.get("noise_sigma").and_then(Json::as_f64)
                .context("noise_sigma")? as f32,
            window: j.get("window").and_then(Json::as_usize)
                .context("window")?,
        })
    }

    /// Serialize to the `pore_model.json` schema `load` reads — the
    /// writer half of the artifact contract, used by the native
    /// backend's exporter (`runtime::native::write_artifacts`).
    pub fn save(&self, path: &str) -> Result<()> {
        use std::collections::BTreeMap;
        let mut o = BTreeMap::new();
        o.insert("k".to_string(), Json::Num(self.k as f64));
        o.insert("levels".to_string(),
                 Json::Arr(self.levels.iter()
                           .map(|&x| Json::Num(x as f64)).collect()));
        o.insert("dwell_min".to_string(),
                 Json::Num(self.dwell_min as f64));
        o.insert("dwell_max".to_string(),
                 Json::Num(self.dwell_max as f64));
        o.insert("noise_sigma".to_string(),
                 Json::Num(self.noise_sigma as f64));
        o.insert("window".to_string(), Json::Num(self.window as f64));
        std::fs::write(path, Json::Obj(o).to_string())
            .with_context(|| format!("writing pore model {path}"))
    }

    /// Synthetic fallback with the same construction as
    /// `pore.PoreModel.default` (used by unit tests and pure-sim paths that
    /// must not depend on artifacts being built).
    pub fn synthetic(seed: u64) -> PoreModel {
        let k = 4usize;
        let mut rng = Rng::new(seed);
        let mut levels: Vec<f32> =
            (0..4usize.pow(k as u32)).map(|_| rng.normal() as f32).collect();
        let mean = levels.iter().sum::<f32>() / levels.len() as f32;
        let var = levels.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / levels.len() as f32;
        let std = var.sqrt();
        for l in levels.iter_mut() {
            *l = (*l - mean) / std;
        }
        PoreModel {
            k,
            levels,
            dwell_min: 6,
            dwell_max: 12,
            noise_sigma: 0.22,
            window: 300,
        }
    }

    /// k-mer id of the context ENDING at base `i` (edges clamp by repeating
    /// the first base) — identical convention to python's `kmer_ids`.
    pub fn kmer_id(&self, seq: &[u8], i: usize) -> usize {
        let mut id = 0usize;
        for j in 0..self.k {
            let pos = (i + j + 1).checked_sub(self.k)
                .map(|p| p.min(seq.len() - 1))
                .unwrap_or(0);
            id = id * 4 + seq[pos] as usize;
        }
        id
    }

    /// Emit a raw signal for `seq`. Returns (signal, owner) where
    /// `owner[s]` is the base index held by the pore at sample `s`.
    pub fn simulate(&self, seq: &[u8], rng: &mut Rng) -> (Vec<f32>, Vec<u32>) {
        let mut signal = Vec::with_capacity(seq.len() * 9);
        let mut owner = Vec::with_capacity(seq.len() * 9);
        for i in 0..seq.len() {
            let level = self.levels[self.kmer_id(seq, i)];
            let dwell = rng.range(self.dwell_min as i64,
                                  self.dwell_max as i64) as usize;
            for _ in 0..dwell {
                signal.push(level
                    + (rng.normal() as f32) * self.noise_sigma);
                owner.push(i as u32);
            }
        }
        // normalize per read, as the paper does (§5.2)
        let n = signal.len() as f32;
        let mean = signal.iter().sum::<f32>() / n;
        let var = signal.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
        let std = var.sqrt().max(1e-8);
        for s in signal.iter_mut() {
            *s = (*s - mean) / std;
        }
        (signal, owner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn save_load_roundtrip() {
        let pm = PoreModel::synthetic(7);
        let dir = std::env::temp_dir().join("helix_pore_save_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pore_model.json");
        let path = path.to_str().unwrap();
        pm.save(path).unwrap();
        let back = PoreModel::load(path).unwrap();
        assert_eq!(back.k, pm.k);
        assert_eq!(back.levels, pm.levels);
        assert_eq!(back.dwell_min, pm.dwell_min);
        assert_eq!(back.dwell_max, pm.dwell_max);
        assert_eq!(back.window, pm.window);
        assert!((back.noise_sigma - pm.noise_sigma).abs() < 1e-7);
    }

    #[test]
    fn synthetic_table_is_standardized() {
        let pm = PoreModel::synthetic(7);
        assert_eq!(pm.levels.len(), 256);
        let mean: f32 = pm.levels.iter().sum::<f32>() / 256.0;
        assert!(mean.abs() < 1e-3);
    }

    #[test]
    fn kmer_id_last_base_is_lsb() {
        let pm = PoreModel::synthetic(7);
        let seq = vec![0u8, 1, 2, 3, 0, 1];
        for i in 0..seq.len() {
            assert_eq!(pm.kmer_id(&seq, i) % 4, seq[i] as usize);
            assert!(pm.kmer_id(&seq, i) < 256);
        }
    }

    #[test]
    fn simulate_invariants() {
        let pm = PoreModel::synthetic(7);
        prop::check("pore simulate", 20, |rng, _| {
            let seq = prop::dna(rng, 10, 80);
            let (sig, owner) = pm.simulate(&seq, rng);
            assert_eq!(sig.len(), owner.len());
            // pore moves monotonically forward, one base at a time
            for w in owner.windows(2) {
                assert!(w[1] == w[0] || w[1] == w[0] + 1);
            }
            assert_eq!(*owner.last().unwrap() as usize, seq.len() - 1);
            // dwell bounds
            let mut counts = vec![0u32; seq.len()];
            for &o in &owner {
                counts[o as usize] += 1;
            }
            assert!(counts.iter().all(
                |&c| c >= pm.dwell_min && c <= pm.dwell_max));
            // normalized
            let mean: f32 = sig.iter().sum::<f32>() / sig.len() as f32;
            assert!(mean.abs() < 1e-3);
        });
    }
}
