//! Windowing: chop read signals into fixed-size model inputs with
//! ground-truth labels (rust twin of `pore.windows_from_read`).

use super::synth::Read;

/// One model input window plus its ground truth.
#[derive(Clone, Debug)]
pub struct Window {
    /// id of the read this window was cut from.
    pub read_id: usize,
    /// offset of the window start in the read signal.
    pub sample_start: usize,
    /// offset of the first labeled base within the read.
    pub base_start: usize,
    /// raw signal slice, exactly the model input length.
    pub signal: Vec<f32>,
    /// ground-truth bases fully contained in the window.
    pub truth: Vec<u8>,
}

/// Chop one read into windows of `window` samples every `hop` samples.
/// A base is labeled iff ALL its samples fall inside the window.
pub fn windows_from_read(read: &Read, window: usize, hop: usize)
                         -> Vec<Window> {
    let mut out = Vec::new();
    if read.signal.len() < window {
        return out;
    }
    let mut start = 0usize;
    while start + window <= read.signal.len() {
        let sl = &read.owner[start..start + window];
        let mut lo = sl[0] as usize;
        let mut hi = *sl.last().unwrap() as usize;
        if start > 0 && read.owner[start - 1] as usize == lo {
            lo += 1;
        }
        if start + window < read.owner.len()
            && read.owner[start + window] as usize == hi
        {
            hi = hi.saturating_sub(1);
        }
        if hi >= lo {
            out.push(Window {
                read_id: read.id,
                sample_start: start,
                base_start: lo,
                signal: read.signal[start..start + window].to_vec(),
                truth: read.seq[lo..=hi].to_vec(),
            });
        }
        start += hop;
    }
    out
}

/// The per-signal voting group of the paper (§2.2: "⌊L/T⌋ reads containing
/// the same signal element vote"): all windows of one read whose base spans
/// overlap a given center window.
pub fn overlapping_groups(windows: &[Window]) -> Vec<(usize, Vec<usize>)> {
    let mut groups = Vec::new();
    for (i, w) in windows.iter().enumerate() {
        let lo = w.base_start;
        let hi = w.base_start + w.truth.len();
        let members: Vec<usize> = windows.iter().enumerate()
            .filter(|(_, o)| {
                o.read_id == w.read_id
                    && o.base_start < hi
                    && o.base_start + o.truth.len() > lo
            })
            .map(|(j, _)| j)
            .collect();
        if members.len() >= 2 {
            groups.push((i, members));
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::pore::PoreModel;
    use crate::util::rng::Rng;

    fn mk_read(len: usize, seed: u64) -> Read {
        let pm = PoreModel::synthetic(7);
        let mut rng = Rng::new(seed);
        let seq: Vec<u8> = (0..len).map(|_| rng.base()).collect();
        let (signal, owner) = pm.simulate(&seq, &mut rng);
        Read { id: 0, start: 0, seq, signal, owner }
    }

    #[test]
    fn window_truth_matches_read() {
        let read = mk_read(120, 3);
        let ws = windows_from_read(&read, 300, 100);
        assert!(!ws.is_empty());
        for w in &ws {
            assert_eq!(w.signal.len(), 300);
            assert_eq!(&read.seq[w.base_start..w.base_start + w.truth.len()],
                       &w.truth[..]);
            assert!(!w.truth.is_empty());
        }
    }

    #[test]
    fn short_read_yields_nothing() {
        let read = mk_read(10, 4);
        assert!(windows_from_read(&read, 10_000, 100).is_empty());
    }

    #[test]
    fn hop_controls_window_count() {
        let read = mk_read(200, 5);
        let dense = windows_from_read(&read, 300, 50).len();
        let sparse = windows_from_read(&read, 300, 200).len();
        assert!(dense > sparse);
    }

    #[test]
    fn groups_are_overlapping() {
        let read = mk_read(200, 6);
        let ws = windows_from_read(&read, 300, 100);
        let groups = overlapping_groups(&ws);
        assert!(!groups.is_empty());
        for (center, members) in groups {
            assert!(members.contains(&center));
            assert!(members.len() >= 2);
        }
    }
}
