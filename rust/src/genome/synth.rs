//! Synthetic genomes and sequencing runs: reads with coverage, the Table 4
//! stand-in (DESIGN.md §Substitutions).

use crate::util::rng::Rng;

use super::pore::PoreModel;

/// Uniform random genome over {A,C,G,T}.
pub fn random_genome(n: usize, rng: &mut Rng) -> Vec<u8> {
    (0..n).map(|_| rng.base()).collect()
}

/// A simulated nanopore read: the true subsequence plus its raw signal.
#[derive(Clone, Debug)]
pub struct Read {
    /// run-unique read id (what `CalledRead::read_id` answers).
    pub id: usize,
    /// start offset in the genome.
    pub start: usize,
    /// ground-truth bases.
    pub seq: Vec<u8>,
    /// raw normalized signal.
    pub signal: Vec<f32>,
    /// `owner[s]` = index into `seq` of the base held at sample `s`.
    pub owner: Vec<u32>,
}

/// Parameters of a simulated sequencing run.
#[derive(Clone, Copy, Debug)]
pub struct RunSpec {
    /// genome length in bases.
    pub genome_len: usize,
    /// target coverage (mean reads crossing a position), 30-50 in the paper.
    pub coverage: usize,
    /// shortest read to draw.
    pub read_len_min: usize,
    /// longest read to draw.
    pub read_len_max: usize,
    /// rng seed: equal specs simulate bit-identical runs.
    pub seed: u64,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            genome_len: 10_000,
            coverage: 30,
            read_len_min: 300,
            read_len_max: 600,
            seed: 7,
        }
    }
}

/// A full sequencing run: one genome + enough reads for the coverage target.
#[derive(Clone, Debug)]
pub struct SequencingRun {
    /// the simulated ground-truth genome.
    pub genome: Vec<u8>,
    /// reads drawn from it, sorted by genome start position.
    pub reads: Vec<Read>,
}

impl SequencingRun {
    /// Simulate a run: draw reads to the coverage target and emit each
    /// one's pore signal. Deterministic in `spec.seed`.
    pub fn simulate(pm: &PoreModel, spec: RunSpec) -> SequencingRun {
        let mut rng = Rng::new(spec.seed);
        let genome = random_genome(spec.genome_len, &mut rng);
        let mean_len = (spec.read_len_min + spec.read_len_max) / 2;
        let n_reads = (spec.genome_len * spec.coverage / mean_len).max(1);
        let mut reads = Vec::with_capacity(n_reads);
        for id in 0..n_reads {
            let len = rng.range(spec.read_len_min as i64,
                                spec.read_len_max as i64) as usize;
            let len = len.min(spec.genome_len);
            let start = rng.below(spec.genome_len - len + 1);
            let seq = genome[start..start + len].to_vec();
            let (signal, owner) = pm.simulate(&seq, &mut rng);
            reads.push(Read { id, start, seq, signal, owner });
        }
        // present reads in genome order (the voting stage relies on known
        // ordering, as the paper notes for read votes in Fig 19)
        reads.sort_by_key(|r| r.start);
        SequencingRun { genome, reads }
    }

    /// Empirical mean coverage across genome positions.
    pub fn mean_coverage(&self) -> f64 {
        let mut cov = vec![0u32; self.genome.len()];
        for r in &self.reads {
            for c in cov[r.start..r.start + r.seq.len()].iter_mut() {
                *c += 1;
            }
        }
        cov.iter().map(|&c| c as f64).sum::<f64>() / cov.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_reaches_target_coverage() {
        let pm = PoreModel::synthetic(7);
        let run = SequencingRun::simulate(&pm, RunSpec {
            genome_len: 4000, coverage: 10, ..Default::default()
        });
        let cov = run.mean_coverage();
        assert!(cov > 6.0 && cov < 14.0, "coverage {cov}");
    }

    #[test]
    fn reads_match_genome() {
        let pm = PoreModel::synthetic(7);
        let run = SequencingRun::simulate(&pm, RunSpec {
            genome_len: 2000, coverage: 5, ..Default::default()
        });
        for r in &run.reads {
            assert_eq!(&run.genome[r.start..r.start + r.seq.len()], &r.seq[..]);
            assert_eq!(r.signal.len(), r.owner.len());
        }
    }

    #[test]
    fn reads_sorted_by_start() {
        let pm = PoreModel::synthetic(7);
        let run = SequencingRun::simulate(&pm, RunSpec {
            genome_len: 3000, coverage: 8, ..Default::default()
        });
        assert!(run.reads.windows(2).all(|w| w[0].start <= w[1].start));
    }
}
