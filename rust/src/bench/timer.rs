//! Micro-benchmark scaffold: warmup + timed iterations + robust stats.
//! In-tree replacement for criterion (offline build).

use std::time::Instant;

/// Timing summary of one benchmarked closure.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// timed iterations (after warmup).
    pub iters: usize,
    /// mean per-iteration time in ns.
    pub mean_ns: f64,
    /// median per-iteration time in ns (the headline number).
    pub median_ns: f64,
    /// 95th-percentile per-iteration time in ns.
    pub p95_ns: f64,
}

impl Stats {
    /// Human-readable median per-iteration time.
    pub fn per_iter(&self) -> String {
        fmt_ns(self.median_ns)
    }
}

/// Render nanoseconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f` adaptively: warm up, then run until ~`budget_ms` of samples.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> Stats {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let target = (budget_ms as f64 * 1e6 / once).clamp(3.0, 10_000.0) as usize;
    let mut samples = Vec::with_capacity(target);
    for _ in 0..target {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let stats = Stats {
        iters: samples.len(),
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        p95_ns: samples[(samples.len() - 1) * 95 / 100],
    };
    println!("{name:<44} {:>12}/iter  (n={}, mean {}, p95 {})",
             stats.per_iter(), stats.iters, fmt_ns(stats.mean_ns),
             fmt_ns(stats.p95_ns));
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let s = bench("noop-ish", 5, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.iters >= 3);
        assert!(s.mean_ns > 0.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
