//! Regenerate every table and figure of the paper's evaluation.
//!
//! Each function prints the same rows/series the paper reports (DESIGN.md
//! experiment index). Training-derived panels (Figs 2/7/10/21/22) read the
//! CSVs produced by `make artifacts`; architecture panels come from the PIM
//! simulator; Fig 23 runs the full basecall+assembly pipeline end-to-end
//! through the PJRT runtime.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::basecall::accuracy::evaluate_group;
use crate::basecall::edit::identity;
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::genome::pore::PoreModel;
use crate::genome::synth::{RunSpec, SequencingRun};
use crate::pim::adc::{CmosAdc, SotAdcArray};
use crate::pim::device::{reference_ladder, vcma_write_threshold, DeviceParams};
use crate::pim::mapper::Topology;
use crate::pim::power;
use crate::pim::schemes::{evaluate, evaluate_with_adc, Scheme};
use crate::pim::variation;
use crate::pipeline;

/// train_results.csv rows keyed by (model, bits, seat).
type TrainResults = BTreeMap<(String, u32, bool), (f64, f64)>;

fn load_train_results(dir: &str) -> Result<TrainResults> {
    let text = std::fs::read_to_string(format!("{dir}/train_results.csv"))
        .context("train_results.csv missing — run `make artifacts`")?;
    let mut out = TrainResults::new();
    for line in text.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() == 5 {
            out.insert(
                (f[0].to_string(), f[1].parse()?, f[2] != "0"),
                (f[3].parse()?, f[4].parse()?),
            );
        }
    }
    Ok(out)
}

fn hr(title: &str) {
    println!("\n=== {title} ===");
}

/// Fig 2: read vs vote accuracy of the evaluated base-callers.
pub fn fig2(dir: &str) -> Result<()> {
    hr("Figure 2: base-caller comparison (accuracy & modeled GPU speed)");
    let tr = load_train_results(dir)?;
    println!("{:<10} {:>10} {:>10} {:>14}", "model", "read acc", "vote acc",
             "GPU kbp/s");
    for topo in Topology::all() {
        let (ra, va) = tr.get(&(topo.name.to_string(), 32, false))
            .copied().unwrap_or((f64::NAN, f64::NAN));
        let e = evaluate(Scheme::Gpu, &topo, 10);
        println!("{:<10} {:>10.4} {:>10.4} {:>14.0}", topo.name, ra, va,
                 e.throughput() / 1e3);
    }
    Ok(())
}

/// Fig 3: random vs systematic error split of read votes.
pub fn fig3() -> Result<()> {
    hr("Figure 3: random vs systematic errors under read voting");
    let truth: Vec<u8> = vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1];
    let mut random = truth.clone();
    random[4] = 2; // one read wrong -> outvoted
    let acc_r = evaluate_group(&[random, truth.clone(), truth.clone()],
                               &truth);
    let mut sys = truth.clone();
    sys[4] = 2; // every read wrong the same way -> survives
    let acc_s = evaluate_group(&[sys.clone(), sys.clone(), sys], &truth);
    println!("random error   : read acc {:.3} -> vote acc {:.3} (corrected: {})",
             acc_r.read_acc, acc_r.vote_acc, acc_r.random_errors);
    println!("systematic err : read acc {:.3} -> vote acc {:.3} (surviving: {})",
             acc_s.read_acc, acc_s.vote_acc, acc_s.systematic_errors);
    Ok(())
}

/// Fig 7: quantization accuracy sweep (with and without SEAT).
pub fn fig7(dir: &str) -> Result<()> {
    hr("Figure 7: accuracy & speed of quantized Guppy (no SEAT, GPU)");
    let tr = load_train_results(dir)?;
    let topo = Topology::guppy();
    println!("{:>5} {:>10} {:>10} {:>12} {:>8}", "bits", "read acc",
             "vote acc", "GPU kbp/s", "speedup");
    let base = evaluate(Scheme::Gpu, &topo, 10).throughput();
    for bits in [32u32, 16, 8, 5, 4, 3] {
        let acc = tr.get(&("guppy".into(), bits, false)).copied();
        // GPU rate doubles per precision halving
        let rate = crate::pim::schemes::GPU_MAC_RATE_FP32
            * (32.0 / bits.max(4) as f64);
        let t = topo.macs_per_base() / rate
            + crate::pim::schemes::GPU_CTC_PER_STEP * topo.ctc_steps as f64
              / topo.bases_per_window
            + crate::pim::schemes::GPU_VOTE_PER_BASE;
        let tp = 1.0 / t;
        match acc {
            Some((ra, va)) => println!(
                "{bits:>5} {ra:>10.4} {va:>10.4} {:>12.0} {:>7.2}x",
                tp / 1e3, tp / base),
            None => println!("{bits:>5} {:>10} {:>10} {:>12.0} {:>7.2}x",
                             "-", "-", tp / 1e3, tp / base),
        }
    }
    Ok(())
}

/// Fig 8: power breakdown of an NVM dot-product engine.
pub fn fig8() -> Result<()> {
    hr("Figure 8: ADC share of NVM dot-product engine power/area");
    println!("{:<10} {:>12} {:>12}", "tech", "ADC power %", "ADC area %");
    for tech in ["reram", "pcm", "stt"] {
        let (p, a) = power::fig8_breakdown(tech);
        println!("{tech:<10} {:>11.1}% {:>11.1}%", p * 100.0, a * 100.0);
    }
    Ok(())
}

/// Fig 9: latency breakdown (DNN / CTC decode / read vote).
pub fn fig9() -> Result<()> {
    hr("Figure 9: execution-time breakdown of 16-bit quantized Guppy (GPU)");
    let topo = Topology::guppy();
    let dnn = topo.macs_per_base()
        / (crate::pim::schemes::GPU_MAC_RATE_FP32 * 2.0);
    let ctc = crate::pim::schemes::GPU_CTC_PER_STEP * topo.ctc_steps as f64
        / topo.bases_per_window;
    let vote = crate::pim::schemes::GPU_VOTE_PER_BASE;
    let total = dnn + ctc + vote;
    println!("Conv+GRU+FC : {:>5.1}%  (paper: 46.3%)", dnn / total * 100.0);
    println!("CTC decode  : {:>5.1}%  (paper: 16.7%)", ctc / total * 100.0);
    println!("read voting : {:>5.1}%  (paper: 37.0%)", vote / total * 100.0);
    Ok(())
}

/// Fig 10: training with the plain vs SEAT-aware loss.
pub fn fig10(dir: &str) -> Result<()> {
    hr("Figure 10: training with loss_0 vs loss_1 (SEAT)");
    let text = std::fs::read_to_string(format!("{dir}/curves_fig10.csv"))
        .context("curves_fig10.csv missing — run `make artifacts`")?;
    let mut series: BTreeMap<String, Vec<(u32, f64, f64)>> = BTreeMap::new();
    for line in text.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() == 5 {
            series.entry(f[0].to_string()).or_default()
                .push((f[1].parse()?, f[2].parse()?, f[4].parse()?));
        }
    }
    for (name, rows) in series {
        println!("--- {name} (step, loss, vote_acc)");
        for (s, l, v) in rows {
            println!("  {s:>5} {l:>9.3} {v:>7.4}");
        }
    }
    Ok(())
}

/// Fig 13: VCMA write-threshold vs read bit-line voltage.
pub fn fig13() -> Result<()> {
    hr("Figure 13: SOT-MRAM write threshold vs RBL voltage (VCMA)");
    println!("{:>10} {:>16}", "V_RBL (V)", "write Vth (V)");
    for v in reference_ladder(8) {
        println!("{v:>10.2} {:>16.3}", vcma_write_threshold(v));
    }
    Ok(())
}

/// Fig 14: SOT-MRAM ADC transfer function (thermometer code).
pub fn fig14() -> Result<()> {
    hr("Figure 14: switching probability vs write voltage x pulse duration");
    let d = DeviceParams::default();
    print!("{:>8}", "V \\ ns");
    let durations = [0.5, 1.0, 1.56, 2.5, 5.0];
    for t in durations {
        print!(" {t:>7.2}");
    }
    println!();
    for i in 0..6 {
        let v = 0.45 + 0.05 * i as f64;
        print!("{v:>8.2}");
        for t in durations {
            print!(" {:>7.3}", d.switch_probability(v, t * 1e-9));
        }
        println!();
    }
    Ok(())
}

/// Fig 15: write-duration Monte-Carlo histogram.
pub fn fig15() -> Result<()> {
    hr("Figure 15: write-duration distribution at 60F^2 (Monte-Carlo)");
    let st = variation::duration_mc(60.0, variation::ADC_WRITE_VOLTAGE,
                                    200_000, 7);
    println!("samples {}  mean {:.3} ns  sigma {:.3} ns  p99.9 {:.3} ns  \
              worst(1e10 extrapolated) {:.3} ns",
             st.samples, st.mean_ns, st.sigma_ns, st.p999_ns, st.worst_ns);
    let max = st.histogram.iter().map(|&(_, c)| c).max().unwrap_or(1);
    for (ns, count) in &st.histogram {
        if *count > 0 {
            let bar = "#".repeat(1 + count * 40 / max);
            println!("{ns:>7.3} ns |{bar}");
        }
    }
    Ok(())
}

/// Fig 16: cell size vs worst-case write duration.
pub fn fig16() -> Result<()> {
    hr("Figure 16: worst-case write duration vs cell size");
    let sizes = [20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0];
    let curve = variation::worst_case_vs_cell_size(
        &sizes, variation::ADC_WRITE_VOLTAGE, 60_000, 7);
    println!("{:>10} {:>16}", "cell F^2", "worst-case (ns)");
    for (s, w) in curve {
        let marker = if w <= 1.56 { "  <= 1.56ns target" } else { "" };
        println!("{s:>10.0} {w:>16.3}{marker}");
    }
    println!("(the paper selects 60F^2; §4.2)");
    Ok(())
}

/// Fig 21: SEAT vs naive quantization on Guppy (vote accuracy).
pub fn fig21(dir: &str) -> Result<()> {
    hr("Figure 21: SEAT vs naive quantization on Guppy (vote accuracy)");
    let tr = load_train_results(dir)?;
    println!("{:>5} {:>12} {:>12}", "bits", "no SEAT", "SEAT");
    let fp = tr.get(&("guppy".into(), 32, false)).map(|x| x.1);
    for bits in [3u32, 4, 5, 8, 16] {
        let ns = tr.get(&("guppy".into(), bits, false)).map(|x| x.1);
        let se = tr.get(&("guppy".into(), bits, true)).map(|x| x.1);
        println!("{bits:>5} {:>12} {:>12}",
                 ns.map_or("-".into(), |v| format!("{v:.4}")),
                 se.map_or("-".into(), |v| format!("{v:.4}")));
    }
    if let Some(fp) = fp {
        println!("fp32 reference vote accuracy: {fp:.4}");
    }
    Ok(())
}

/// Fig 22: SEAT quantization across base-callers (vote accuracy).
pub fn fig22(dir: &str) -> Result<()> {
    hr("Figure 22: quantization with SEAT across base-callers (vote acc)");
    let tr = load_train_results(dir)?;
    println!("{:<10} {:>8} {:>8} {:>8} {:>8} {:>8}",
             "model", "fp32", "8-bit", "5-bit", "4-bit", "3-bit");
    for model in ["guppy", "scrappie", "chiron"] {
        let g = |bits: u32, seat: bool| {
            tr.get(&(model.to_string(), bits, seat))
                .map_or("-".to_string(), |x| format!("{:.4}", x.1))
        };
        println!("{model:<10} {:>8} {:>8} {:>8} {:>8} {:>8}",
                 g(32, false), g(8, true), g(5, true), g(4, true),
                 g(3, true));
    }
    Ok(())
}

/// Fig 23 work-horse: basecall a sequencing run end-to-end and push it
/// through overlap/assembly/mapping/polish.
pub fn pipeline_accuracy(dir: &str, model: &str, bits: u32,
                         spec: RunSpec) -> Result<(f64, f64, f64)> {
    let pm = PoreModel::load(
        &format!("{dir}/pore_model.json"))?;
    let run = SequencingRun::simulate(&pm, spec);
    let mut coord = Coordinator::new(CoordinatorConfig {
        model: model.into(),
        bits,
        artifacts_dir: dir.into(),
        ..Default::default()
    })?;
    // stream completed reads out while submitting (keeps the bounded
    // pipeline moving on arbitrarily large runs)
    let mut called = Vec::new();
    for r in &run.reads {
        coord.submit(r);
        called.extend(coord.drain_ready());
    }
    called.extend(coord.finish()?);
    called.sort_by_key(|c| c.read_id);
    // base-call accuracy: identity of each called read vs its truth
    let mut acc = 0.0;
    let mut n = 0;
    let mut called_seqs = Vec::new();
    for c in &called {
        let truth = &run.reads.iter().find(|r| r.id == c.read_id)
            .unwrap().seq;
        // called read covers the interior of the truth (window trimming);
        // compare against the aligned prefix window
        let t = &truth[..truth.len().min(c.seq.len() + 8)];
        acc += identity(&c.seq, t);
        n += 1;
        called_seqs.push(c.seq.clone());
    }
    let base_call = acc / n.max(1) as f64;
    // draft assembly + polish
    let draft = pipeline::assemble(&called_seqs, 12);
    let polished = pipeline::polish(&draft, &called_seqs);
    let draft_acc = best_window_identity(&draft, &run.genome);
    let polished_acc = best_window_identity(&polished, &run.genome);
    Ok((base_call, draft_acc, polished_acc))
}

/// Identity of `seq` against its best-matching window of `genome`.
fn best_window_identity(seq: &[u8], genome: &[u8]) -> f64 {
    if seq.is_empty() {
        return 0.0;
    }
    let idx = pipeline::mapping::DraftIndex::build(genome);
    match pipeline::mapping::map_read(seq, genome, &idx) {
        Some(m) => m.identity,
        None => identity(seq, &genome[..seq.len().min(genome.len())]),
    }
}

/// Fig 23: end-to-end pipeline accuracy (basecall through polish).
pub fn fig23(dir: &str) -> Result<()> {
    hr("Figure 23: base-call / draft / polished accuracy through the \
        full pipeline");
    let spec = RunSpec {
        genome_len: 1200,
        coverage: 6,
        read_len_min: 200,
        read_len_max: 320,
        seed: 21,
    };
    println!("{:<16} {:>10} {:>10} {:>10}", "config", "base-call", "draft",
             "polished");
    for (model, bits) in [("guppy", 32u32), ("guppy", 5), ("guppy", 4)] {
        match pipeline_accuracy(dir, model, bits, spec) {
            Ok((b, d, p)) => println!(
                "{:<16} {b:>10.4} {d:>10.4} {p:>10.4}",
                format!("{model}-{bits}bit")),
            Err(e) => println!("{:<16} unavailable: {e}",
                               format!("{model}-{bits}bit")),
        }
    }
    Ok(())
}

/// Fig 24: throughput / power / area across the eight schemes.
pub fn fig24() -> Result<()> {
    hr("Figure 24: throughput / per-Watt / per-mm^2 across schemes");
    for topo in Topology::all() {
        println!("--- {}", topo.name);
        println!("{:<8} {:>12} {:>14} {:>14} {:>9} {:>9}",
                 "scheme", "kbp/s", "bp/s/W", "bp/s/mm2", "vs ISAAC",
                 "step");
        let base = evaluate(Scheme::Isaac, &topo, 10);
        let mut prev: Option<f64> = None;
        for s in Scheme::all() {
            let e = evaluate(s, &topo, 10);
            let vs = e.throughput() / base.throughput();
            let step = prev.map_or(String::from("-"),
                                   |p| format!("{:+.1}%",
                                               (e.throughput() / p - 1.0)
                                               * 100.0));
            println!("{:<8} {:>12.1} {:>14.1} {:>14.1} {:>8.2}x {:>9}",
                     s.name(), e.throughput() / 1e3,
                     e.throughput_per_watt(), e.throughput_per_mm2(), vs,
                     step);
            if matches!(s, Scheme::Isaac | Scheme::Q16 | Scheme::Seat
                        | Scheme::Adc | Scheme::Ctc | Scheme::Helix) {
                prev = Some(e.throughput());
            }
        }
    }
    use crate::pim::schemes::geomean_ratio;
    println!("\ngeomean Helix vs ISAAC:  throughput {:.2}x (paper 6x)   \
              /W {:.2}x (paper 11.9x)   /mm2 {:.2}x (paper 7.5x)",
             geomean_ratio(Scheme::Helix, Scheme::Isaac, 10,
                           |e| e.throughput()),
             geomean_ratio(Scheme::Helix, Scheme::Isaac, 10,
                           |e| e.throughput_per_watt()),
             geomean_ratio(Scheme::Helix, Scheme::Isaac, 10,
                           |e| e.throughput_per_mm2()));
    Ok(())
}

/// Fig 25: SOT-MRAM ADC arrays vs low-resolution CMOS ADCs.
pub fn fig25() -> Result<()> {
    hr("Figure 25: SOT-MRAM ADC arrays vs low-resolution CMOS ADCs");
    println!("{:<22} {:>12} {:>12}", "datapath", "bp/s/W", "bp/s/mm2");
    for topo in Topology::all() {
        println!("--- {}", topo.name);
        for (name, bits) in [("8-bit CMOS (ISAAC)", Some(8u32)),
                             ("6-bit CMOS (SRE)", Some(6)),
                             ("5-bit CMOS (IMP)", Some(5))] {
            let e = evaluate_with_adc(Scheme::Seat, &topo, 10, bits);
            println!("{name:<22} {:>12.1} {:>12.1}",
                     e.throughput_per_watt(), e.throughput_per_mm2());
        }
        let e = evaluate(Scheme::Adc, &topo, 10);
        println!("{:<22} {:>12.1} {:>12.1}", "SOT-MRAM ADC (Helix)",
                 e.throughput_per_watt(), e.throughput_per_mm2());
    }
    Ok(())
}

/// Fig 26: crossbar CTC engine sensitivity to beam width.
pub fn fig26() -> Result<()> {
    hr("Figure 26: sensitivity of the crossbar CTC engine to beam width");
    println!("{:>6} {:>14} {:>14} {:>10}", "width", "ADC kbp/s",
             "CTC kbp/s", "gain");
    let topo = Topology::guppy();
    for w in [2usize, 5, 10, 20, 30] {
        let adc = evaluate(Scheme::Adc, &topo, w).throughput();
        let ctc = evaluate(Scheme::Ctc, &topo, w).throughput();
        println!("{w:>6} {:>14.1} {:>14.1} {:>9.2}x", adc / 1e3, ctc / 1e3,
                 ctc / adc);
    }
    Ok(())
}

/// Table 1: SOT-MRAM process-variation parameters.
pub fn table1() -> Result<()> {
    hr("Table 1: SOT-MRAM process-variation parameters");
    let d = DeviceParams::default();
    let s = crate::pim::device::VariationSigmas::default();
    println!("WR/RD transistor width : {} nm (±{:.0}%)", d.w_wt, s.w_wt * 100.0);
    println!("WR/RD transistor length: {} nm (±{:.0}%)", d.l_wt, s.l_wt * 100.0);
    println!("threshold voltage      : {} V (±{:.0}%)", d.v_th, s.v_th * 100.0);
    println!("MTJ R*A product        : {} Ohm*um^2 (±{:.0}%)", d.ra, s.ra * 100.0);
    println!("MTJ cross-section      : {} nm^2 (±{:.0}%)", d.area_nm2,
             s.area * 100.0);
    println!("stability Delta        : {} (±{:.0}%)", d.delta, s.delta * 100.0);
    Ok(())
}

/// Table 2: Helix area and power rollup.
pub fn table2() -> Result<()> {
    hr("Table 2: area and power of Helix (model rollup)");
    let (pp, pa): (f64, f64) = power::tile_peripherals().iter()
        .fold((0.0, 0.0), |(p, a), c| (p + c.power_mw, a + c.area_mm2));
    println!("tile peripherals       : {pp:.1} mW  {pa:.4} mm^2");
    let (ip, ia) = power::ima_with_cmos_adc(&CmosAdc::isaac());
    println!("ISAAC IMA (x12)        : {:.1} mW  {:.4} mm^2", ip * 12.0,
             ia * 12.0);
    let (hp, ha) = power::ima_with_sot_adc();
    println!("Helix IMA (x12)        : {:.1} mW  {:.4} mm^2", hp * 12.0,
             ha * 12.0);
    let i = power::isaac_chip();
    let h = power::helix_chip();
    println!("ISAAC tile / chip      : {:.0} mW, {:.3} mm^2  ->  {:.1} W, \
              {:.1} mm^2 (paper 330mW/0.372mm^2, 55.4W/62.5mm^2)",
             i.tile_power_mw, i.tile_area_mm2, i.power_w, i.area_mm2);
    println!("Helix tile / chip      : {:.0} mW, {:.3} mm^2  ->  {:.1} W, \
              {:.1} mm^2 (paper 163mW/0.259mm^2, 25.7W/43.83mm^2)",
             h.tile_power_mw, h.tile_area_mm2, h.power_w, h.area_mm2);
    let sot = SotAdcArray::paper();
    println!("SOT ADC array          : {:.3} mW, {:.6} mm^2 @ {} MHz",
             sot.power_mw(), sot.area_mm2(), sot.freq_mhz);
    let cmp = power::comparator_block();
    println!("comparator block       : {:.1} W, {:.2} mm^2",
             cmp.power_mw / 1000.0, cmp.area_mm2);
    Ok(())
}

/// Table 3: full-size base-caller architectures as mapped.
pub fn table3() -> Result<()> {
    hr("Table 3: base-caller architectures (full-size, as mapped)");
    println!("{:<10} {:>12} {:>12} {:>10} {:>8}", "model", "MACs/window",
             "params", "CTC steps", "layers");
    for t in Topology::all() {
        println!("{:<10} {:>12.2e} {:>12.2e} {:>10} {:>8}", t.name,
                 t.total_macs(), t.total_params(), t.ctc_steps,
                 t.layers.len());
    }
    Ok(())
}

/// Table 4: dataset stand-ins (synthetic equivalents).
pub fn table4() -> Result<()> {
    hr("Table 4: datasets (synthetic equivalents; DESIGN.md §Substitutions)");
    let pm = PoreModel::synthetic(7);
    println!("{:<16} {:>9} {:>16} {:>10}", "sample", "# reads",
             "median len (b)", "coverage");
    for (name, spec) in [
        ("Lambda-like", RunSpec { genome_len: 8000, coverage: 30,
                                  seed: 41, ..Default::default() }),
        ("E.coli-like", RunSpec { genome_len: 12000, coverage: 30,
                                  seed: 42, ..Default::default() }),
        ("M.tb-like", RunSpec { genome_len: 10000, coverage: 40,
                                read_len_min: 250, read_len_max: 450,
                                seed: 43 }),
        ("human-like", RunSpec { genome_len: 15000, coverage: 30,
                                 read_len_min: 350, read_len_max: 700,
                                 seed: 44 }),
    ] {
        let run = SequencingRun::simulate(&pm, spec);
        let mut lens: Vec<usize> = run.reads.iter()
            .map(|r| r.seq.len())
            .collect();
        lens.sort_unstable();
        println!("{name:<16} {:>9} {:>16} {:>10.1}", run.reads.len(),
                 lens[lens.len() / 2], run.mean_coverage());
    }
    Ok(())
}

/// Table 5: CPU vs GPU vs Helix summary.
pub fn table5() -> Result<()> {
    hr("Table 5: CPU vs GPU vs Helix");
    use crate::pim::schemes as s;
    let h = power::helix_chip();
    println!("{:<12} {:>12} {:>12} {:>12}", "", "CPU", "GPU", "Helix");
    println!("{:<12} {:>12} {:>12} {:>12}", "cores", "8", "2560",
             crate::pim::isaac::Chip::helix().total_arrays());
    println!("{:<12} {:>12} {:>12} {:>12}", "freq", "3.2 GHz", "1.5 GHz",
             "10 MHz");
    println!("{:<12} {:>11.0}W {:>11.0}W {:>11.1}W", "TDP", s::CPU_TDP_W,
             s::GPU_TDP_W, h.power_w);
    println!("{:<12} {:>9}mm2 {:>9}mm2 {:>8.1}mm2", "area", s::CPU_AREA_MM2,
             s::GPU_AREA_MM2, h.area_mm2);
    Ok(())
}

/// Run one figure/table by id, or "all".
pub fn run(which: &str, artifacts_dir: &str) -> Result<()> {
    let d = artifacts_dir;
    match which {
        "fig2" => fig2(d)?,
        "fig3" => fig3()?,
        "fig7" => fig7(d)?,
        "fig8" => fig8()?,
        "fig9" => fig9()?,
        "fig10" => fig10(d)?,
        "fig13" => fig13()?,
        "fig14" => fig14()?,
        "fig15" => fig15()?,
        "fig16" => fig16()?,
        "fig21" => fig21(d)?,
        "fig22" => fig22(d)?,
        "fig23" => fig23(d)?,
        "fig24" => fig24()?,
        "fig25" => fig25()?,
        "fig26" => fig26()?,
        "table1" => table1()?,
        "table2" => table2()?,
        "table3" => table3()?,
        "table4" => table4()?,
        "table5" => table5()?,
        "all" => {
            for f in ["fig2", "fig3", "fig7", "fig8", "fig9", "fig10",
                      "fig13", "fig14", "fig15", "fig16", "fig21", "fig22",
                      "fig23", "fig24", "fig25", "fig26", "table1",
                      "table2", "table3", "table4", "table5"] {
                if let Err(e) = run(f, d) {
                    println!("[{f}] unavailable: {e}");
                }
            }
        }
        other => anyhow::bail!("unknown figure id '{other}' \
                                (fig2..fig26, table1..table5, all)"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn architecture_figures_run_without_artifacts() {
        // Everything not derived from training CSVs must work standalone.
        for f in ["fig3", "fig8", "fig9", "fig13", "fig14", "fig16",
                  "fig24", "fig25", "fig26", "table1", "table2", "table3",
                  "table4", "table5"] {
            run(f, "/nonexistent").unwrap_or_else(|e| panic!("{f}: {e}"));
        }
    }

    #[test]
    fn unknown_figure_errors() {
        assert!(run("fig99", ".").is_err());
    }

    #[test]
    fn fig9_ctc_anchor_holds() {
        // Fig 9 anchor: CTC decode = 16.7% of 16-bit Guppy on the GPU.
        // Re-asserted here after dropping the no-op `/ 2.0 * 2.0`
        // calibration leftover from GPU_CTC_PER_STEP.
        use crate::pim::schemes as s;
        let t = Topology::guppy();
        let dnn16 = t.macs_per_base() / (s::GPU_MAC_RATE_FP32 * 2.0);
        let ctc = s::GPU_CTC_PER_STEP * t.ctc_steps as f64
            / t.bases_per_window;
        let total = dnn16 + ctc + s::GPU_VOTE_PER_BASE;
        assert!((ctc / total - 0.167).abs() < 0.05,
                "ctc fraction {}", ctc / total);
    }

    #[test]
    fn best_window_identity_finds_subsequence() {
        let mut rng = Rng::new(3);
        let genome: Vec<u8> = (0..500).map(|_| rng.base()).collect();
        let seq = genome[100..300].to_vec();
        assert!(best_window_identity(&seq, &genome) > 0.99);
    }
}
