//! Benchmark + figure-regeneration harness.
//!
//! `figures` re-creates every table and figure of the paper's evaluation
//! (DESIGN.md experiment index); `timer` is the micro-benchmark scaffold the
//! `rust/benches/*.rs` binaries use (criterion is unavailable in the
//! offline build — DESIGN.md §Substitutions).

pub mod figures;
pub mod timer;
