//! Base-calling algorithms: CTC decoding (greedy + prefix beam search),
//! read voting (consensus), and accuracy metrics (edit distance / identity).
//!
//! These are the operations the paper identifies as the post-quantization
//! bottleneck (Fig 9: CTC decoding 16.7% + read voting 37% of latency) and
//! accelerates with the crossbar CTC engine (§4.3) and the SOT-MRAM binary
//! comparator arrays. The software implementations here are both the
//! functional reference for those hardware models and the production decode
//! path of the rust coordinator.

pub mod accuracy;
pub mod ctc;
pub mod edit;
pub mod vote;

/// Alphabet shared with the python side: 0=A 1=C 2=G 3=T, 4=blank.
pub const NUM_BASES: usize = 4;
/// Symbol id of the CTC blank.
pub const BLANK: usize = 4;
/// Output alphabet size: the four bases plus the CTC blank.
pub const NUM_SYMBOLS: usize = 5;

/// Render a base-id sequence as an ACGT string (for logs/examples).
pub fn to_acgt(seq: &[u8]) -> String {
    seq.iter().map(|&b| b"ACGT"[b as usize] as char).collect()
}
