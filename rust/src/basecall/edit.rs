//! Edit distance — the paper's base-calling error metric (§2.2): the minimum
//! number of insertions, deletions and substitutions transforming one read
//! into the other.

/// Classic two-row Levenshtein, O(|a|*|b|) time, O(min) memory.
pub fn edit_distance(a: &[u8], b: &[u8]) -> usize {
    let (a, b) = if a.len() < b.len() { (b, a) } else { (a, b) };
    if b.is_empty() {
        return a.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Banded Levenshtein: exact when the true distance <= band, otherwise
/// returns a lower-bound >= band. Reads differ by ~12% in nanopore data, so
/// a narrow band covers the realistic cases at a fraction of the cost — this
/// is the hot-path variant used by voting and accuracy evaluation.
pub fn edit_distance_banded(a: &[u8], b: &[u8], band: usize) -> usize {
    let n = a.len();
    let m = b.len();
    if n.abs_diff(m) > band {
        return n.abs_diff(m).max(band);
    }
    if m == 0 {
        return n;
    }
    const INF: usize = usize::MAX / 2;
    let width = 2 * band + 1;
    // row[i] holds cells j in [i-band, i+band] mapped to [0, width)
    let mut prev = vec![INF; width];
    let mut cur = vec![INF; width];
    // row 0: D[0][j] = j for j <= band
    for j in 0..=band.min(m) {
        prev[j + band] = j; // offset: col j maps to j - 0 + band
    }
    for i in 1..=n {
        for c in cur.iter_mut() {
            *c = INF;
        }
        let jlo = i.saturating_sub(band).max(0);
        let jhi = (i + band).min(m);
        for j in jlo..=jhi {
            let k = j + band - i; // in [0, width)
            let mut best = INF;
            if j == 0 {
                best = i;
            } else {
                // substitution: prev row col j-1 -> offset (j-1)+band-(i-1)
                let ks = j + band - i;
                if prev[ks] < INF {
                    best = best.min(prev[ks]
                        + usize::from(a[i - 1] != b[j - 1]));
                }
                // insertion in a: cur row col j-1 -> offset k-1
                if k > 0 && cur[k - 1] < INF {
                    best = best.min(cur[k - 1] + 1);
                }
                // deletion: prev row col j -> offset j+band-(i-1) = k+1
                if k + 1 < width && prev[k + 1] < INF {
                    best = best.min(prev[k + 1] + 1);
                }
            }
            cur[k] = best;
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    let k = m + band - n;
    prev.get(k).copied().unwrap_or(INF).min(n.max(m))
}

/// Identity = 1 - dist/|truth| (clamped to [0,1]); the paper's accuracy.
pub fn identity(pred: &[u8], truth: &[u8]) -> f64 {
    if truth.is_empty() {
        return if pred.is_empty() { 1.0 } else { 0.0 };
    }
    let d = edit_distance(pred, truth) as f64;
    (1.0 - d / truth.len() as f64).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn known_cases() {
        assert_eq!(edit_distance(b"\x00\x01\x02", b"\x00\x01\x02"), 0);
        assert_eq!(edit_distance(b"\x00\x01\x02", b"\x00\x02"), 1);
        assert_eq!(edit_distance(b"", b"\x01\x02\x03"), 3);
        assert_eq!(edit_distance(b"\x00\x01", b"\x01\x00"), 2);
    }

    #[test]
    fn prop_metric_axioms() {
        prop::check("edit metric", 60, |rng, _| {
            let a = prop::dna(rng, 0, 30);
            let b = prop::dna(rng, 0, 30);
            let d = edit_distance(&a, &b);
            assert_eq!(d, edit_distance(&b, &a), "symmetry");
            assert!(d <= a.len().max(b.len()), "upper bound");
            assert_eq!(d == 0, a == b, "identity of indiscernibles");
            assert!(d >= a.len().abs_diff(b.len()), "length lower bound");
        });
    }

    #[test]
    fn prop_triangle_inequality() {
        prop::check("edit triangle", 40, |rng, _| {
            let a = prop::dna(rng, 0, 20);
            let b = prop::dna(rng, 0, 20);
            let c = prop::dna(rng, 0, 20);
            assert!(edit_distance(&a, &c)
                <= edit_distance(&a, &b) + edit_distance(&b, &c));
        });
    }

    #[test]
    fn prop_banded_matches_exact_within_band() {
        prop::check("banded = exact", 80, |rng, _| {
            let a = prop::dna(rng, 0, 40);
            // mutate a into b with a few edits so the distance is small
            let mut b = a.clone();
            let edits = rng.below(4);
            for _ in 0..edits {
                if b.is_empty() {
                    b.push(rng.base());
                    continue;
                }
                let i = rng.below(b.len());
                match rng.below(3) {
                    0 => b[i] = rng.base(),
                    1 => {
                        b.insert(i, rng.base());
                    }
                    _ => {
                        b.remove(i);
                    }
                }
            }
            let exact = edit_distance(&a, &b);
            if exact <= 8 {
                assert_eq!(edit_distance_banded(&a, &b, 8), exact,
                           "a={a:?} b={b:?}");
            }
        });
    }

    #[test]
    fn identity_bounds() {
        assert_eq!(identity(b"", b""), 1.0);
        assert_eq!(identity(b"", b"\x00\x01"), 0.0);
        assert_eq!(identity(b"\x00\x01", b"\x00\x01"), 1.0);
        let id = identity(b"\x00\x00", b"\x00\x01");
        assert!(id > 0.0 && id < 1.0);
    }
}
