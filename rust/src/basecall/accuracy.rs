//! Accuracy accounting: read accuracy (pre-vote) vs vote accuracy
//! (post-vote), and the random/systematic error split of Fig 3.

use super::edit::identity;
use super::vote::consensus;

/// Summary of a basecalling evaluation run.
#[derive(Clone, Copy, Debug, Default)]
pub struct Accuracy {
    /// mean identity of individual decoded reads vs truth (pre-vote).
    pub read_acc: f64,
    /// identity of the voted consensus vs truth (post-vote).
    pub vote_acc: f64,
    /// positions wrong in >= half the reads AND wrong in the consensus
    /// (systematic, uncorrectable by voting).
    pub systematic_errors: usize,
    /// positions wrong in some read but fixed by the vote (random).
    pub random_errors: usize,
    /// truth positions evaluated.
    pub positions: usize,
}

/// Evaluate a group of decoded reads that all cover the same `truth`
/// sequence: per-read identity, consensus identity, and the error split.
pub fn evaluate_group(decodes: &[Vec<u8>], truth: &[u8]) -> Accuracy {
    if decodes.is_empty() || truth.is_empty() {
        return Accuracy::default();
    }
    let read_acc = decodes.iter()
        .map(|d| identity(d, truth))
        .sum::<f64>() / decodes.len() as f64;
    let refs: Vec<&[u8]> = decodes.iter().map(|d| d.as_slice()).collect();
    let cons = consensus(truth_scaffold(&refs), &refs);
    let vote_acc = identity(&cons, truth);

    // error split: align consensus and each read onto the truth
    let cons_aligned = super::vote::align_onto(truth, &cons);
    let mut systematic = 0usize;
    let mut random = 0usize;
    let per_read: Vec<Vec<Option<u8>>> = refs.iter()
        .map(|r| super::vote::align_onto(truth, r))
        .collect();
    for (i, &t) in truth.iter().enumerate() {
        let wrong_reads = per_read.iter()
            .filter(|a| a[i].map_or(true, |s| s != t))
            .count();
        let cons_wrong = cons_aligned[i].map_or(true, |s| s != t);
        if cons_wrong && wrong_reads * 2 >= per_read.len() {
            systematic += 1;
        } else if wrong_reads > 0 && !cons_wrong {
            random += 1;
        }
    }
    Accuracy {
        read_acc,
        vote_acc,
        systematic_errors: systematic,
        random_errors: random,
        positions: truth.len(),
    }
}

/// Pick the scaffold for voting: the read whose length is the median —
/// robust to truncated decodes.
fn truth_scaffold<'a>(reads: &[&'a [u8]]) -> &'a [u8] {
    let mut order: Vec<usize> = (0..reads.len()).collect();
    order.sort_by_key(|&i| reads[i].len());
    reads[order[order.len() / 2]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn perfect_reads_are_perfect() {
        let truth = vec![0u8, 1, 2, 3, 0, 1];
        let acc = evaluate_group(&[truth.clone(), truth.clone(),
                                   truth.clone()], &truth);
        assert_eq!(acc.read_acc, 1.0);
        assert_eq!(acc.vote_acc, 1.0);
        assert_eq!(acc.systematic_errors, 0);
        assert_eq!(acc.random_errors, 0);
    }

    #[test]
    fn random_error_fixed_by_vote() {
        let truth = vec![0u8, 1, 2, 3, 0, 1, 2, 3];
        let mut r1 = truth.clone();
        r1[4] = 2;
        let acc = evaluate_group(&[r1, truth.clone(), truth.clone()], &truth);
        assert!(acc.read_acc < 1.0);
        assert_eq!(acc.vote_acc, 1.0);
        assert_eq!(acc.systematic_errors, 0);
        assert!(acc.random_errors >= 1);
    }

    #[test]
    fn systematic_error_counted() {
        let truth = vec![0u8, 1, 2, 3, 0, 1, 2, 3];
        let mut bad = truth.clone();
        bad[4] = 2; // every read carries the same error
        let acc = evaluate_group(&[bad.clone(), bad.clone(), bad], &truth);
        assert!(acc.vote_acc < 1.0);
        assert!(acc.systematic_errors >= 1);
    }

    #[test]
    fn prop_vote_acc_at_least_read_acc_with_clean_majority() {
        prop::check("vote >= read (majority clean)", 25, |rng, _| {
            let truth = prop::dna(rng, 10, 40);
            let mut noisy = truth.clone();
            let i = rng.below(noisy.len());
            noisy[i] = (noisy[i] + 1 + rng.base() % 3) % 4;
            let acc = evaluate_group(
                &[noisy, truth.clone(), truth.clone()], &truth);
            assert!(acc.vote_acc >= acc.read_acc - 1e-9,
                    "vote {} read {}", acc.vote_acc, acc.read_acc);
        });
    }
}
