//! CTC decoders: greedy best-path and prefix beam search (§2.2, Fig 4c/d).
//!
//! The beam search keeps the top-W prefixes per time step, tracking the
//! probability of each prefix ending in blank vs non-blank so that merged
//! alignments (AA / A- / -A -> A) accumulate correctly — the merge the
//! paper maps onto crossbar bit-lines with pass transistors (§4.3, Fig 18).
//! `pim::ctc_engine` checks itself against this implementation.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use super::{BLANK, NUM_SYMBOLS};

/// Multiplicative hasher for the small integer keys of the beam maps —
/// SipHash was ~20% of decode time in the §Perf profile (offline build has
/// no fxhash crate, so this is the in-tree equivalent).
#[derive(Default)]
pub struct U64MulHasher(u64);

impl Hasher for U64MulHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        }
        self.0 ^= self.0 >> 31;
    }

    #[inline]
    fn write_u32(&mut self, x: u32) {
        self.0 = (self.0 ^ x as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.0 ^= self.0 >> 31;
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.0 = (self.0 ^ x).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.0 ^= self.0 >> 31;
    }

    #[inline]
    fn write_u8(&mut self, x: u8) {
        self.write_u64(x as u64);
    }
}

type FastMap<K, V> = HashMap<K, V, BuildHasherDefault<U64MulHasher>>;

/// Per-window log-probabilities, row-major (T, NUM_SYMBOLS).
#[derive(Clone, Debug)]
pub struct LogProbs {
    /// number of CTC time steps.
    pub t: usize,
    /// row-major payload, `t * NUM_SYMBOLS` log-probabilities.
    pub data: Vec<f32>,
}

impl LogProbs {
    /// Wrap a row-major payload; panics if its length is not
    /// `t * NUM_SYMBOLS`.
    pub fn new(t: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), t * NUM_SYMBOLS, "bad logprob payload");
        LogProbs { t, data }
    }

    /// The NUM_SYMBOLS log-probabilities at time step `t`.
    #[inline]
    pub fn row(&self, t: usize) -> &[f32] {
        &self.data[t * NUM_SYMBOLS..(t + 1) * NUM_SYMBOLS]
    }
}

/// Greedy best-path decode: argmax per step, collapse repeats, drop blanks.
pub fn greedy_decode(lp: &LogProbs) -> Vec<u8> {
    let mut out = Vec::with_capacity(lp.t / 3);
    let mut prev = usize::MAX;
    for t in 0..lp.t {
        let row = lp.row(t);
        let mut best = 0usize;
        for s in 1..NUM_SYMBOLS {
            if row[s] > row[best] {
                best = s;
            }
        }
        if best != prev && best != BLANK {
            out.push(best as u8);
        }
        prev = best;
    }
    out
}

#[inline]
fn logsumexp2(a: f32, b: f32) -> f32 {
    if a == f32::NEG_INFINITY {
        return b;
    }
    if b == f32::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

#[derive(Clone, Copy, Debug)]
struct Mass {
    /// log p(prefix, last symbol blank)
    pb: f32,
    /// log p(prefix, last symbol non-blank)
    pnb: f32,
}

impl Mass {
    const EMPTY: Mass = Mass { pb: f32::NEG_INFINITY, pnb: f32::NEG_INFINITY };

    #[inline]
    fn total(&self) -> f32 {
        logsumexp2(self.pb, self.pnb)
    }
}

/// Prefix beam search with width `beam`. Returns the most probable decoded
/// read. This is the decoder the paper assumes in its base-callers
/// (beam width 10, §5.2) and whose cost Fig 26 sweeps.
pub fn beam_search(lp: &LogProbs, beam: usize) -> Vec<u8> {
    beam_search_n(lp, beam, 1).pop().map(|(s, _)| s).unwrap_or_default()
}

/// Pruning thresholds for the prefix beam search hot path. Both knobs
/// are log-domain distances (nonnegative; larger prunes less).
///
/// [`BeamPrune::OFF`] (both thresholds infinite) skips the threshold
/// computations entirely, so the pruned search is then
/// operation-for-operation identical to the exhaustive
/// [`beam_search_n`] traversal — byte-identical output, which is what
/// keeps the coordinator's determinism pins intact when pruning is
/// disabled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BeamPrune {
    /// Per-step symbol cutoff: at each time step, a base symbol whose
    /// log-prob is below `best_symbol − symbol_delta` is not used to
    /// extend any prefix. Blank emission is never pruned, so every
    /// surviving prefix keeps accumulating mass.
    pub symbol_delta: f32,
    /// Beam score floor: after the top-K selection, candidates whose
    /// total mass is below `best_total − score_floor` are dropped.
    /// The best survivor is never dropped.
    pub score_floor: f32,
}

impl BeamPrune {
    /// No pruning: infinite thresholds — arithmetic-identical to the
    /// exhaustive search.
    pub const OFF: BeamPrune = BeamPrune {
        symbol_delta: f32::INFINITY,
        score_floor: f32::INFINITY,
    };

    /// Production defaults (what `--beam-prune` without explicit
    /// values enables): δ = 3.0 keeps only near-dominant base
    /// extensions on peaked (model-realistic) rows while pruning
    /// nothing on near-uniform rows; floor = 10.0 drops prefixes
    /// ~e^10 less likely than the best survivor.
    pub fn defaults() -> BeamPrune {
        BeamPrune { symbol_delta: 3.0, score_floor: 10.0 }
    }

    /// Pruning knobs from the environment: `HELIX_BEAM_PRUNE` (symbol
    /// delta; enables pruning) and `HELIX_BEAM_FLOOR` (score floor,
    /// optional refinement). `None` when `HELIX_BEAM_PRUNE` is unset
    /// or unparsable.
    pub fn from_env() -> Option<BeamPrune> {
        let delta = std::env::var("HELIX_BEAM_PRUNE").ok()
            .and_then(|s| s.parse::<f32>().ok())
            .filter(|d| d.is_finite() && *d >= 0.0)?;
        let mut p = BeamPrune { symbol_delta: delta,
                                ..BeamPrune::defaults() };
        if let Some(floor) = std::env::var("HELIX_BEAM_FLOOR").ok()
            .and_then(|s| s.parse::<f32>().ok())
            .filter(|f| f.is_finite() && *f >= 0.0)
        {
            p.score_floor = floor;
        }
        Some(p)
    }
}

/// Pruned prefix beam search returning the single best decode — the
/// decode-pool hot path when `CoordinatorConfig::prune` is set.
pub fn beam_search_pruned(lp: &LogProbs, beam: usize, prune: BeamPrune)
                          -> Vec<u8> {
    beam_search_pruned_n(lp, beam, 1, prune)
        .pop().map(|(s, _)| s).unwrap_or_default()
}

/// Prefix trie node: prefixes live in an arena and are deduplicated via a
/// (parent, symbol) -> child map, so every logical prefix has exactly ONE
/// u32 id. This removes the per-candidate `Vec<u8>` clone + hash of the naive
/// implementation (§Perf pass: ~6x faster at width 10, see EXPERIMENTS.md).
struct PrefixArena {
    /// (parent, sym) per node; root = u32::MAX parent.
    nodes: Vec<(u32, u8)>,
    children: FastMap<(u32, u8), u32>,
}

impl PrefixArena {
    fn new() -> Self {
        PrefixArena {
            nodes: vec![(u32::MAX, 0)],
            children: FastMap::default(),
        }
    }

    const ROOT: u32 = 0;

    #[inline]
    fn child(&mut self, parent: u32, sym: u8) -> u32 {
        let nodes = &mut self.nodes;
        *self.children.entry((parent, sym)).or_insert_with(|| {
            nodes.push((parent, sym));
            (nodes.len() - 1) as u32
        })
    }

    #[inline]
    fn last_sym(&self, id: u32) -> Option<u8> {
        if id == Self::ROOT {
            None
        } else {
            Some(self.nodes[id as usize].1)
        }
    }

    fn materialize(&self, mut id: u32) -> Vec<u8> {
        let mut out = Vec::new();
        while id != Self::ROOT {
            let (parent, sym) = self.nodes[id as usize];
            out.push(sym);
            id = parent;
        }
        out.reverse();
        out
    }
}

/// Prefix beam search returning the top-n (prefix, log-prob) results.
pub fn beam_search_n(lp: &LogProbs, beam: usize, n: usize)
                     -> Vec<(Vec<u8>, f32)> {
    beam_search_pruned_n(lp, beam, n, BeamPrune::OFF)
}

/// Prefix beam search with per-step symbol pruning and a beam score
/// floor (see [`BeamPrune`]), returning the top-n (prefix, log-prob)
/// results. With [`BeamPrune::OFF`] this is the exhaustive search.
pub fn beam_search_pruned_n(lp: &LogProbs, beam: usize, n: usize,
                            prune: BeamPrune) -> Vec<(Vec<u8>, f32)> {
    assert!(beam >= 1);
    let mut arena = PrefixArena::new();
    // (prefix node, mass) survivors of the previous step.
    let mut beams: Vec<(u32, Mass)> =
        vec![(PrefixArena::ROOT, Mass { pb: 0.0, pnb: f32::NEG_INFINITY })];
    let mut next: FastMap<u32, Mass> =
        FastMap::with_capacity_and_hasher(beam * 8, Default::default());
    let mut scored: Vec<(u32, Mass, f32)> = Vec::with_capacity(beam * 8);

    for t in 0..lp.t {
        let row = lp.row(t);
        next.clear();
        // Per-step symbol cutoff: extensions whose emission log-prob
        // falls below best-base-minus-delta are skipped for every
        // prefix this step. NaN rows never trip the cutoff (`p_s <
        // cut` is false for NaN), so malformed input degrades to the
        // unpruned traversal instead of losing symbols.
        let cut = if prune.symbol_delta.is_finite() {
            let mut best = f32::NEG_INFINITY;
            for &p in &row[..BLANK] {
                if p > best {
                    best = p;
                }
            }
            best - prune.symbol_delta
        } else {
            f32::NEG_INFINITY
        };
        for &(node, mass) in beams.iter() {
            let total = mass.total();
            let last = arena.last_sym(node);
            // 1) emit blank: prefix unchanged, ends in blank. Blank is
            //    never pruned — survivors keep accumulating mass.
            {
                let e = next.entry(node).or_insert(Mass::EMPTY);
                e.pb = logsumexp2(e.pb, total + row[BLANK]);
            }
            // 2) emit a base.
            for s in 0..BLANK as u8 {
                let p_s = row[s as usize];
                if p_s < cut {
                    continue;
                }
                if last == Some(s) {
                    // repeat of the last symbol: the extension only grows
                    // from blank-ending mass (A- + A -> AA); non-blank mass
                    // collapses onto the same prefix (the AA/A merge of
                    // Fig 4d).
                    {
                        let e = next.entry(node).or_insert(Mass::EMPTY);
                        e.pnb = logsumexp2(e.pnb, mass.pnb + p_s);
                    }
                    let ext = arena.child(node, s);
                    let e = next.entry(ext).or_insert(Mass::EMPTY);
                    e.pnb = logsumexp2(e.pnb, mass.pb + p_s);
                } else {
                    let ext = arena.child(node, s);
                    let e = next.entry(ext).or_insert(Mass::EMPTY);
                    e.pnb = logsumexp2(e.pnb, total + p_s);
                }
            }
        }
        // prune to the top-`beam` prefixes by total mass (totals cached:
        // logsumexp per comparison was the next §Perf hotspot).
        scored.clear();
        scored.extend(next.iter().map(|(&k, &v)| (k, v, v.total())));
        if scored.len() > beam {
            // total_cmp, not partial_cmp().unwrap(): a NaN mass (from
            // NaN rows upstream) must rank, not panic the decoder.
            scored.select_nth_unstable_by(beam - 1,
                                          |a, b| b.2.total_cmp(&a.2));
            scored.truncate(beam);
        }
        // Beam score floor: drop survivors far below the step's best.
        // `c.2 >= floor` is false for NaN, so NaN candidates are only
        // culled when the floor is actually enabled.
        if prune.score_floor.is_finite() && !scored.is_empty() {
            let mut best = f32::NEG_INFINITY;
            for c in scored.iter() {
                if c.2 > best {
                    best = c.2;
                }
            }
            let floor = best - prune.score_floor;
            scored.retain(|c| c.2 >= floor);
        }
        beams.clear();
        beams.extend(scored.iter().map(|&(k, v, _)| (k, v)));
    }

    beams.sort_unstable_by(|a, b| b.1.total().total_cmp(&a.1.total()));
    let mut out: Vec<(Vec<u8>, f32)> = beams.into_iter()
        .take(n)
        .map(|(node, m)| (arena.materialize(node), m.total()))
        .collect();
    out.reverse(); // best last, so pop() yields it
    out
}

/// log p(labels | lp) via the CTC forward algorithm — rust twin of
/// python/compile/ctc.py, used by tests and the pipeline quality metrics.
pub fn ctc_log_prob(lp: &LogProbs, labels: &[u8]) -> f32 {
    if lp.t == 0 {
        // no emissions: only the empty labelling has mass (p = 1),
        // consistent with `beam_search_n`, which returns the empty
        // prefix at log-prob 0.0 for t == 0 — indexing row(0) here
        // used to panic out of bounds.
        return if labels.is_empty() { 0.0 } else { f32::NEG_INFINITY };
    }
    let s_len = 2 * labels.len() + 1;
    let ext = |s: usize| -> usize {
        if s % 2 == 0 { BLANK } else { labels[s / 2] as usize }
    };
    let mut alpha = vec![f32::NEG_INFINITY; s_len];
    alpha[0] = lp.row(0)[BLANK];
    if s_len > 1 {
        alpha[1] = lp.row(0)[ext(1)];
    }
    let mut next = vec![f32::NEG_INFINITY; s_len];
    for t in 1..lp.t {
        let row = lp.row(t);
        for s in 0..s_len {
            let mut m = alpha[s];
            if s >= 1 {
                m = logsumexp2(m, alpha[s - 1]);
            }
            if s >= 2 && ext(s) != BLANK && ext(s) != ext(s - 2) {
                m = logsumexp2(m, alpha[s - 2]);
            }
            next[s] = m + row[ext(s)];
        }
        std::mem::swap(&mut alpha, &mut next);
    }
    if s_len == 1 {
        alpha[0]
    } else {
        logsumexp2(alpha[s_len - 1], alpha[s_len - 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn uniformish(t: usize, seed: u64) -> LogProbs {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(t * NUM_SYMBOLS);
        for _ in 0..t {
            let raw: Vec<f64> = (0..NUM_SYMBOLS).map(|_| rng.f64() + 0.05).collect();
            let sum: f64 = raw.iter().sum();
            data.extend(raw.iter().map(|p| ((p / sum).ln()) as f32));
        }
        LogProbs::new(t, data)
    }

    /// Logprobs that deterministically spell out `path` symbols.
    fn from_path(path: &[usize]) -> LogProbs {
        let mut data = vec![(0.01f32 / 4.0).ln(); path.len() * NUM_SYMBOLS];
        for (t, &s) in path.iter().enumerate() {
            data[t * NUM_SYMBOLS + s] = 0.99f32.ln();
        }
        LogProbs::new(path.len(), data)
    }

    #[test]
    fn greedy_collapses_repeats_and_blanks() {
        let lp = from_path(&[0, 0, 4, 0, 1, 4, 4, 2]);
        assert_eq!(greedy_decode(&lp), vec![0, 0, 1, 2]);
    }

    #[test]
    fn paper_fig4d_example() {
        // t=0: p(A)=.3 p(-)=.5 ; t=1: p(A)=.3 p(-)=.4 (renormalized over 5
        // symbols in spirit). Beam width 2 must decode "A" as in Fig 4d.
        let rest = 0.2f32 / 3.0;
        let data = vec![
            0.3f32.ln(), rest.ln(), rest.ln(), rest.ln(), 0.5f32.ln(),
            0.3f32.ln(), rest.ln(), rest.ln(), rest.ln(), 0.4f32.ln(),
        ];
        let lp = LogProbs::new(2, data);
        assert_eq!(beam_search(&lp, 2), vec![0u8]);
        // p(A) = p(AA)+p(A-)+p(-A) = .09+.12+.15 = .36 > p(--) = .2
        let top = beam_search_n(&lp, 8, 2);
        let p_a = top.iter().find(|(s, _)| s == &vec![0u8]).unwrap().1.exp();
        assert!((p_a - 0.36).abs() < 1e-3, "{p_a}");
    }

    #[test]
    fn beam1_equals_greedy_on_peaked_dists() {
        prop::check("beam1 = greedy (peaked)", 30, |rng, _| {
            let t = rng.range(2, 12) as usize;
            let path: Vec<usize> = (0..t)
                .map(|_| rng.below(NUM_SYMBOLS)).collect();
            let lp = from_path(&path);
            assert_eq!(beam_search(&lp, 1), greedy_decode(&lp));
        });
    }

    #[test]
    fn exhaustive_beam_is_global_argmax() {
        // An exhaustive beam (width >= #reachable prefixes) must return the
        // prefix with the highest true CTC forward probability; any narrow
        // beam can only do worse. (Narrow beams are NOT monotone in width —
        // pruning is heuristic — so that is deliberately not asserted.)
        prop::check("beam exhaustive argmax", 12, |rng, _| {
            let t = rng.range(2, 5) as usize;
            let lp = uniformish(t, rng.next_u64());
            let p2 = ctc_log_prob(&lp, &beam_search(&lp, 2));
            let pex = ctc_log_prob(&lp, &beam_search(&lp, 100_000));
            assert!(pex >= p2 - 1e-4, "p2={p2} pex={pex}");
        });
    }

    #[test]
    fn beam_mass_matches_forward_algorithm() {
        // The beam's reported mass for a prefix must equal the CTC forward
        // probability of that label sequence when the beam is wide enough to
        // be exhaustive.
        prop::check("beam mass = forward", 15, |rng, _| {
            let t = rng.range(2, 5) as usize;
            let lp = uniformish(t, rng.next_u64());
            let all = beam_search_n(&lp, 10_000, 10_000);
            for (prefix, mass) in all {
                if prefix.is_empty() {
                    continue;
                }
                let fwd = ctc_log_prob(&lp, &prefix);
                if mass < -1e20 && fwd < -1e20 {
                    continue; // both "impossible": -inf == -inf
                }
                assert!((mass - fwd).abs() < 1e-3,
                        "prefix {prefix:?}: beam {mass} fwd {fwd}");
            }
        });
    }

    #[test]
    fn total_probability_sums_to_one() {
        // Exhaustive beam: sum of all prefix masses = 1.
        let lp = uniformish(4, 77);
        let all = beam_search_n(&lp, 100_000, 100_000);
        let total: f64 = all.iter().map(|(_, m)| (*m as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-4, "{total}");
    }

    #[test]
    fn forward_empty_label_is_all_blank() {
        let lp = uniformish(5, 3);
        let want: f32 = (0..5).map(|t| lp.row(t)[BLANK]).sum();
        assert!((ctc_log_prob(&lp, &[]) - want).abs() < 1e-5);
    }

    #[test]
    fn nan_and_neg_inf_rows_decode_without_panicking() {
        // A backend bug (or a hostile artifact) can hand the decoders
        // NaN or -inf log-prob rows. Every decoder must degrade
        // gracefully — total_cmp ordering, no partial_cmp panics.
        let t = 6;
        let mut data = vec![0.0f32; t * NUM_SYMBOLS];
        for (i, v) in data.iter_mut().enumerate() {
            *v = (-((i % NUM_SYMBOLS) as f32)).max(-3.0);
        }
        // row 1 all-NaN, row 3 all -inf, row 4 mixed NaN/-inf/finite.
        for s in 0..NUM_SYMBOLS {
            data[NUM_SYMBOLS + s] = f32::NAN;
            data[3 * NUM_SYMBOLS + s] = f32::NEG_INFINITY;
        }
        data[4 * NUM_SYMBOLS] = f32::NAN;
        data[4 * NUM_SYMBOLS + 1] = f32::NEG_INFINITY;
        let lp = LogProbs::new(t, data);
        greedy_decode(&lp);
        for beam in [1usize, 2, 8] {
            beam_search(&lp, beam);
            beam_search_n(&lp, beam, beam);
            beam_search_pruned(&lp, beam, BeamPrune::defaults());
            beam_search_pruned_n(&lp, beam, beam, BeamPrune::defaults());
        }
        // The all-NaN input is the worst case: every mass goes NaN.
        let lp = LogProbs::new(3, vec![f32::NAN; 3 * NUM_SYMBOLS]);
        greedy_decode(&lp);
        beam_search(&lp, 4);
        beam_search_pruned(&lp, 4, BeamPrune::defaults());
    }

    #[test]
    fn pruning_off_is_byte_identical_to_exhaustive() {
        // BeamPrune::OFF must take the exact arithmetic path of the
        // exhaustive search, and huge-but-finite thresholds (which DO
        // run the threshold code, pruning nothing) must not perturb a
        // single bit either — the coordinator's determinism pins rely
        // on this.
        prop::check("prune off == exhaustive", 10, |rng, _| {
            let t = rng.range(3, 20) as usize;
            let lp = uniformish(t, rng.next_u64());
            for beam in [1usize, 2, 10] {
                let full = beam_search_n(&lp, beam, beam);
                for prune in [BeamPrune::OFF,
                              BeamPrune { symbol_delta: 1e9,
                                          score_floor: 1e9 }] {
                    let pruned =
                        beam_search_pruned_n(&lp, beam, beam, prune);
                    assert_eq!(full.len(), pruned.len());
                    for (f, p) in full.iter().zip(pruned.iter()) {
                        assert_eq!(f.0, p.0);
                        assert_eq!(f.1.to_bits(), p.1.to_bits(),
                                   "mass drifted: {} vs {}", f.1, p.1);
                    }
                }
            }
        });
    }

    #[test]
    fn pruned_beam_equals_full_beam_on_peaked_dists() {
        // On peaked (model-realistic) distributions the default
        // thresholds must not change the decoded read: the dominant
        // symbol's log-prob gap (ln(0.99/0.0025) ≈ 5.98) is far past
        // symbol_delta = 3.0, so pruning only removes mass that could
        // never overtake the winner.
        prop::check("pruned = full (peaked)", 20, |rng, _| {
            let t = rng.range(10, 40) as usize;
            let path: Vec<usize> = (0..t)
                .map(|_| rng.below(NUM_SYMBOLS)).collect();
            let lp = from_path(&path);
            for beam in [2usize, 10] {
                assert_eq!(
                    beam_search(&lp, beam),
                    beam_search_pruned(&lp, beam, BeamPrune::defaults()),
                    "beam {beam} diverged under default pruning");
            }
        });
    }

    #[test]
    fn zero_length_input_is_consistent_across_decoders() {
        // t == 0: every decoder must agree on "the empty read with
        // probability 1" instead of panicking on row(0).
        let lp = LogProbs::new(0, Vec::new());
        assert!(greedy_decode(&lp).is_empty());
        assert!(beam_search(&lp, 10).is_empty());
        let top = beam_search_n(&lp, 10, 1);
        assert_eq!(top.len(), 1);
        assert!(top[0].0.is_empty());
        assert_eq!(top[0].1, 0.0);
        // forward algorithm: p(empty) = 1, p(anything else) = 0
        assert_eq!(ctc_log_prob(&lp, &[]), 0.0);
        assert_eq!(ctc_log_prob(&lp, &[0]), f32::NEG_INFINITY);
        assert_eq!(ctc_log_prob(&lp, &[1, 2, 3]), f32::NEG_INFINITY);
    }
}
