//! Read voting (§2.2, §4.3 Fig 19): aligning multiple decoded reads that
//! cover the same DNA and taking a per-position majority. Random errors are
//! outvoted; systematic errors (same wrong symbol in every read) survive —
//! the error taxonomy of Fig 3 that motivates SEAT.
//!
//! The hardware twin is `pim::comparator` (SOT-MRAM binary comparator
//! arrays finding the longest sub-string matches); this module is the
//! functional reference and the production software path.

use super::edit::edit_distance_banded;

/// An overlap of length L tolerates at most `L / OVERLAP_DIVERGENCE_DIV`
/// edits (20%). The doc comment used to promise "~12% mismatch" while
/// the code accepted 20% — the code's bound is the intended one (splice
/// recall on nanopore-grade decodes needs the slack; the edit-count
/// score term keeps slop-extended overlaps from winning).
pub const OVERLAP_DIVERGENCE_DIV: usize = 5;

/// Semi-global ("fit") alignment of `other` onto `scaffold`: leading and
/// trailing scaffold positions are FREE, so a fragment covering only part
/// of the scaffold aligns where it belongs instead of being stretched
/// end-to-end — stretched alignments inject systematically wrong votes and
/// made voting hurt accuracy before this fix (python twin:
/// compile/align.py). Returns per-scaffold-position symbols or None (gap).
pub fn align_onto(scaffold: &[u8], other: &[u8]) -> Vec<Option<u8>> {
    let n = scaffold.len();
    let m = other.len();
    let mut out = vec![None; n];
    if n == 0 || m == 0 {
        return out;
    }
    // full DP with backtrace; reads are short (10-300 bases) so O(nm) is fine
    let w = m + 1;
    let mut d = vec![0u32; (n + 1) * w];
    for j in 0..=m {
        d[j] = j as u32; // consuming the fragment costs
    }
    for i in 1..=n {
        d[i * w] = 0; // skipping scaffold prefix is free
        for j in 1..=m {
            let sub = d[(i - 1) * w + j - 1]
                + u32::from(scaffold[i - 1] != other[j - 1]);
            let del = d[(i - 1) * w + j] + 1;
            let ins = d[i * w + j - 1] + 1;
            d[i * w + j] = sub.min(del).min(ins);
        }
    }
    // free scaffold suffix: backtrace from the best row of the last column
    let mut i = (0..=n).min_by_key(|&i| d[i * w + m]).unwrap();
    let mut j = m;
    // tie-break order: exact-match diagonal > scaffold skip > mismatch
    // diagonal > fragment skip — keeps votes on genuinely matching symbols
    while i > 0 && j > 0 {
        let cur = d[i * w + j];
        let is_match = scaffold[i - 1] == other[j - 1];
        if is_match && cur == d[(i - 1) * w + j - 1] {
            out[i - 1] = Some(other[j - 1]);
            i -= 1;
            j -= 1;
        } else if cur == d[(i - 1) * w + j] + 1 {
            i -= 1;
        } else if cur == d[(i - 1) * w + j - 1] + 1 && !is_match {
            out[i - 1] = Some(other[j - 1]);
            i -= 1;
            j -= 1;
        } else {
            j -= 1;
        }
    }
    out
}

/// Majority-vote `reads` onto the `scaffold` read (ties keep the scaffold
/// symbol). Returns the consensus, same length as the scaffold.
pub fn consensus(scaffold: &[u8], reads: &[&[u8]]) -> Vec<u8> {
    if scaffold.is_empty() {
        return Vec::new();
    }
    let n = scaffold.len();
    let mut votes = vec![[0u32; 5]; n];
    for (i, &s) in scaffold.iter().enumerate() {
        votes[i][s as usize] += 1;
    }
    for read in reads {
        for (i, sym) in align_onto(scaffold, read).into_iter().enumerate() {
            if let Some(s) = sym {
                votes[i][s as usize] += 1;
            }
        }
    }
    scaffold
        .iter()
        .enumerate()
        .map(|(i, &orig)| {
            let v = &votes[i];
            let (mut best, mut cnt) = (orig as usize, v[orig as usize]);
            for (s, &c) in v.iter().enumerate() {
                if c > cnt {
                    best = s;
                    cnt = c;
                }
            }
            best as u8
        })
        .collect()
}

/// Find the best suffix(a)-prefix(b) overlap of length >= `min_len`,
/// accepting up to `len / OVERLAP_DIVERGENCE_DIV` edits (banded edit
/// distance) — i.e. 20% divergence, the nanopore-realistic bound pinned
/// by `overlap_threshold_is_one_fifth`. Returns the overlap length.
/// This is the "longest match" primitive of Fig 19(a), also reused by the
/// pipeline's overlap-finding stage.
pub fn best_overlap(a: &[u8], b: &[u8], min_len: usize) -> Option<usize> {
    let max_len = a.len().min(b.len());
    let mut best: Option<(usize, f64)> = None;
    for len in (min_len..=max_len).rev() {
        let band = (len / OVERLAP_DIVERGENCE_DIV).max(1);
        let d = edit_distance_banded(&a[a.len() - len..], &b[..len], band);
        // accept up to 20% divergence, but penalize edits hard so a
        // slop-extended overlap never beats a cleaner, shorter one
        // (which would silently drop genome bases on splice).
        if d <= len / OVERLAP_DIVERGENCE_DIV {
            let score = len as f64 - 16.0 * d as f64;
            if best.map_or(true, |(_, s)| score > s) {
                best = Some((len, score));
            }
            if d == 0 {
                break; // exact match: longer candidates were already scanned
            }
        }
    }
    best.map(|(l, _)| l)
}

/// Within-read voting + splice (§2.2, the ⌊L/T⌋-reads-per-signal vote):
/// neighbouring windows of one read overlap, so vote each window decode
/// against its neighbours, then merge the voted windows into one sequence.
/// This is the per-read consensus entry point the coordinator's collector
/// stage calls the moment a read's last window decodes.
pub fn vote_and_splice(decodes: &[Vec<u8>], min_overlap: usize) -> Vec<u8> {
    let voted: Vec<Vec<u8>> = (0..decodes.len())
        .map(|i| {
            let mut nbrs: Vec<&[u8]> = Vec::new();
            if i > 0 {
                nbrs.push(&decodes[i - 1]);
            }
            if i + 1 < decodes.len() {
                nbrs.push(&decodes[i + 1]);
            }
            consensus(&decodes[i], &nbrs)
        })
        .collect();
    merge_reads(&voted, min_overlap)
}

/// Merge overlapping reads (in genome order) into one contig using
/// suffix-prefix overlaps; non-overlapping reads are concatenated.
/// Fig 19(b): "align & vote" — with only two reads per junction this is the
/// alignment half; column voting happens in `pipeline::polish`.
pub fn merge_reads(reads: &[Vec<u8>], min_overlap: usize) -> Vec<u8> {
    let mut contig: Vec<u8> = Vec::new();
    for read in reads {
        if contig.is_empty() {
            contig = read.clone();
            continue;
        }
        let tail = &contig[contig.len().saturating_sub(read.len() + 16)..];
        match best_overlap(tail, read, min_overlap) {
            Some(len) => contig.extend_from_slice(&read[len..]),
            None => contig.extend_from_slice(read),
        }
    }
    contig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn consensus_outvotes_random_error() {
        let truth = vec![0u8, 1, 2, 3, 0, 1, 2, 3];
        let mut r1 = truth.clone();
        r1[3] = 0;
        let cons = consensus(&truth, &[&r1, &truth]);
        assert_eq!(cons, truth);
        // error in the scaffold itself is fixed by two good neighbours
        let cons2 = consensus(&r1, &[&truth, &truth]);
        assert_eq!(cons2, truth);
    }

    #[test]
    fn systematic_error_survives() {
        let truth = vec![0u8, 1, 2, 3, 0, 1];
        let mut wrong = truth.clone();
        wrong[2] = 3;
        let cons = consensus(&wrong, &[&wrong, &wrong]);
        assert_eq!(cons, wrong);
        assert_ne!(cons, truth);
    }

    #[test]
    fn prop_consensus_of_identical_reads_is_identity() {
        prop::check("consensus identity", 40, |rng, _| {
            let a = prop::dna(rng, 1, 40);
            assert_eq!(consensus(&a, &[&a, &a]), a);
        });
    }

    #[test]
    fn prop_consensus_majority_wins_everywhere() {
        // coverage-5 vote with <=1 corrupted read recovers the truth
        prop::check("consensus majority", 30, |rng, _| {
            let truth = prop::dna(rng, 8, 30);
            let mut bad = truth.clone();
            let i = rng.below(bad.len());
            bad[i] = (bad[i] + 1) % 4;
            let cons = consensus(&truth,
                                 &[&bad, &truth, &truth, &truth]);
            assert_eq!(cons, truth);
        });
    }

    #[test]
    fn vote_and_splice_recovers_from_one_bad_window() {
        // three overlapping windows of a pseudo-random truth; the middle
        // one carries an error that its two neighbours outvote
        let mut rng = crate::util::rng::Rng::new(41);
        let truth: Vec<u8> = (0..40).map(|_| rng.base()).collect();
        let w0 = truth[0..20].to_vec();
        let mut w1 = truth[10..30].to_vec();
        w1[5] = (w1[5] + 1) % 4; // truth[15] corrupted
        let w2 = truth[20..40].to_vec();
        let spliced = vote_and_splice(&[w0, w1, w2], 6);
        assert_eq!(spliced, truth);
    }

    #[test]
    fn vote_and_splice_degenerate_inputs() {
        assert!(vote_and_splice(&[], 6).is_empty());
        let one = vec![vec![0u8, 1, 2, 3]];
        assert_eq!(vote_and_splice(&one, 6), vec![0u8, 1, 2, 3]);
    }

    #[test]
    fn overlap_found_exact() {
        let a = vec![0u8, 1, 2, 3, 0, 1, 2, 3];
        let b = vec![0u8, 1, 2, 3, 3, 3, 3];
        assert_eq!(best_overlap(&a, &b, 3), Some(4));
    }

    #[test]
    fn overlap_threshold_is_one_fifth() {
        // pins the 20% divergence bound (the doc used to claim ~12%):
        // over a length-10 overlap, 2 mismatches (20%) are accepted and
        // 3 (30%) are rejected. a.len() == min_len forces exactly one
        // candidate length, so the boundary itself is what's tested.
        let a = vec![0u8, 1, 2, 3, 0, 1, 2, 3, 0, 1];
        let mut two_off = a.clone();
        two_off[1] = (two_off[1] + 1) % 4;
        two_off[6] = (two_off[6] + 1) % 4;
        assert_eq!(best_overlap(&a, &two_off, 10), Some(10));
        let mut three_off = two_off.clone();
        three_off[8] = (three_off[8] + 1) % 4;
        assert_eq!(best_overlap(&a, &three_off, 10), None);
        // (a ~12% bound would already reject the 2-edit overlap: the
        // accepted case above is what distinguishes 20% from ~12%)
    }

    #[test]
    fn merge_reconstructs_sequence() {
        // pseudo-random (aperiodic) truth so overlaps are unambiguous
        let mut rng = crate::util::rng::Rng::new(99);
        let truth: Vec<u8> = (0..64).map(|_| rng.base()).collect();
        let reads: Vec<Vec<u8>> = (0..7)
            .map(|k| truth[k * 8..(k * 8 + 16).min(truth.len())].to_vec())
            .collect();
        let contig = merge_reads(&reads, 5);
        assert_eq!(contig, truth);
    }

    #[test]
    fn align_onto_handles_indels() {
        let scaf = vec![0u8, 1, 2, 3];
        let other = vec![0u8, 2, 3]; // deletion of '1'
        let m = align_onto(&scaf, &other);
        assert_eq!(m[0], Some(0));
        assert_eq!(m[2], Some(2));
        assert_eq!(m[3], Some(3));
    }
}
