//! Multi-tenant TCP serving front-end: N concurrent clients stream raw
//! signal in over a length-prefixed binary protocol ([`frame`]) and
//! receive called reads back as they complete, all sharing ONE
//! [`Coordinator`] pipeline.
//!
//! ```text
//!  client A ──┐  SUBMIT(tag, f32×n)                 RESULT(tag, bases)
//!  client B ──┼──▶ reader thread ──▶ admission ──▶ Coordinator ──▶ pump ──▶ writer thread ─▶ client
//!  client C ──┘     (per conn)      quota │ slo     (shared)     (1 thread)   (per conn)
//!                                     │BUSY(1)│BUSY(2)
//! ```
//!
//! Each accepted connection becomes a **tenant** (ids from 1; tenant 0
//! is reserved for the in-process library path). A reader thread
//! parses frames and runs admission per SUBMIT: the per-tenant
//! [`quota::QuotaGate`] first (a greedy client blocks only itself),
//! then the global [`quota::SloGate`] (interval-p99 load shedding,
//! refused with `BUSY(slo)` for every tenant). Admitted reads are
//! tagged with the tenant id, which rides every window job through
//! dispatch, the DNN shards (including hq escalation re-queues), CTC
//! decode and the collector, so the single pump thread can route each
//! [`CalledRead`] back to its owning connection via the
//! [`registry::ConnectionRegistry`].
//!
//! Disconnects drain gracefully: a clean `FIN` holds the connection
//! open until every outstanding read is answered (then `DONE`); a dead
//! socket cancels the tenant's reads at the collector — their windows
//! still drain through the pipeline (so `in_flight` stays truthful and
//! settles to 0) but the assembled reads are dropped at the router
//! instead of being voted, and the tenant's quota slots are released
//! immediately. Teardown also purges the tenant's per-read state from
//! the streaming analysis stage (when enabled), so a client that
//! vanishes mid-assembly cannot leak partial contigs — tenant ids are
//! never reused, so the purge is permanent.

pub mod frame;
pub(crate) mod quota;
pub(crate) mod registry;

use std::io::{Read as IoRead, Write as IoWrite};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream,
               ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::bounded;

use super::config::{CoordinatorConfig, ServeConfig};
use super::metrics::Metrics;
use super::server::Coordinator;

use frame::{encode, BusyReason, Frame, FrameParser};
use quota::{QuotaGate, SloGate};
use registry::ConnectionRegistry;

/// How often the reader threads surface from a blocking socket read to
/// check the stop flag.
const READ_TICK: Duration = Duration::from_millis(50);
/// Accept-loop poll interval (the listener is non-blocking so shutdown
/// never waits on a connection that isn't coming).
const ACCEPT_TICK: Duration = Duration::from_millis(5);
/// Pump idle sleep between output-queue drains.
const PUMP_TICK: Duration = Duration::from_micros(500);
/// How often the pump closes an SLO interval and recomputes the p99.
const SLO_TICK: Duration = Duration::from_millis(20);

/// Everything the acceptor, readers, writers and pump share.
struct Shared {
    coord: Mutex<Option<Coordinator>>,
    conns: ConnectionRegistry,
    quota: QuotaGate,
    slo: SloGate,
    metrics: Arc<Metrics>,
    stop: AtomicBool,
    next_tenant: AtomicU64,
    next_read: AtomicUsize,
}

impl Shared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }
}

/// The serving front-end: owns the listener, the per-connection
/// reader/writer threads, the shared [`Coordinator`], and the pump
/// thread that routes completed reads back to their tenants. Built by
/// [`Server::start`], torn down by [`Server::shutdown`].
pub struct Server {
    local: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    pump: Option<JoinHandle<()>>,
}

impl Server {
    /// Open the pipeline described by `cfg` and start listening per
    /// `serve`. Binding `host:0` picks an ephemeral port — read it
    /// back with [`Server::local_addr`].
    pub fn start(cfg: CoordinatorConfig, serve: ServeConfig)
        -> Result<Server>
    {
        let coord = Coordinator::new(cfg)?;
        let metrics = coord.metrics.clone();
        let slo = SloGate::new(serve.slo, &metrics.read_latency);
        let listener = TcpListener::bind(&serve.addr)
            .with_context(|| format!("binding {}", serve.addr))?;
        listener.set_nonblocking(true)
            .context("non-blocking listener")?;
        let local = listener.local_addr().context("listener addr")?;

        let shared = Arc::new(Shared {
            coord: Mutex::new(Some(coord)),
            conns: ConnectionRegistry::default(),
            quota: QuotaGate::new(serve.tenant_quota),
            slo,
            metrics,
            stop: AtomicBool::new(false),
            next_tenant: AtomicU64::new(1),
            next_read: AtomicUsize::new(0),
        });

        let accept = {
            let sh = shared.clone();
            std::thread::spawn(move || accept_loop(&sh, listener))
        };
        let pump = {
            let sh = shared.clone();
            std::thread::spawn(move || pump_loop(&sh))
        };
        Ok(Server { local, shared, accept: Some(accept),
                    pump: Some(pump) })
    }

    /// The bound listen address (resolves an ephemeral-port bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Live pipeline telemetry, including the per-tenant rows.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Windows currently in flight inside the shared pipeline (0 once
    /// everything submitted has drained — including windows owned by
    /// killed connections).
    pub fn in_flight(&self) -> usize {
        self.shared.coord.lock().unwrap()
            .as_ref().map_or(0, |c| c.in_flight())
    }

    /// Reads the quota gate currently holds in flight for `tenant`.
    pub fn tenant_in_flight(&self, tenant: u64) -> usize {
        self.shared.quota.in_flight(tenant)
    }

    /// Handle on the shared streaming analysis stage, if the pipeline
    /// was opened with `analysis_threads > 0` (None otherwise, and
    /// None after [`Server::shutdown`] took the coordinator). Lets
    /// tests and operators inspect per-tenant assembly state — e.g.
    /// verify a disconnected tenant's partial contigs were purged.
    pub fn analysis_state(&self)
        -> Option<Arc<super::analysis::AnalysisState>>
    {
        self.shared.coord.lock().unwrap()
            .as_ref().and_then(|c| c.analysis_state())
    }

    /// Stop accepting, drop every connection, drain the pipeline, and
    /// join every thread. Outstanding reads of still-open connections
    /// are cancelled (this is an operator stop, not a graceful drain —
    /// clients that want their answers should FIN and wait for DONE
    /// first).
    pub fn shutdown(mut self) -> Result<()> {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow!("acceptor panicked"))?;
        }
        let coord = self.shared.coord.lock().unwrap().take();
        let res = match coord {
            Some(c) => c.finish().map(|_| ()),
            None => Ok(()),
        };
        if let Some(h) = self.pump.take() {
            h.join().map_err(|_| anyhow!("pump panicked"))?;
        }
        res
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // shutdown() consumed the handles; a bare drop still unsticks
        // every thread so the process can exit
        self.shared.stop.store(true, Ordering::Relaxed);
    }
}

/// Accept loop: non-blocking accept + stop-flag poll. Reader threads
/// are detached — each one owns its connection teardown and the stop
/// flag bounds its lifetime, so the acceptor joins only the readers it
/// spawned by collecting their handles.
fn accept_loop(sh: &Arc<Shared>, listener: TcpListener) {
    let mut readers: Vec<JoinHandle<()>> = Vec::new();
    while !sh.stopping() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let tenant =
                    sh.next_tenant.fetch_add(1, Ordering::Relaxed);
                let sh = sh.clone();
                readers.push(std::thread::spawn(move || {
                    reader_loop(&sh, stream, tenant);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_TICK);
            }
            Err(_) => std::thread::sleep(ACCEPT_TICK),
        }
    }
    for h in readers {
        let _ = h.join();
    }
}

/// Per-connection reader: parse frames, run admission, submit to the
/// shared pipeline. Exits on FIN-drained, EOF, protocol error, read
/// error, or server stop — and in every case tears the connection down
/// exactly once (cancelling outstanding reads unless the drain
/// completed cleanly).
fn reader_loop(sh: &Arc<Shared>, mut stream: TcpStream, tenant: u64) {
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let _ = stream.set_nodelay(true);
    let (tx, rx) = bounded::unbounded::<Vec<u8>>();
    let writer = match stream.try_clone() {
        Ok(ws) => std::thread::spawn(move || writer_loop(ws, rx)),
        Err(_) => return,
    };
    sh.conns.add(tenant, tx);

    let mut parser = FrameParser::default();
    let mut buf = [0u8; 16 * 1024];
    'conn: while !sh.stopping() {
        let n = match stream.read(&mut buf) {
            Ok(0) => break 'conn, // EOF
            Ok(n) => n,
            Err(e) if matches!(e.kind(),
                               std::io::ErrorKind::WouldBlock
                               | std::io::ErrorKind::TimedOut) =>
                continue,
            Err(_) => break 'conn,
        };
        parser.feed(&buf[..n]);
        loop {
            match parser.next() {
                Ok(Some(Frame::Submit { tag, signal })) =>
                    handle_submit(sh, tenant, tag, &signal),
                Ok(Some(Frame::Fin)) => {
                    if sh.conns.mark_fin(tenant) {
                        // drained: DONE is queued, the writer will
                        // flush it when the registry drops our sender
                        break 'conn;
                    }
                    // outstanding reads remain; the pump finishes the
                    // drain and the client closes after DONE (EOF)
                }
                Ok(Some(_)) => break 'conn, // server→client frame: bogus
                Ok(None) => break,
                Err(_) => break 'conn, // malformed stream: drop it
            }
        }
    }

    // teardown: if the registry still knows us the drain was NOT clean
    // (EOF/protocol error/stop before DONE) — cancel what's left.
    // cancel_tenant runs UNCONDITIONALLY (not just when reads were
    // orphaned): even a cleanly-drained tenant may have per-read state
    // parked in the streaming analysis stage, and tenant ids are never
    // reused, so nobody will ever ask for those partial contigs again.
    // Cancelling with nothing outstanding is a no-op at the registry.
    let _orphaned = sh.conns.drop_conn(tenant);
    sh.quota.release_all(tenant);
    if let Some(c) = sh.coord.lock().unwrap().as_ref() {
        c.cancel_tenant(tenant);
    }
    let _ = stream.shutdown(Shutdown::Read);
    let _ = writer.join();
}

/// Admission + submission for one SUBMIT frame.
fn handle_submit(sh: &Arc<Shared>, tenant: u64, tag: u64,
                 signal: &[f32]) {
    let m = &sh.metrics;
    if !sh.quota.try_acquire(tenant) {
        m.add(&m.shed_reads, 1);
        m.add(&m.tenant(tenant).shed, 1);
        sh.conns.send_busy(tenant, tag, BusyReason::Quota);
        return;
    }
    if sh.slo.shedding() {
        sh.quota.release(tenant); // shed AFTER acquire: give it back
        m.add(&m.shed_reads, 1);
        m.add(&m.tenant(tenant).shed, 1);
        sh.conns.send_busy(tenant, tag, BusyReason::Slo);
        return;
    }
    let read_id = sh.next_read.fetch_add(1, Ordering::Relaxed);
    // track BEFORE submit: the pipeline may complete the read before
    // this thread runs again, and the pump must find the routing entry
    sh.conns.track(tenant, read_id, tag);
    let delivered = match sh.coord.lock().unwrap().as_mut() {
        Some(c) => c.submit_signal(read_id, signal, tenant),
        None => 0, // shutting down; connection is about to die anyway
    };
    if delivered == 0 {
        // too short for a single window: trivially complete, answer
        // the empty read right away
        sh.conns.route_result(tenant, read_id, &[]);
        sh.quota.release(tenant);
    }
}

/// Per-connection writer: flush encoded frames queued by the registry
/// until the sender side is dropped (connection removed), then close
/// the write half so a draining client sees EOF after DONE.
fn writer_loop(mut stream: TcpStream, rx: bounded::Receiver<Vec<u8>>) {
    while let Ok(bytes) = rx.recv() {
        if stream.write_all(&bytes).is_err() {
            break;
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
}

/// The pump: single thread that drains completed reads out of the
/// shared pipeline, releases their quota slots, routes them to their
/// tenants, and keeps the SLO gate's interval fresh.
fn pump_loop(sh: &Arc<Shared>) {
    let mut last_slo = std::time::Instant::now();
    loop {
        let stopping = sh.stopping();
        let called = match sh.coord.lock().unwrap().as_ref() {
            Some(c) => c.drain_ready(),
            None => Vec::new(),
        };
        let idle = called.is_empty();
        for r in called {
            if r.tenant == 0 {
                continue; // library-path read: not ours to route
            }
            sh.quota.release(r.tenant);
            sh.conns.route_result(r.tenant, r.read_id, &r.seq);
        }
        if last_slo.elapsed() >= SLO_TICK {
            sh.slo.refresh(&sh.metrics.read_latency);
            last_slo = std::time::Instant::now();
        }
        if stopping {
            // one final drain already ran above with stop observed:
            // nothing more can arrive (finish() precedes pump join)
            break;
        }
        if idle {
            std::thread::sleep(PUMP_TICK);
        }
    }
}

/// Minimal blocking client for the wire protocol — what the tests, the
/// serve bench and `helix serve` smoke-checks speak. One thread, one
/// connection; pipelining is just calling [`Client::submit`] multiple
/// times before reading events.
pub struct Client {
    stream: TcpStream,
    parser: FrameParser,
}

/// Everything a drained connection received, in arrival order per
/// kind: completed reads as `(tag, bases)` and admission refusals as
/// `(tag, reason)`.
#[derive(Debug, Default)]
pub struct ClientSummary {
    /// RESULT frames: client tag → called base sequence.
    pub results: Vec<(u64, Vec<u8>)>,
    /// BUSY frames: client tag → which gate refused it.
    pub busy: Vec<(u64, BusyReason)>,
}

impl Client {
    /// Connect to a running [`Server`].
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .context("connecting to helix server")?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, parser: FrameParser::default() })
    }

    /// Submit one read's raw signal under a client-chosen tag. Tags
    /// are echoed on the matching RESULT/BUSY; reusing a tag across
    /// in-flight reads is legal but the answers become ambiguous.
    pub fn submit(&mut self, tag: u64, signal: &[f32]) -> Result<()> {
        self.stream
            .write_all(&encode(&Frame::Submit {
                tag,
                signal: signal.to_vec(),
            }))
            .context("writing SUBMIT")
    }

    /// Announce no further submissions; the server answers everything
    /// outstanding and then sends DONE.
    pub fn fin(&mut self) -> Result<()> {
        self.stream.write_all(&encode(&Frame::Fin))
            .context("writing FIN")
    }

    /// Block until the next server frame (RESULT, BUSY, or DONE).
    pub fn next_event(&mut self) -> Result<Frame> {
        let mut buf = [0u8; 16 * 1024];
        loop {
            if let Some(f) = self.parser.next()? {
                return Ok(f);
            }
            let n = self.stream.read(&mut buf)
                .context("reading server frame")?;
            if n == 0 {
                bail!("server closed the connection mid-stream \
                       ({} bytes buffered)", self.parser.buffered());
            }
            self.parser.feed(&buf[..n]);
        }
    }

    /// FIN, then collect every RESULT/BUSY until DONE.
    pub fn drain(mut self) -> Result<ClientSummary> {
        self.fin()?;
        let mut out = ClientSummary::default();
        loop {
            match self.next_event()? {
                Frame::Result { tag, seq } => out.results.push((tag, seq)),
                Frame::Busy { tag, reason } => out.busy.push((tag, reason)),
                Frame::Done => return Ok(out),
                other => bail!("unexpected frame from server: {other:?}"),
            }
        }
    }
}
