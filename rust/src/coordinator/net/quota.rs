//! Admission control for the TCP front-end: the per-tenant in-flight
//! [`QuotaGate`] and the interval-p99 [`SloGate`] load shedder.
//!
//! Both gates answer at SUBMIT time, before a read touches the
//! pipeline, so a refused read costs the server one BUSY frame and
//! nothing else. The quota is counted in **reads, not windows**, and a
//! slot is acquired exactly once at admission and released exactly once
//! when the read leaves the system (result routed, shed, or its
//! connection died) — escalated windows re-enter the DNN stage without
//! ever touching the gate, so tiered serving structurally cannot
//! double-count a read.

use std::collections::HashMap;
use std::time::Duration;

use crate::util::sync::Mutex;

use super::super::metrics::{LatencyHistogram, LatencySnapshot};

/// Per-tenant in-flight read accounting. A tenant at its quota has
/// further submissions refused — the greedy client blocks itself, never
/// its neighbours, while the global `queue_cap` still bounds the
/// pipeline as a whole.
pub(crate) struct QuotaGate {
    /// max in-flight reads per tenant; 0 = unlimited.
    quota: usize,
    in_flight: Mutex<HashMap<u64, usize>>,
}

impl QuotaGate {
    pub(crate) fn new(quota: usize) -> QuotaGate {
        QuotaGate { quota, in_flight: Mutex::new(HashMap::new()) }
    }

    /// Claim one in-flight slot for `tenant`; false = at quota, refuse
    /// the read with BUSY(quota) and do NOT call `release` for it.
    pub(crate) fn try_acquire(&self, tenant: u64) -> bool {
        let mut m = self.in_flight.lock().unwrap();
        let slot = m.entry(tenant).or_insert(0);
        if self.quota != 0 && *slot >= self.quota {
            return false;
        }
        *slot += 1;
        true
    }

    /// Return one slot: the read completed, was shed after acquiring
    /// (SLO refusal), or produced no windows. Releasing a tenant with
    /// no outstanding slots is a no-op, so late pipeline results for a
    /// connection already torn down by `release_all` cannot drive the
    /// count negative.
    pub(crate) fn release(&self, tenant: u64) {
        let mut m = self.in_flight.lock().unwrap();
        if let Some(slot) = m.get_mut(&tenant) {
            *slot = slot.saturating_sub(1);
            if *slot == 0 {
                m.remove(&tenant);
            }
        }
    }

    /// Drop every slot a dead connection still held (its reads were
    /// cancelled at the collector; no per-read releases will arrive
    /// in any fixed order relative to this).
    pub(crate) fn release_all(&self, tenant: u64) {
        self.in_flight.lock().unwrap().remove(&tenant);
    }

    /// Current in-flight reads for `tenant`.
    pub(crate) fn in_flight(&self, tenant: u64) -> usize {
        self.in_flight.lock().unwrap().get(&tenant).copied().unwrap_or(0)
    }
}

/// Interval-p99 load shedder. The serving pump periodically calls
/// [`SloGate::refresh`] with the pipeline's per-read latency histogram;
/// between refreshes, [`SloGate::shedding`] answers from the last
/// interval's p99. An interval with **no completed reads** clears the
/// breach rather than holding it: a sticky breach with nothing
/// completing would refuse admissions forever and the system could
/// never observe its own recovery.
pub(crate) struct SloGate {
    /// micros of read latency the interval p99 may reach; None never
    /// sheds.
    slo_micros: Option<u64>,
    state: Mutex<SloState>,
}

struct SloState {
    prev: LatencySnapshot,
    breached: bool,
}

impl SloGate {
    /// Build the gate, snapshotting `hist` as the first interval floor.
    pub(crate) fn new(slo: Option<Duration>, hist: &LatencyHistogram)
        -> SloGate
    {
        SloGate {
            slo_micros: slo.map(|d| d.as_micros() as u64),
            state: Mutex::new(SloState {
                prev: hist.snapshot(),
                breached: false,
            }),
        }
    }

    /// Close the current interval: recompute the interval p99 against
    /// the previous snapshot and advance the floor.
    pub(crate) fn refresh(&self, hist: &LatencyHistogram) {
        let Some(slo) = self.slo_micros else { return };
        let mut st = self.state.lock().unwrap();
        let snap = hist.snapshot();
        let p99 = snap.quantile_since(&st.prev, 0.99);
        // p99 == 0 means no reads completed this interval (see module
        // docs): treat as recovered, not as breached
        st.breached = p99 > slo;
        st.prev = snap;
    }

    /// True while the last closed interval's p99 breached the SLO:
    /// refuse every tenant's submissions with BUSY(slo).
    pub(crate) fn shedding(&self) -> bool {
        self.slo_micros.is_some() && self.state.lock().unwrap().breached
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quota_blocks_only_the_greedy_tenant() {
        let g = QuotaGate::new(2);
        assert!(g.try_acquire(1));
        assert!(g.try_acquire(1));
        assert!(!g.try_acquire(1), "tenant 1 is at quota");
        assert!(g.try_acquire(2), "tenant 2 is unaffected");
        assert_eq!(g.in_flight(1), 2);
        assert_eq!(g.in_flight(2), 1);
    }

    #[test]
    fn release_on_shed_restores_the_slot() {
        // the SLO path acquires first, then sheds: the release must
        // give the slot back or quota capacity leaks away
        let g = QuotaGate::new(1);
        assert!(g.try_acquire(7));
        g.release(7); // shed after acquire
        assert!(g.try_acquire(7), "shed read must not consume quota");
    }

    #[test]
    fn dead_connection_release_all_clears_every_slot() {
        let g = QuotaGate::new(4);
        for _ in 0..3 {
            assert!(g.try_acquire(5));
        }
        g.release_all(5);
        assert_eq!(g.in_flight(5), 0);
        // a late pipeline completion for the dead tenant is harmless
        g.release(5);
        assert_eq!(g.in_flight(5), 0);
        assert!(g.try_acquire(5), "tenant id reuse starts clean");
    }

    #[test]
    fn double_release_cannot_go_negative() {
        let g = QuotaGate::new(2);
        assert!(g.try_acquire(3));
        g.release(3);
        g.release(3);
        g.release(3);
        assert_eq!(g.in_flight(3), 0);
        assert!(g.try_acquire(3));
        assert!(g.try_acquire(3));
        assert!(!g.try_acquire(3), "quota intact after over-release");
    }

    #[test]
    fn zero_quota_is_unlimited() {
        let g = QuotaGate::new(0);
        for _ in 0..1000 {
            assert!(g.try_acquire(1));
        }
        assert_eq!(g.in_flight(1), 1000);
    }

    #[test]
    fn slo_gate_trips_on_breach_and_recovers_on_quiet() {
        let hist = LatencyHistogram::default();
        let gate = SloGate::new(Some(Duration::from_millis(10)), &hist);
        assert!(!gate.shedding(), "starts open");
        // an interval of 50ms reads breaches a 10ms SLO
        for _ in 0..100 {
            hist.record(50_000);
        }
        gate.refresh(&hist);
        assert!(gate.shedding());
        // a quiet interval (no completions) clears the breach
        gate.refresh(&hist);
        assert!(!gate.shedding());
        // fast reads keep it open
        for _ in 0..100 {
            hist.record(1_000);
        }
        gate.refresh(&hist);
        assert!(!gate.shedding());
    }

    #[test]
    fn slo_gate_without_slo_never_sheds() {
        let hist = LatencyHistogram::default();
        let gate = SloGate::new(None, &hist);
        for _ in 0..100 {
            hist.record(60_000_000);
        }
        gate.refresh(&hist);
        assert!(!gate.shedding());
    }
}

// Schedule-exploration models for the quota-slot conservation
// invariants (docs/CONCURRENCY.md). Compiled only under
// `--cfg helix_check`; run via `./ci.sh check`.
#[cfg(all(test, helix_check))]
mod model_tests {
    use super::*;
    use crate::util::check::{explore, spawn};
    use std::sync::Arc;

    /// Quota slots are conserved across concurrent acquire / release /
    /// shed traffic: with quota 2 and three workers each doing
    /// acquire→(maybe work)→release, the gate ends drained and every
    /// successful acquire was matched by exactly one release — no
    /// interleaving can leak a slot or drive the count negative.
    #[test]
    fn model_quota_slots_conserved_under_concurrency() {
        explore("model_quota_slots_conserved_under_concurrency", 200,
                || {
            let g = Arc::new(QuotaGate::new(2));
            let mut hs = Vec::new();
            for _ in 0..3 {
                let g = Arc::clone(&g);
                hs.push(spawn(move || {
                    let mut held = 0u32;
                    for _ in 0..2 {
                        if g.try_acquire(1) {
                            held += 1;
                        }
                    }
                    // release exactly what was acquired (the shed
                    // path: acquire then give the slot back)
                    for _ in 0..held {
                        g.release(1);
                    }
                    held
                }));
            }
            let granted: u32 = hs.into_iter().map(|h| h.join()).sum();
            assert_eq!(g.in_flight(1), 0,
                       "slots leaked ({granted} grants)");
            assert!(g.try_acquire(1),
                    "fully-released tenant must re-admit");
            g.release(1);
        });
    }

    /// PR 8 regression, schedule-exhaustive: a disconnect's
    /// `release_all` racing the dead tenant's late per-read `release`
    /// calls (pipeline drain) must end at zero in-flight — never
    /// negative, never resurrecting slots — and the tenant id must
    /// start clean on reuse, in every order the drain interleaves
    /// with the teardown.
    #[test]
    fn model_disconnect_drain_release_race_stays_clean() {
        explore("model_disconnect_drain_release_race_stays_clean", 200,
                || {
            let g = Arc::new(QuotaGate::new(4));
            for _ in 0..3 {
                assert!(g.try_acquire(9));
            }
            let g2 = Arc::clone(&g);
            // late pipeline completions draining after the disconnect
            let drain = spawn(move || {
                g2.release(9);
                g2.release(9);
            });
            // connection teardown
            g.release_all(9);
            drain.join();
            assert_eq!(g.in_flight(9), 0,
                       "drain/teardown race left residue");
            // id reuse starts with full quota whatever the order
            for _ in 0..4 {
                assert!(g.try_acquire(9));
            }
            assert!(!g.try_acquire(9), "quota shrank after the race");
        });
    }
}
