//! Wire framing for the TCP serving front-end: a hand-rolled
//! length-prefixed binary protocol in the spirit of `util::json`'s
//! hand-rolled parser (no serde, no tokio — the offline build has
//! neither, and the protocol is small enough that a hand parser is the
//! clearer artifact anyway).
//!
//! Every frame is `[type: u8][len: u32 LE][payload: len bytes]`:
//!
//! | type | dir | name   | payload |
//! |------|-----|--------|---------|
//! | 0x01 | c→s | SUBMIT | `[tag u64][n u32][n × f32]` raw signal |
//! | 0x02 | c→s | FIN    | empty — no further submissions |
//! | 0x81 | s→c | RESULT | `[tag u64][n u32][n × u8]` called bases |
//! | 0x82 | s→c | BUSY   | `[tag u64][reason u8]` admission refusal |
//! | 0x83 | s→c | DONE   | empty — every tracked read answered |
//!
//! All integers and floats are little-endian. The `tag` is chosen by
//! the client and echoed verbatim on the read's RESULT/BUSY, so a
//! client can pipeline submissions and match answers without caring
//! about server-side read ids. Payloads are capped at [`MAX_PAYLOAD`]
//! so an adversarial length prefix is rejected outright instead of
//! sizing an allocation.
//!
//! [`FrameParser`] is incremental: `feed` raw socket bytes, then pull
//! decoded frames with `next` until it returns `Ok(None)` (needs more
//! bytes). Malformed input — unknown type, oversized length, payload
//! that doesn't type-check — returns a [`FrameError`] and poisons the
//! parser: framing is byte-positional, so there is no resynchronizing
//! with a stream that has lied once; the connection must be dropped.
//! The property tests below drive random and adversarial byte streams
//! (truncations, oversized prefixes, mid-frame splits, interleaved
//! tenants) through the parser: it must never panic and must reject
//! cleanly.

use std::fmt;

/// Hard cap on a frame payload (16 MiB ≈ a 4M-sample read): anything
/// larger is rejected as [`FrameError::Oversized`] before any
/// allocation is sized from the wire.
pub const MAX_PAYLOAD: usize = 1 << 24;

const TYPE_SUBMIT: u8 = 0x01;
const TYPE_FIN: u8 = 0x02;
const TYPE_RESULT: u8 = 0x81;
const TYPE_BUSY: u8 = 0x82;
const TYPE_DONE: u8 = 0x83;

/// Why an admission gate refused a SUBMIT (the `reason` byte of a BUSY
/// frame).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BusyReason {
    /// the tenant's own in-flight quota is full: its earlier reads
    /// must complete before it may submit more.
    Quota,
    /// the server is shedding load: the interval p99 read latency
    /// breached the configured SLO.
    Slo,
}

impl BusyReason {
    fn code(self) -> u8 {
        match self {
            BusyReason::Quota => 1,
            BusyReason::Slo => 2,
        }
    }

    fn from_code(c: u8) -> Option<BusyReason> {
        match c {
            1 => Some(BusyReason::Quota),
            2 => Some(BusyReason::Slo),
            _ => None,
        }
    }
}

/// One decoded protocol frame (either direction).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// client→server: one read's raw signal under a client-chosen tag.
    Submit {
        /// client-chosen read tag, echoed on the RESULT/BUSY answer.
        tag: u64,
        /// raw current samples.
        signal: Vec<f32>,
    },
    /// client→server: no further submissions; answer outstanding reads
    /// then DONE.
    Fin,
    /// server→client: one read's called bases.
    Result {
        /// the tag the read was submitted under.
        tag: u64,
        /// consensus base sequence (values 0–3).
        seq: Vec<u8>,
    },
    /// server→client: the submission was refused by admission control.
    Busy {
        /// the tag the refused read was submitted under.
        tag: u64,
        /// which gate refused it.
        reason: BusyReason,
    },
    /// server→client: FIN acknowledged and every tracked read
    /// answered; the connection is drained.
    Done,
}

/// A malformed byte stream, detected positionally. The parser is
/// poisoned afterwards (see module docs) — drop the connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// the type byte names no known frame.
    BadType(u8),
    /// the length prefix exceeds [`MAX_PAYLOAD`].
    Oversized(u32),
    /// the payload does not type-check against its frame type.
    BadPayload(&'static str),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadType(t) =>
                write!(f, "unknown frame type 0x{t:02x}"),
            FrameError::Oversized(n) =>
                write!(f, "frame payload of {n} bytes exceeds the \
                           {MAX_PAYLOAD}-byte cap"),
            FrameError::BadPayload(why) =>
                write!(f, "malformed frame payload: {why}"),
        }
    }
}

impl std::error::Error for FrameError {}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Encode one frame to wire bytes.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let (ty, payload) = match frame {
        Frame::Submit { tag, signal } => {
            let mut p = Vec::with_capacity(12 + signal.len() * 4);
            put_u64(&mut p, *tag);
            put_u32(&mut p, signal.len() as u32);
            for s in signal {
                p.extend_from_slice(&s.to_le_bytes());
            }
            (TYPE_SUBMIT, p)
        }
        Frame::Fin => (TYPE_FIN, Vec::new()),
        Frame::Result { tag, seq } => {
            let mut p = Vec::with_capacity(12 + seq.len());
            put_u64(&mut p, *tag);
            put_u32(&mut p, seq.len() as u32);
            p.extend_from_slice(seq);
            (TYPE_RESULT, p)
        }
        Frame::Busy { tag, reason } => {
            let mut p = Vec::with_capacity(9);
            put_u64(&mut p, *tag);
            p.push(reason.code());
            (TYPE_BUSY, p)
        }
        Frame::Done => (TYPE_DONE, Vec::new()),
    };
    let mut out = Vec::with_capacity(5 + payload.len());
    out.push(ty);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

fn get_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn get_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3],
                        b[4], b[5], b[6], b[7]])
}

fn decode_payload(ty: u8, p: &[u8]) -> Result<Frame, FrameError> {
    match ty {
        TYPE_SUBMIT | TYPE_RESULT => {
            if p.len() < 12 {
                return Err(FrameError::BadPayload(
                    "submit/result header needs 12 bytes"));
            }
            let tag = get_u64(p);
            let n = get_u32(&p[8..]) as usize;
            let body = &p[12..];
            if ty == TYPE_SUBMIT {
                if body.len() != n * 4 {
                    return Err(FrameError::BadPayload(
                        "submit sample count disagrees with length"));
                }
                let signal = body.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Ok(Frame::Submit { tag, signal })
            } else {
                if body.len() != n {
                    return Err(FrameError::BadPayload(
                        "result base count disagrees with length"));
                }
                Ok(Frame::Result { tag, seq: body.to_vec() })
            }
        }
        TYPE_FIN | TYPE_DONE => {
            if !p.is_empty() {
                return Err(FrameError::BadPayload(
                    "fin/done carries no payload"));
            }
            Ok(if ty == TYPE_FIN { Frame::Fin } else { Frame::Done })
        }
        TYPE_BUSY => {
            if p.len() != 9 {
                return Err(FrameError::BadPayload(
                    "busy payload is tag + reason byte"));
            }
            match BusyReason::from_code(p[8]) {
                Some(reason) =>
                    Ok(Frame::Busy { tag: get_u64(p), reason }),
                None => Err(FrameError::BadPayload(
                    "unknown busy reason code")),
            }
        }
        other => Err(FrameError::BadType(other)),
    }
}

/// Incremental frame parser over a raw byte stream (see module docs
/// for the feed/next contract and the poisoning rule).
#[derive(Default)]
pub struct FrameParser {
    buf: Vec<u8>,
    pos: usize,
    poisoned: Option<FrameError>,
}

impl FrameParser {
    /// Append raw bytes read from the socket.
    pub fn feed(&mut self, bytes: &[u8]) {
        // reclaim consumed prefix before it dominates the buffer
        if self.pos > 4096 && self.pos * 2 > self.buf.len() {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes fed but not yet consumed by a decoded frame. Nonzero at
    /// EOF means the stream ended mid-frame (a truncated/dirty
    /// disconnect).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Decode the next complete frame: `Ok(None)` means feed more
    /// bytes; an error poisons the parser (every later call returns
    /// the same error).
    pub fn next(&mut self) -> Result<Option<Frame>, FrameError> {
        if let Some(e) = self.poisoned {
            return Err(e);
        }
        let avail = &self.buf[self.pos..];
        if avail.len() < 5 {
            return Ok(None);
        }
        let ty = avail[0];
        if !matches!(ty, TYPE_SUBMIT | TYPE_RESULT | TYPE_BUSY
                         | TYPE_FIN | TYPE_DONE) {
            return self.poison(FrameError::BadType(ty));
        }
        let len = get_u32(&avail[1..]) as usize;
        if len > MAX_PAYLOAD {
            return self.poison(FrameError::Oversized(len as u32));
        }
        if avail.len() < 5 + len {
            return Ok(None);
        }
        match decode_payload(ty, &avail[5..5 + len]) {
            Ok(frame) => {
                self.pos += 5 + len;
                Ok(Some(frame))
            }
            Err(e) => self.poison(e),
        }
    }

    fn poison(&mut self, e: FrameError) -> Result<Option<Frame>, FrameError> {
        self.poisoned = Some(e);
        Err(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_frame(rng: &mut Rng) -> Frame {
        match rng.below(5) {
            0 => Frame::Submit {
                tag: rng.next_u64(),
                signal: (0..rng.below(64))
                    .map(|_| rng.normal() as f32).collect(),
            },
            1 => Frame::Fin,
            2 => Frame::Result {
                tag: rng.next_u64(),
                seq: (0..rng.below(64)).map(|_| rng.base()).collect(),
            },
            3 => Frame::Busy {
                tag: rng.next_u64(),
                reason: if rng.below(2) == 0 { BusyReason::Quota }
                        else { BusyReason::Slo },
            },
            _ => Frame::Done,
        }
    }

    /// Frames survive encode → arbitrary re-chunking → decode, in
    /// order, including interleaved tenants (many Submit frames under
    /// different tags back to back).
    #[test]
    fn roundtrip_survives_arbitrary_chunking() {
        prop::check("frame roundtrip", 60, |rng, _| {
            let frames: Vec<Frame> =
                (0..1 + rng.below(8)).map(|_| random_frame(rng)).collect();
            let mut wire = Vec::new();
            for f in &frames {
                wire.extend_from_slice(&encode(f));
            }
            let mut parser = FrameParser::default();
            let mut got = Vec::new();
            let mut i = 0;
            while i < wire.len() {
                let n = (1 + rng.below(7)).min(wire.len() - i);
                parser.feed(&wire[i..i + n]);
                i += n;
                while let Some(f) = parser.next().unwrap() {
                    got.push(f);
                }
            }
            assert_eq!(got, frames);
            assert_eq!(parser.buffered(), 0, "no residue after decode");
        });
    }

    /// Random byte soup must never panic: every frame either decodes
    /// or the parser rejects cleanly and stays poisoned.
    #[test]
    fn random_bytes_never_panic() {
        prop::check("frame byte soup", 80, |rng, _| {
            let bytes: Vec<u8> = (0..rng.below(512))
                .map(|_| (rng.next_u64() & 0xff) as u8).collect();
            let mut parser = FrameParser::default();
            parser.feed(&bytes);
            let mut first_err = None;
            for _ in 0..bytes.len() + 1 {
                match parser.next() {
                    Ok(Some(_)) => continue,
                    Ok(None) => break,
                    Err(e) => {
                        first_err = Some(e);
                        break;
                    }
                }
            }
            if let Some(e) = first_err {
                // poisoned: the error is sticky and feed stays safe
                parser.feed(&bytes);
                assert_eq!(parser.next(), Err(e));
            }
        });
    }

    /// A truncated frame (any proper prefix) is "need more bytes",
    /// never an error and never a phantom frame — and the unread
    /// residue is observable so EOF-mid-frame reads as dirty.
    #[test]
    fn truncated_frames_wait_cleanly() {
        prop::check("frame truncation", 60, |rng, _| {
            let frame = random_frame(rng);
            let wire = encode(&frame);
            let cut = rng.below(wire.len().max(1));
            let mut parser = FrameParser::default();
            parser.feed(&wire[..cut]);
            assert_eq!(parser.next(), Ok(None),
                       "prefix of {cut}/{} bytes must just wait",
                       wire.len());
            assert_eq!(parser.buffered(), cut);
            // completing the frame decodes it after all
            parser.feed(&wire[cut..]);
            assert_eq!(parser.next(), Ok(Some(frame)));
        });
    }

    /// An adversarial length prefix past MAX_PAYLOAD is rejected from
    /// the 5-byte header alone — no allocation, no waiting for 4 GiB.
    #[test]
    fn oversized_length_prefix_rejected_from_header() {
        let mut wire = vec![0x01u8];
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut parser = FrameParser::default();
        parser.feed(&wire);
        assert_eq!(parser.next(), Err(FrameError::Oversized(u32::MAX)));
        // poisoned thereafter, even if valid bytes follow
        parser.feed(&encode(&Frame::Fin));
        assert_eq!(parser.next(), Err(FrameError::Oversized(u32::MAX)));
    }

    #[test]
    fn unknown_type_and_bad_payloads_reject() {
        let mut parser = FrameParser::default();
        parser.feed(&[0x7f, 0, 0, 0, 0]);
        assert_eq!(parser.next(), Err(FrameError::BadType(0x7f)));
        // FIN with a payload
        let mut parser = FrameParser::default();
        parser.feed(&[TYPE_FIN, 1, 0, 0, 0, 9]);
        assert!(matches!(parser.next(),
                         Err(FrameError::BadPayload(_))));
        // SUBMIT whose sample count disagrees with the length
        let mut p = vec![TYPE_SUBMIT];
        p.extend_from_slice(&13u32.to_le_bytes());
        p.extend_from_slice(&[0u8; 13]);
        let mut parser = FrameParser::default();
        parser.feed(&p);
        assert!(matches!(parser.next(),
                         Err(FrameError::BadPayload(_))));
        // BUSY with an unknown reason code
        let mut p = vec![TYPE_BUSY];
        p.extend_from_slice(&9u32.to_le_bytes());
        p.extend_from_slice(&[0u8; 8]);
        p.push(7);
        let mut parser = FrameParser::default();
        parser.feed(&p);
        assert!(matches!(parser.next(),
                         Err(FrameError::BadPayload(_))));
    }

    /// The compaction path (large consumed prefix) must not corrupt
    /// later frames.
    #[test]
    fn long_streams_compact_without_corruption() {
        let mut parser = FrameParser::default();
        let frame = Frame::Submit { tag: 42, signal: vec![1.0; 600] };
        let wire = encode(&frame);
        for round in 0..64 {
            parser.feed(&wire);
            assert_eq!(parser.next(), Ok(Some(frame.clone())),
                       "round {round}");
        }
        assert_eq!(parser.buffered(), 0);
    }
}
