//! Connection registry: maps each live tenant (TCP connection) to its
//! outbound frame channel and the set of reads it still awaits.
//!
//! **Routing rule** — every state transition that both inspects the
//! outstanding set and queues a frame happens under ONE registry lock,
//! so the "last result arrives while FIN is being processed" race
//! cannot drop a DONE or send one early: whichever of
//! [`ConnectionRegistry::route_result`] / [`ConnectionRegistry::mark_fin`]
//! observes `fin && outstanding.is_empty()` first queues the DONE and
//! removes the connection; the other sees the connection gone and does
//! nothing.
//!
//! Frames are queued as encoded bytes on an unbounded in-tree channel
//! drained by the connection's writer thread; removing the connection
//! drops the sender, which is the writer thread's exit signal after it
//! flushes what was already queued (so a DONE queued at removal still
//! reaches the socket).

use std::collections::HashMap;

use crate::util::bounded::Sender;
use crate::util::sync::Mutex;

use super::frame::{encode, BusyReason, Frame};

struct ConnState {
    /// encoded outbound frames, drained by the writer thread.
    tx: Sender<Vec<u8>>,
    /// server-side read id → client tag, for every admitted read not
    /// yet answered.
    outstanding: HashMap<usize, u64>,
    /// client sent FIN: queue DONE and drop once `outstanding` drains.
    fin: bool,
}

/// All live connections, keyed by tenant id (see module docs for the
/// locking discipline).
#[derive(Default)]
pub(crate) struct ConnectionRegistry {
    conns: Mutex<HashMap<u64, ConnState>>,
}

impl ConnectionRegistry {
    /// Register a fresh connection with its writer-thread channel.
    pub(crate) fn add(&self, tenant: u64, tx: Sender<Vec<u8>>) {
        let prev = self.conns.lock().unwrap().insert(tenant, ConnState {
            tx,
            outstanding: HashMap::new(),
            fin: false,
        });
        debug_assert!(prev.is_none(), "tenant ids are never reused");
    }

    /// Record an admitted read BEFORE it is submitted to the pipeline,
    /// so a result can never race ahead of its routing entry. False if
    /// the connection is already gone.
    pub(crate) fn track(&self, tenant: u64, read_id: usize, tag: u64)
        -> bool
    {
        let mut m = self.conns.lock().unwrap();
        match m.get_mut(&tenant) {
            Some(c) => {
                c.outstanding.insert(read_id, tag);
                true
            }
            None => false,
        }
    }

    /// Queue a RESULT for one completed read and, if that read was the
    /// last thing a FINished connection awaited, the DONE as well
    /// (removing the connection). False if the connection or the read
    /// is unknown — a late result for a dead tenant is dropped here.
    pub(crate) fn route_result(&self, tenant: u64, read_id: usize,
                               seq: &[u8]) -> bool {
        let mut m = self.conns.lock().unwrap();
        let Some(c) = m.get_mut(&tenant) else { return false };
        let Some(tag) = c.outstanding.remove(&read_id) else {
            return false;
        };
        let sent = c.tx
            .send(encode(&Frame::Result { tag, seq: seq.to_vec() }))
            .is_ok();
        if c.fin && c.outstanding.is_empty() {
            let _ = c.tx.send(encode(&Frame::Done));
            m.remove(&tenant);
        }
        sent
    }

    /// Queue a BUSY refusal for a submission that was never admitted
    /// (it has no outstanding entry to clear).
    pub(crate) fn send_busy(&self, tenant: u64, tag: u64,
                            reason: BusyReason) -> bool {
        let m = self.conns.lock().unwrap();
        match m.get(&tenant) {
            Some(c) => c.tx.send(encode(&Frame::Busy { tag, reason }))
                .is_ok(),
            None => false,
        }
    }

    /// Client sent FIN: if nothing is outstanding the DONE goes out now
    /// and the connection is removed (returns true — the reader may
    /// exit); otherwise the flag arms `route_result` to finish the
    /// drain.
    pub(crate) fn mark_fin(&self, tenant: u64) -> bool {
        let mut m = self.conns.lock().unwrap();
        let Some(c) = m.get_mut(&tenant) else { return true };
        c.fin = true;
        if c.outstanding.is_empty() {
            let _ = c.tx.send(encode(&Frame::Done));
            m.remove(&tenant);
            return true;
        }
        false
    }

    /// Tear down a connection that died (EOF without a clean DONE,
    /// protocol error, read error): returns how many reads it still
    /// awaited so the caller can cancel them at the collector and
    /// release their quota slots. Dropping the state drops the frame
    /// sender, which stops the writer thread.
    pub(crate) fn drop_conn(&self, tenant: u64) -> usize {
        self.conns.lock().unwrap()
            .remove(&tenant)
            .map_or(0, |c| c.outstanding.len())
    }

    /// Reads currently awaited by `tenant` (0 if gone).
    #[cfg(test)]
    pub(crate) fn outstanding(&self, tenant: u64) -> usize {
        self.conns.lock().unwrap()
            .get(&tenant).map_or(0, |c| c.outstanding.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bounded;

    use super::super::frame::FrameParser;

    fn drain(rx: &bounded::Receiver<Vec<u8>>) -> Vec<Frame> {
        let mut parser = FrameParser::default();
        while let Ok(b) = rx.try_recv() {
            parser.feed(&b);
        }
        let mut out = Vec::new();
        while let Some(f) = parser.next().unwrap() {
            out.push(f);
        }
        out
    }

    #[test]
    fn done_follows_last_result_after_fin() {
        let reg = ConnectionRegistry::default();
        let (tx, rx) = bounded::bounded(64);
        reg.add(9, tx);
        assert!(reg.track(9, 100, 7));
        assert!(reg.track(9, 101, 8));
        assert!(!reg.mark_fin(9), "two reads still outstanding");
        assert!(reg.route_result(9, 100, &[0, 1]));
        assert!(reg.route_result(9, 101, &[2]));
        let frames = drain(&rx);
        assert_eq!(frames, vec![
            Frame::Result { tag: 7, seq: vec![0, 1] },
            Frame::Result { tag: 8, seq: vec![2] },
            Frame::Done,
        ]);
        assert!(!reg.route_result(9, 100, &[]),
                "connection is gone after DONE");
    }

    #[test]
    fn fin_with_nothing_outstanding_is_immediate_done() {
        let reg = ConnectionRegistry::default();
        let (tx, rx) = bounded::bounded(64);
        reg.add(3, tx);
        assert!(reg.mark_fin(3));
        assert_eq!(drain(&rx), vec![Frame::Done]);
    }

    #[test]
    fn drop_conn_reports_orphans_and_silences_late_results() {
        let reg = ConnectionRegistry::default();
        let (tx, rx) = bounded::bounded(64);
        reg.add(4, tx);
        assert!(reg.track(4, 1, 10));
        assert!(reg.track(4, 2, 11));
        assert_eq!(reg.drop_conn(4), 2);
        assert!(!reg.route_result(4, 1, &[0]), "late result dropped");
        assert!(!reg.send_busy(4, 12, BusyReason::Quota));
        assert_eq!(drain(&rx), vec![], "nothing was queued");
        assert_eq!(reg.drop_conn(4), 0, "double drop is a no-op");
    }
}

// Schedule-exploration models for the routing-rule invariants
// (docs/CONCURRENCY.md). Compiled only under `--cfg helix_check`; run
// via `./ci.sh check`.
#[cfg(all(test, helix_check))]
mod model_tests {
    use super::*;
    use crate::util::bounded;
    use crate::util::check::{explore, spawn};
    use std::sync::Arc;

    use super::super::frame::FrameParser;

    fn frames(rx: &bounded::Receiver<Vec<u8>>) -> Vec<Frame> {
        let mut parser = FrameParser::default();
        while let Ok(b) = rx.try_recv() {
            parser.feed(&b);
        }
        let mut out = Vec::new();
        while let Some(f) = parser.next().unwrap() {
            out.push(f);
        }
        out
    }

    /// The registry queues exactly one DONE per tenant: when the last
    /// RESULT races the client's FIN, whichever of `route_result` /
    /// `mark_fin` observes `fin && outstanding.is_empty()` first queues
    /// the DONE and removes the connection — never both, never
    /// neither, and the DONE always follows the RESULT on the wire.
    #[test]
    fn model_last_result_vs_fin_queues_exactly_one_done() {
        explore("model_last_result_vs_fin_queues_exactly_one_done",
                200, || {
            let reg = Arc::new(ConnectionRegistry::default());
            let (tx, rx) = bounded::bounded(64);
            reg.add(5, tx);
            assert!(reg.track(5, 100, 7));
            let reg2 = Arc::clone(&reg);
            let h = spawn(move || reg2.route_result(5, 100, &[1, 2]));
            reg.mark_fin(5);
            assert!(h.join(), "the tracked result must route");
            let fs = frames(&rx);
            let dones = fs.iter()
                .filter(|f| matches!(f, Frame::Done)).count();
            let results = fs.iter()
                .filter(|f| matches!(f, Frame::Result { .. })).count();
            assert_eq!((results, dones), (1, 1),
                       "wire saw {fs:?} — exactly one RESULT then one \
                        DONE expected");
            assert!(matches!(fs.last(), Some(Frame::Done)),
                    "DONE must be the final frame");
            assert!(!reg.route_result(5, 100, &[]),
                    "connection must be gone after its DONE");
        });
    }

    /// A dying connection (`drop_conn`) racing a late `route_result`
    /// accounts for each outstanding read exactly once: either the
    /// result routed before the teardown (frame queued, zero orphans)
    /// or the teardown counted it as an orphan and the late result is
    /// dropped — never both, never neither, so quota release can key
    /// off the orphan count without double-freeing.
    #[test]
    fn model_drop_conn_vs_late_result_counts_read_once() {
        explore("model_drop_conn_vs_late_result_counts_read_once", 200,
                || {
            let reg = Arc::new(ConnectionRegistry::default());
            let (tx, rx) = bounded::bounded(64);
            reg.add(6, tx);
            assert!(reg.track(6, 42, 9));
            let reg2 = Arc::clone(&reg);
            let h = spawn(move || reg2.route_result(6, 42, &[3]));
            let orphans = reg.drop_conn(6);
            let routed = h.join();
            assert!(routed != (orphans == 1),
                    "read counted {}", if routed && orphans == 1 {
                        "twice (routed AND orphaned)"
                    } else {
                        "zero times (neither routed nor orphaned)"
                    });
            let queued = frames(&rx).iter()
                .filter(|f| matches!(f, Frame::Result { .. })).count();
            assert_eq!(queued, usize::from(routed));
        });
    }
}
