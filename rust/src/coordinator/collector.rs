//! Collector stage: per-read eager completion.
//!
//! Decode workers emit `DecodedWindow`s in whatever order batches and beam
//! searches finish. The collector's router thread assembles them against
//! the expected window count registered at `submit()` time, and the moment
//! a read's last window arrives it dispatches the read to a vote worker
//! pool that runs the within-read neighbour vote + splice
//! (`basecall::vote::vote_and_splice`) and pushes the finished
//! `CalledRead` onto the output queue. Consensus is therefore
//! pipelined with the DNN/decode stages instead of being single-threaded
//! caller-side work after the run, and `Coordinator::try_recv()` observes
//! reads mid-run.
//!
//! **Tiered serving needs no collector changes.** When the decode pool
//! escalates a low-confidence fast-tier window, it emits *no*
//! `DecodedWindow` for the fast attempt — the window's slot stays
//! unfilled, the read's arrival count does not advance, and the
//! collector simply keeps waiting until the hq re-run's decode arrives
//! under the same `(read_id, window_idx)` key. Exactly one delivery per
//! window reaches this stage in either mode, so the expected-count
//! completion rule and the vote/splice inputs are identical with
//! tiering on or off.
//!
//! Two extensions ride the same router (see [`Collector::spawn_full`]):
//! with a [`RejectGate`], a read any window condemned still completes —
//! its registry entry drains `in_flight()` — but is dropped before the
//! vote stage (`Metrics::rejected_reads`); with an analysis feeder,
//! every voted read is also side-fed into the streaming analysis pool
//! (`coordinator::analysis`) on its way to the output queue.

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::sync::Mutex;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::basecall::vote::vote_and_splice;
use crate::util::bounded::{unbounded, Feeder, Receiver};

use super::analysis::RejectGate;
use super::autoscale::{StagePool, WorkerPool};
use super::job::AnalysisJob;
use super::metrics::{Metrics, StageId};
use super::server::CalledRead;

/// Overlap floor for splicing neighbouring window decodes (samples the
/// windower's hop guarantees).
const SPLICE_MIN_OVERLAP: usize = 6;

/// One decoded window en route from the decode pool to the collector.
#[derive(Clone, Debug)]
pub struct DecodedWindow {
    /// read this window belongs to.
    pub read_id: usize,
    /// position of the window within the read.
    pub window_idx: usize,
    /// owning tenant of the read (0 = in-process library submission;
    /// see `Coordinator::submit_tagged`).
    pub tenant: u64,
    /// decoded base fragment.
    pub seq: Vec<u8>,
    /// the window's confidence margin fell below the reject
    /// threshold (or its read was already condemned): the read
    /// completes and drains normally, but the router drops it —
    /// no vote, no emission, no analysis — counting
    /// `Metrics::rejected_reads`.
    pub rejected: bool,
}

struct ReadEntry {
    expected: usize,
    submitted_at: Instant,
    tenant: u64,
    /// the owning connection disconnected mid-flight: the entry stays
    /// until the read's windows drain (so `in_flight()` reflects work
    /// still in the pipeline), but the completed assembly is dropped
    /// at the router instead of being voted and emitted.
    cancelled: bool,
}

/// What the router learns when a read's last window arrives (the entry
/// is removed either way — see [`ReadRegistry::complete`]).
enum Completion {
    /// the read is wanted: vote it and emit, stamping the latency from
    /// `submitted_at` and routing by `tenant`.
    Live { submitted_at: Instant, tenant: u64 },
    /// the owning tenant disconnected: drop the assembly.
    Cancelled { tenant: u64 },
    /// the read was never registered (windows injected without a
    /// `submit()`, e.g. collector unit tests): flush-complete it with
    /// no latency stamp.
    Unregistered,
}

/// Shared bookkeeping between `Coordinator::submit()` (which knows how
/// many windows each read was chopped into) and the collector router
/// (which must recognise a read's last window). Reads MUST be registered
/// before their first window enters the pipeline.
#[derive(Default)]
pub struct ReadRegistry {
    inner: Mutex<HashMap<usize, ReadEntry>>,
}

impl ReadRegistry {
    /// Record a read's expected window count (call BEFORE its first
    /// window enters the pipeline). Untenanted: equivalent to
    /// `register_tenant(read_id, expected, 0)`.
    pub fn register(&self, read_id: usize, expected: usize) {
        self.register_tenant(read_id, expected, 0);
    }

    /// Record a read's expected window count together with its owning
    /// tenant (0 = in-process library submission, a connection id for
    /// reads arriving over `coordinator::net`).
    pub fn register_tenant(&self, read_id: usize, expected: usize,
                           tenant: u64) {
        self.inner.lock().unwrap().insert(read_id, ReadEntry {
            expected,
            submitted_at: Instant::now(),
            tenant,
            cancelled: false,
        });
    }

    /// Mark every in-flight read of `tenant` cancelled: its windows
    /// keep draining through the pipeline (so backpressure and
    /// `in_flight()` stay truthful), but the router drops each
    /// completed assembly instead of voting and emitting it. Returns
    /// the number of reads marked. Cancelling tenant 0 is refused —
    /// that would silently discard library-path reads.
    pub fn cancel_tenant(&self, tenant: u64) -> usize {
        if tenant == 0 {
            return 0;
        }
        let mut n = 0;
        for e in self.inner.lock().unwrap().values_mut() {
            if e.tenant == tenant && !e.cancelled {
                e.cancelled = true;
                n += 1;
            }
        }
        n
    }

    fn expected(&self, read_id: usize) -> Option<usize> {
        self.inner.lock().unwrap().get(&read_id).map(|e| e.expected)
    }

    /// Remove a read's entry at assembly completion and report what to
    /// do with it (vote, drop, or flush without a latency stamp).
    fn complete(&self, read_id: usize) -> Completion {
        match self.inner.lock().unwrap().remove(&read_id) {
            Some(e) if e.cancelled =>
                Completion::Cancelled { tenant: e.tenant },
            Some(e) => Completion::Live {
                submitted_at: e.submitted_at,
                tenant: e.tenant,
            },
            None => Completion::Unregistered,
        }
    }

    /// Drop a registration whose windows never entered the pipeline
    /// (e.g. `submit()` after a mid-run DNN failure).
    pub(super) fn unregister(&self, read_id: usize) {
        self.inner.lock().unwrap().remove(&read_id);
    }

    /// Drop every remaining registration. Called by the router once the
    /// decoded stream has disconnected: no further window can ever
    /// arrive, so anything still registered is permanently stuck.
    fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }

    /// Reads whose windows are still somewhere in the pipeline (an entry
    /// is removed when the read is handed to the vote stage, just before
    /// its `CalledRead` is emitted). Telemetry/tests.
    pub fn in_flight(&self) -> usize {
        self.inner.lock().unwrap().len()
    }
}

/// Collector stage sizing.
#[derive(Clone, Copy, Debug)]
pub struct CollectorConfig {
    /// vote/splice worker count.
    pub vote_threads: usize,
    /// sizes the per-worker vote-job queues (shared with the rest of the
    /// pipeline's queue bound); the output queue is uncapped.
    pub queue_cap: usize,
}

impl Default for CollectorConfig {
    fn default() -> Self {
        CollectorConfig { vote_threads: 2, queue_cap: 256 }
    }
}

struct VoteJob {
    read_id: usize,
    tenant: u64,
    decodes: Vec<Vec<u8>>,
    submitted_at: Option<Instant>,
}

/// In-progress assembly of one read's windows.
struct Assembly {
    expected: Option<usize>,
    wins: Vec<Option<Vec<u8>>>,
    got: usize,
    /// any window arrived tagged rejected: drop the read at
    /// completion instead of voting it.
    rejected: bool,
}

/// Handle over the router thread + vote worker pool + output queue.
pub struct Collector {
    router: Option<JoinHandle<()>>,
    vote_pool: Option<Arc<WorkerPool<VoteJob>>>,
    rx_out: Receiver<CalledRead>,
}

impl Collector {
    /// Start the router thread and vote pool over a decoded-window
    /// stream; results surface through the returned handle. The vote
    /// workers live in a [`WorkerPool`] (QueueSet-backed slots), so
    /// the autoscale controller can retire and respawn them mid-run
    /// exactly like DNN shards; per-worker busy time lands in
    /// `Metrics::vote_workers` when the `Metrics` carries vote slots.
    /// No analysis side-feed, no reject gate — see
    /// [`Collector::spawn_full`].
    pub fn spawn(registry: Arc<ReadRegistry>,
                 rx_decoded: Receiver<DecodedWindow>,
                 metrics: Arc<Metrics>,
                 cfg: CollectorConfig) -> Collector {
        Collector::spawn_full(registry, rx_decoded, metrics, cfg,
                              None, None)
    }

    /// [`Collector::spawn`] plus the PR-9 extensions: with `analysis`
    /// set, every voted read is also side-fed (round-robin) into the
    /// streaming analysis pool's queues — the feeder moves into the
    /// vote workers, so the analysis queue set seals exactly when the
    /// last vote worker exits. With `gate` set, reads any window
    /// condemned are dropped at the router (completing their registry
    /// entry so `in_flight()` drains, counting
    /// `Metrics::rejected_reads`) and their gate marks are forgotten
    /// once no further window can arrive.
    pub(crate) fn spawn_full(registry: Arc<ReadRegistry>,
                             rx_decoded: Receiver<DecodedWindow>,
                             metrics: Arc<Metrics>,
                             cfg: CollectorConfig,
                             analysis: Option<Feeder<AnalysisJob>>,
                             gate: Option<Arc<RejectGate>>)
                             -> Collector {
        let n_vote = cfg.vote_threads.max(1);
        let vote_cap = (cfg.queue_cap / n_vote).max(8);
        // the output queue is deliberately unbounded: its occupancy is
        // bounded by the run's own result set, and a cap here would turn
        // a batch caller that only drains at finish() into a silent
        // whole-pipeline deadlock once a run outgrows the cap.
        let (tx_out, rx_out) = unbounded::<CalledRead>();

        // tx_out moves into the respawn closure, which clones it into
        // each spawned worker. The closure's prototype sender is the
        // reason finish() drops the pool before draining: the output
        // queue disconnects only when every sender is gone.
        let m_router = metrics.clone();
        let vote_pool = {
            let m = metrics.clone();
            WorkerPool::new(
                StageId::Vote, metrics, n_vote, vote_cap,
                Box::new(move |slot, rx: Receiver<VoteJob>| {
                    let out = tx_out.clone();
                    let m = m.clone();
                    let analysis = analysis.clone();
                    std::thread::spawn(move || {
                        // spread the analysis round-robin start points
                        // so vote workers do not gang up on slot 0
                        let mut rr_a = slot;
                        while let Ok(job) = rx.recv() {
                            let t0 = Instant::now();
                            let seq = vote_and_splice(&job.decodes,
                                                      SPLICE_MIN_OVERLAP);
                            let busy = t0.elapsed().as_micros() as u64;
                            m.add(&m.vote_micros, busy);
                            if let Some(st) = m.vote_workers.get(slot) {
                                m.add(&st.jobs, 1);
                                m.add(&st.busy_micros, busy);
                            }
                            m.add(&m.bases_called, seq.len() as u64);
                            m.add(&m.reads_out, 1);
                            if let Some(t) = job.submitted_at {
                                let us = t.elapsed().as_micros() as u64;
                                m.read_latency.record(us);
                                if job.tenant != 0 {
                                    let ts = m.tenant(job.tenant);
                                    m.add(&ts.reads_out, 1);
                                    ts.latency.record(us);
                                }
                            }
                            // side-feed the voted read into the
                            // streaming analysis stage BEFORE the
                            // emission (the caller-facing CalledRead
                            // is unchanged either way)
                            if let Some(f) = &analysis {
                                let _ = f.send_round_robin(
                                    &mut rr_a,
                                    AnalysisJob {
                                        read_id: job.read_id,
                                        tenant: job.tenant,
                                        seq: seq.clone(),
                                    });
                            }
                            if out.send(CalledRead {
                                read_id: job.read_id,
                                tenant: job.tenant,
                                seq,
                                window_decodes: job.decodes,
                            }).is_err() {
                                break; // output receiver gone
                            }
                        }
                    })
                }))
        };

        let vote_queues = vote_pool.queues();
        let router = std::thread::spawn(move || {
            let mut pending: HashMap<usize, Assembly> = HashMap::new();
            let mut rr = 0usize;
            // skip-over-backlogged round-robin to the vote pool; a
            // `false` return means every vote worker died — the job is
            // lost, which Collector::finish surfaces as a panic error.
            // A read whose tenant disconnected mid-flight is dropped
            // HERE, at assembly completion: its registry entry kept
            // in_flight() truthful while its windows drained, and no
            // vote work is spent on a result nobody can receive.
            let dispatch = |read_id: usize, a: Assembly, rr: &mut usize| {
                // the read's last window has drained: no further
                // window can consult the gate, so its mark can go
                if let Some(g) = &gate {
                    g.forget(read_id);
                }
                let (submitted_at, tenant) =
                    match registry.complete(read_id) {
                        Completion::Cancelled { tenant } => {
                            m_router.add(&m_router.dropped_reads, 1);
                            if tenant != 0 {
                                m_router.add(
                                    &m_router.tenant(tenant).dropped, 1);
                            }
                            return true;
                        }
                        Completion::Live { submitted_at, tenant } =>
                            (Some(submitted_at), tenant),
                        Completion::Unregistered => (None, 0),
                    };
                // GenPIP-style early exit lands here: a read any
                // window condemned completes (in_flight drains) but
                // is dropped before the vote stage spends on it
                if a.rejected {
                    m_router.add(&m_router.rejected_reads, 1);
                    return true;
                }
                let decodes: Vec<Vec<u8>> =
                    a.wins.into_iter().flatten().collect();
                vote_queues.send_round_robin(rr, VoteJob {
                    read_id,
                    tenant,
                    decodes,
                    submitted_at,
                })
            };
            while let Ok(d) = rx_decoded.recv() {
                let a = pending.entry(d.read_id).or_insert_with(|| {
                    Assembly {
                        expected: registry.expected(d.read_id),
                        wins: Vec::new(),
                        got: 0,
                        rejected: false,
                    }
                });
                a.rejected |= d.rejected;
                if a.wins.len() <= d.window_idx {
                    a.wins.resize(d.window_idx + 1, None);
                }
                if a.wins[d.window_idx].is_none() {
                    a.got += 1;
                }
                a.wins[d.window_idx] = Some(d.seq);
                if a.expected == Some(a.got) {
                    let done = pending.remove(&d.read_id).unwrap();
                    let _ = dispatch(d.read_id, done, &mut rr);
                }
            }
            // upstream closed (normal end-of-run, or a mid-run DNN
            // failure): flush whatever arrived so partial reads are not
            // silently lost.
            let mut rest: Vec<(usize, Assembly)> = pending.drain().collect();
            rest.sort_by_key(|(id, _)| *id);
            for (read_id, a) in rest {
                let _ = dispatch(read_id, a, &mut rr);
            }
            // registrations whose windows never arrived at all (a DNN
            // failure before their first window decoded) can never
            // complete now — drop them so in_flight() settles at 0.
            registry.clear();
            // same for gate marks: no window remains to consult them
            if let Some(g) = &gate {
                g.clear();
            }
            // seal the vote queue set: the workers drain and exit, and
            // the output queue disconnects once finish() has also
            // dropped the pool's respawn closure (the last sender).
            vote_queues.close_all();
        });

        Collector {
            router: Some(router),
            vote_pool: Some(vote_pool),
            rx_out,
        }
    }

    /// The vote pool as a controller-facing stage pool, for the
    /// coordinator to register under `AutoscaleConfig::scale_vote`.
    pub(super) fn vote_stage_pool(&self) -> Option<Arc<dyn StagePool>> {
        self.vote_pool.clone()
            .map(|p| p as Arc<dyn StagePool>)
    }

    /// Vote workers live right now (telemetry/tests).
    pub(super) fn live_vote_workers(&self) -> usize {
        self.vote_pool.as_ref().map_or(0, |p| p.live_count())
    }

    /// Non-blocking: a read whose last window has decoded, if any.
    pub fn try_recv(&self) -> Option<CalledRead> {
        self.rx_out.try_recv().ok()
    }

    /// Block up to `timeout` for the next completed read. `None` means
    /// timeout OR pipeline fully drained; use `finish` to disambiguate.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<CalledRead> {
        self.rx_out.recv_timeout(timeout).ok()
    }

    /// Deterministic drain: block until the pipeline disconnects
    /// end-to-end, return every remaining read, and join the workers.
    /// Upstream senders must already be closed or closing, otherwise this
    /// blocks until they are. A router or vote-worker panic surfaces as
    /// `Err` instead of silently returning a short result set.
    pub fn finish(mut self) -> Result<Vec<CalledRead>> {
        // release the vote pool FIRST: its respawn closure holds the
        // output queue's prototype sender, and the drain below ends
        // only when every sender (workers + closure) is gone. The
        // autoscale controller — the only other pool holder — is
        // always joined before Coordinator::finish reaches this point,
        // so no new worker can spawn under us.
        let vote_handles = match self.vote_pool.take() {
            Some(pool) => pool.take_handles(),
            None => Vec::new(),
        };
        let mut out = Vec::new();
        while let Ok(r) = self.rx_out.recv() {
            out.push(r);
        }
        let mut panicked = false;
        if let Some(h) = self.router.take() {
            panicked |= h.join().is_err();
        }
        for h in vote_handles {
            panicked |= h.join().is_err();
        }
        anyhow::ensure!(!panicked,
                        "collector stage panicked mid-run ({} reads were \
                         recovered before the failure)", out.len());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bounded::{bounded, Sender};

    fn spawn_collector(queue_cap: usize)
        -> (Arc<ReadRegistry>, Sender<DecodedWindow>, Collector,
            Arc<Metrics>)
    {
        let registry = Arc::new(ReadRegistry::default());
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = bounded::<DecodedWindow>(queue_cap);
        let col = Collector::spawn(registry.clone(), rx, metrics.clone(),
                                   CollectorConfig {
                                       vote_threads: 2,
                                       queue_cap,
                                   });
        (registry, tx, col, metrics)
    }

    fn win(read_id: usize, window_idx: usize, seq: &[u8]) -> DecodedWindow {
        DecodedWindow {
            read_id,
            window_idx,
            tenant: 0,
            seq: seq.to_vec(),
            rejected: false,
        }
    }

    #[test]
    fn out_of_order_windows_assemble_in_order() {
        let (reg, tx, col, metrics) = spawn_collector(64);
        reg.register(7, 3);
        // arrival order 2, 0, 1 — window_idx must still win
        tx.send(win(7, 2, &[2, 2, 2, 2, 2, 2, 2, 2])).unwrap();
        tx.send(win(7, 0, &[0, 0, 0, 0, 0, 0, 0, 0])).unwrap();
        tx.send(win(7, 1, &[1, 1, 1, 1, 1, 1, 1, 1])).unwrap();
        // eager: the read completes while the input channel is still open
        let r = col.recv_timeout(Duration::from_secs(5))
            .expect("read should complete before end-of-run");
        assert_eq!(r.read_id, 7);
        assert_eq!(r.window_decodes.len(), 3);
        assert_eq!(r.window_decodes[0], vec![0u8; 8]);
        assert_eq!(r.window_decodes[1], vec![1u8; 8]);
        assert_eq!(r.window_decodes[2], vec![2u8; 8]);
        assert_eq!(metrics.reads_out
                       .load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(metrics.read_latency.count(), 1);
        drop(tx);
        assert!(col.finish().unwrap().is_empty());
    }

    #[test]
    fn eager_completion_is_per_read() {
        let (reg, tx, col, _m) = spawn_collector(64);
        reg.register(1, 2);
        reg.register(2, 2);
        // read 2 completes while read 1 is still missing a window
        tx.send(win(1, 0, &[0, 1, 2, 3])).unwrap();
        tx.send(win(2, 0, &[3, 2, 1, 0])).unwrap();
        tx.send(win(2, 1, &[3, 2, 1, 0])).unwrap();
        let first = col.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(first.read_id, 2);
        assert!(col.try_recv().is_none(), "read 1 must still be pending");
        assert_eq!(reg.in_flight(), 1);
        tx.send(win(1, 1, &[0, 1, 2, 3])).unwrap();
        let second = col.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(second.read_id, 1);
        drop(tx);
        assert!(col.finish().unwrap().is_empty());
        assert_eq!(reg.in_flight(), 0);
    }

    #[test]
    fn duplicate_window_does_not_double_complete() {
        let (reg, tx, col, _m) = spawn_collector(64);
        reg.register(4, 2);
        tx.send(win(4, 0, &[1, 1, 1, 1])).unwrap();
        tx.send(win(4, 0, &[2, 2, 2, 2])).unwrap(); // re-delivery
        assert!(col.try_recv().is_none());
        tx.send(win(4, 1, &[3, 3, 3, 3])).unwrap();
        let r = col.recv_timeout(Duration::from_secs(5)).unwrap();
        // last delivery wins
        assert_eq!(r.window_decodes[0], vec![2u8; 4]);
        drop(tx);
        assert!(col.finish().unwrap().is_empty());
    }

    #[test]
    fn incomplete_reads_flush_at_shutdown() {
        let (reg, tx, col, _m) = spawn_collector(64);
        reg.register(9, 3);
        tx.send(win(9, 0, &[0, 1, 2, 3, 0, 1, 2, 3])).unwrap();
        tx.send(win(9, 2, &[2, 3, 0, 1, 2, 3, 0, 1])).unwrap();
        drop(tx); // e.g. the DNN stage died mid-run
        let out = col.finish().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].read_id, 9);
        // the gap at window 1 is skipped, order preserved
        assert_eq!(out[0].window_decodes.len(), 2);
        assert_eq!(out[0].window_decodes[0][0], 0);
        assert_eq!(out[0].window_decodes[1][0], 2);
    }

    /// A tenant disconnect mid-flight: the read's windows keep
    /// draining (in_flight() stays truthful until the last one lands),
    /// but the completed assembly is dropped at the router — no vote,
    /// no emission — and `dropped_reads` records the drop.
    #[test]
    fn cancelled_tenant_read_drops_at_completion() {
        use std::sync::atomic::Ordering;
        let (reg, tx, col, m) = spawn_collector(64);
        reg.register_tenant(11, 2, 5);
        reg.register_tenant(12, 1, 6);
        tx.send(DecodedWindow {
            read_id: 11, window_idx: 0, tenant: 5, seq: vec![1, 2, 3, 0],
            rejected: false,
        }).unwrap();
        assert_eq!(reg.cancel_tenant(5), 1, "one read of tenant 5 marked");
        assert_eq!(reg.in_flight(), 2,
                   "cancelled read still drains through the pipeline");
        // the cancelled read's last window arrives: dropped, not voted
        tx.send(DecodedWindow {
            read_id: 11, window_idx: 1, tenant: 5, seq: vec![0, 1, 2, 3],
            rejected: false,
        }).unwrap();
        // tenant 6 is unaffected and completes normally
        tx.send(DecodedWindow {
            read_id: 12, window_idx: 0, tenant: 6, seq: vec![2, 2, 2, 2],
            rejected: false,
        }).unwrap();
        let r = col.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.read_id, 12);
        assert_eq!(r.tenant, 6);
        drop(tx);
        assert!(col.finish().unwrap().is_empty(),
                "the cancelled read must never be emitted");
        assert_eq!(reg.in_flight(), 0, "in_flight settles to 0");
        assert_eq!(m.dropped_reads.load(Ordering::Relaxed), 1);
        assert_eq!(m.tenant(5).dropped.load(Ordering::Relaxed), 1);
    }

    /// Cancelled reads are also dropped on the end-of-stream flush
    /// path (a tenant dies, then the run ends before its windows all
    /// arrive): the partial assembly must not leak into the output.
    #[test]
    fn cancelled_read_drops_on_flush_too() {
        use std::sync::atomic::Ordering;
        let (reg, tx, col, m) = spawn_collector(64);
        reg.register_tenant(3, 4, 9);
        tx.send(DecodedWindow {
            read_id: 3, window_idx: 0, tenant: 9, seq: vec![1, 1, 1, 1],
            rejected: false,
        }).unwrap();
        assert_eq!(reg.cancel_tenant(9), 1);
        drop(tx); // stream ends with the read incomplete
        assert!(col.finish().unwrap().is_empty());
        assert_eq!(m.dropped_reads.load(Ordering::Relaxed), 1);
        assert_eq!(reg.in_flight(), 0);
    }

    /// Cancelling tenant 0 (the library path) is refused, and
    /// cancelling an unknown tenant is a no-op.
    #[test]
    fn cancel_tenant_guards() {
        let reg = ReadRegistry::default();
        reg.register(1, 2); // library read (tenant 0)
        assert_eq!(reg.cancel_tenant(0), 0, "tenant 0 must be refused");
        assert_eq!(reg.cancel_tenant(42), 0, "unknown tenant: no-op");
        assert_eq!(reg.in_flight(), 1);
    }

    fn spawn_full_collector(gate: Option<Arc<RejectGate>>,
                            analysis: Option<Feeder<AnalysisJob>>)
        -> (Arc<ReadRegistry>, Sender<DecodedWindow>, Collector,
            Arc<Metrics>)
    {
        let registry = Arc::new(ReadRegistry::default());
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = bounded::<DecodedWindow>(64);
        let col = Collector::spawn_full(
            registry.clone(), rx, metrics.clone(),
            CollectorConfig { vote_threads: 2, queue_cap: 64 },
            analysis, gate);
        (registry, tx, col, metrics)
    }

    /// A read with a rejected window completes (in_flight drains, the
    /// gate mark is forgotten) but is dropped before the vote stage:
    /// never emitted, counted in `rejected_reads`, and the healthy
    /// read beside it is untouched.
    #[test]
    fn rejected_read_drops_before_vote() {
        use std::sync::atomic::Ordering;
        let gate = Arc::new(RejectGate::new(f32::INFINITY));
        gate.mark(21); // the decode pool condemned read 21
        let (reg, tx, col, m) =
            spawn_full_collector(Some(gate.clone()), None);
        reg.register(21, 2);
        reg.register(22, 1);
        tx.send(DecodedWindow {
            read_id: 21, window_idx: 0, tenant: 0,
            seq: vec![1, 1, 1, 1], rejected: false,
        }).unwrap();
        tx.send(DecodedWindow {
            read_id: 21, window_idx: 1, tenant: 0,
            seq: Vec::new(), rejected: true,
        }).unwrap();
        tx.send(win(22, 0, &[2, 0, 2, 0])).unwrap();
        let r = col.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.read_id, 22, "healthy read unaffected");
        drop(tx);
        assert!(col.finish().unwrap().is_empty(),
                "a rejected read must never be emitted");
        assert_eq!(m.rejected_reads.load(Ordering::Relaxed), 1);
        assert_eq!(m.reads_out.load(Ordering::Relaxed), 1,
                   "no vote was spent on the rejected read");
        assert_eq!(reg.in_flight(), 0,
                   "the rejected read still drains the registry");
        assert!(!gate.is_rejected(21),
                "the mark is forgotten once the read drains");
    }

    /// With an analysis feeder, every voted read lands in the
    /// streaming analysis state too — and the caller-facing emission
    /// is unchanged.
    #[test]
    fn voted_reads_side_feed_the_analysis_pool() {
        use crate::coordinator::analysis::{spawn_analysis_pool,
                                           AnalysisState};
        let state = Arc::new(AnalysisState::new(20));
        let metrics = Arc::new(Metrics::default());
        let pool = spawn_analysis_pool(metrics.clone(), 2, 8,
                                       state.clone());
        let feeder = Feeder::new(pool.queues());
        let (reg, tx, col, _m) =
            spawn_full_collector(None, Some(feeder));
        reg.register(1, 1);
        tx.send(win(1, 0, &[0, 1, 2, 3, 0, 1, 2, 3])).unwrap();
        let r = col.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.read_id, 1);
        drop(tx);
        // vote workers exit at finish(); the feeder clones drop with
        // them, sealing the analysis queues so the workers drain out
        col.finish().unwrap();
        for h in pool.take_handles() {
            h.join().unwrap();
        }
        assert_eq!(state.reads_indexed(0), 1);
        assert_eq!(state.contigs(0), vec![r.seq]);
    }

    #[test]
    fn unregistered_read_still_flushes() {
        let (_reg, tx, col, _m) = spawn_collector(64);
        tx.send(win(3, 0, &[1, 2, 3, 0])).unwrap();
        assert!(col.try_recv().is_none(), "unknown total: cannot be eager");
        drop(tx);
        let out = col.finish().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].read_id, 3);
    }
}
