//! Dynamic batching: size-or-deadline policy over a bounded queue.

use std::time::{Duration, Instant};

use crate::util::bounded::{Receiver, RecvTimeoutError};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// launch as soon as this many items are queued.
    pub max_batch: usize,
    /// ... or when the oldest item has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(20) }
    }
}

/// A collected batch plus queueing telemetry.
#[derive(Debug)]
pub struct Batch<T> {
    /// the collected jobs, in arrival order.
    pub items: Vec<T>,
    /// how long the oldest item waited before launch.
    pub oldest_wait: Duration,
    /// whether the size (true) or the deadline (false) triggered launch.
    pub full: bool,
}

impl<T> Batch<T> {
    /// A *tail* batch: launched by the deadline before filling up.
    /// The coordinator routes these differently from full batches —
    /// full batches go to the least-loaded shard (spread the heavy
    /// work), tail batches go to the *busiest* live shard, so a
    /// trickle of small deadline-triggered launches rides along on the
    /// replica that is already hot instead of fragmenting the pool and
    /// keeping idle shards from being retired (or, under the
    /// autoscaler, from staying retired).
    pub fn is_tail(&self) -> bool {
        !self.full
    }
}

/// Pulls batches off a bounded channel according to the policy. Returns
/// None when the channel is closed and drained. Because the feeding
/// channel is bounded, a batcher that falls behind backpressures
/// `Coordinator::submit()` instead of letting the queue grow without
/// limit.
///
/// The deadline clock starts at the batch's first item's **enqueue**
/// time when the items carry one (`with_stamp`), falling back to
/// first-dequeue time otherwise (`new`). The distinction matters under
/// backpressure: an item that sat queued for 30ms behind a slow run
/// has already spent its latency budget, so the deadline is treated as
/// elapsed — the batch launches with whatever is queued instead of
/// waiting another `max_wait` — and `Batch::oldest_wait` reports the
/// true queue-to-launch wait.
pub struct Batcher<T> {
    rx: Receiver<T>,
    policy: BatchPolicy,
    closed: bool,
    stamp: Option<fn(&T) -> Instant>,
}

impl<T> Batcher<T> {
    /// Wrap the stage's input channel with a batching policy; the
    /// deadline clock starts when the first item of each batch is
    /// dequeued (blind to queue wait — prefer `with_stamp` when the
    /// item type records its enqueue time).
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy, closed: false, stamp: None }
    }

    /// Like `new`, but `stamp` extracts each item's enqueue timestamp
    /// and the deadline clock starts at the batch's first item's
    /// enqueue — time spent queued behind backpressure counts against
    /// `max_wait` and shows up in `Batch::oldest_wait`.
    pub fn with_stamp(rx: Receiver<T>, policy: BatchPolicy,
                      stamp: fn(&T) -> Instant) -> Self {
        Batcher { rx, policy, closed: false, stamp: Some(stamp) }
    }

    /// Block for the next batch (size or deadline triggered); `None`
    /// once the channel is closed and drained.
    pub fn next_batch(&mut self) -> Option<Batch<T>> {
        if self.closed {
            return None;
        }
        // block for the first item
        let first = match self.rx.recv() {
            Ok(x) => x,
            Err(_) => {
                self.closed = true;
                return None;
            }
        };
        let start = match self.stamp {
            Some(f) => f(&first),
            None => Instant::now(),
        };
        let mut items = vec![first];
        let mut full = false;
        while items.len() < self.policy.max_batch {
            let remaining = self.policy.max_wait
                .checked_sub(start.elapsed())
                .unwrap_or(Duration::ZERO);
            match self.rx.recv_timeout(remaining) {
                Ok(x) => items.push(x),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
        }
        if items.len() >= self.policy.max_batch {
            full = true;
        }
        Some(Batch { items, oldest_wait: start.elapsed(), full })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bounded::bounded;

    #[test]
    fn size_trigger() {
        let (tx, rx) = bounded(16);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut b = Batcher::new(rx, BatchPolicy {
            max_batch: 4, max_wait: Duration::from_secs(5),
        });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![0, 1, 2, 3]);
        assert!(batch.full);
        assert!(!batch.is_tail());
        assert_eq!(b.next_batch().unwrap().items, vec![4, 5, 6, 7]);
    }

    #[test]
    fn deadline_trigger() {
        let (tx, rx) = bounded(16);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let mut b = Batcher::new(rx, BatchPolicy {
            max_batch: 100, max_wait: Duration::from_millis(10),
        });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![1, 2]);
        assert!(!batch.full);
        assert!(batch.is_tail(), "deadline-triggered launch is a tail");
        assert!(batch.oldest_wait >= Duration::from_millis(9));
    }

    #[test]
    fn stamped_batcher_counts_queue_wait_toward_deadline() {
        // regression: the deadline clock used to start at first
        // DEQUEUE, so items queued behind backpressure waited a full
        // extra max_wait and oldest_wait under-reported their latency.
        struct J(Instant);
        let (tx, rx) = bounded::<J>(16);
        // pre-fill the queue BEFORE the batcher ever drains it
        for _ in 0..3 {
            tx.send(J(Instant::now())).unwrap();
        }
        std::thread::sleep(Duration::from_millis(30));
        let mut b = Batcher::with_stamp(rx, BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(20),
        }, |j: &J| j.0);
        let batch = b.next_batch().unwrap();
        // the 30ms already spent queued blew the 20ms budget: the
        // batch launches with what is queued, reporting the true wait
        assert_eq!(batch.items.len(), 3);
        assert!(batch.is_tail());
        assert!(batch.oldest_wait >= Duration::from_millis(29),
                "oldest_wait {:?} must include time queued before the \
                 first dequeue", batch.oldest_wait);
    }

    #[test]
    fn unstamped_batcher_keeps_dequeue_clock() {
        // without a stamp the old semantics hold: the clock starts at
        // first dequeue, so a pre-filled queue still waits max_wait
        let (tx, rx) = bounded::<u32>(16);
        tx.send(1).unwrap();
        std::thread::sleep(Duration::from_millis(40));
        let mut b = Batcher::new(rx, BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
        });
        let batch = b.next_batch().unwrap();
        assert!(batch.oldest_wait < Duration::from_millis(40),
                "unstamped oldest_wait {:?} starts at dequeue, not at \
                 the 40ms-old enqueue", batch.oldest_wait);
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = bounded(16);
        tx.send(7).unwrap();
        drop(tx);
        let mut b = Batcher::new(rx, BatchPolicy::default());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![7]);
        assert!(b.next_batch().is_none());
        assert!(b.next_batch().is_none());
    }
}
