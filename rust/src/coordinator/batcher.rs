//! Dynamic batching: size-or-deadline policy over a bounded queue,
//! plus the two-lane [`TieredBatcher`] that also accepts re-queued
//! (escalated) items on a side channel without mixing them into fresh
//! batches.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::util::sync::AtomicU64;
use std::time::{Duration, Instant};

use crate::util::bounded::{Receiver, RecvTimeoutError, TryRecvError};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// launch as soon as this many items are queued.
    pub max_batch: usize,
    /// ... or when the oldest item has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(20) }
    }
}

/// A collected batch plus queueing telemetry.
#[derive(Debug)]
pub struct Batch<T> {
    /// the collected jobs, in arrival order.
    pub items: Vec<T>,
    /// how long the oldest item waited before launch.
    pub oldest_wait: Duration,
    /// whether the size (true) or the deadline (false) triggered launch.
    pub full: bool,
}

impl<T> Batch<T> {
    /// A *tail* batch: launched by the deadline before filling up.
    /// The coordinator routes these differently from full batches —
    /// full batches go to the least-loaded shard (spread the heavy
    /// work), tail batches go to the *busiest* live shard, so a
    /// trickle of small deadline-triggered launches rides along on the
    /// replica that is already hot instead of fragmenting the pool and
    /// keeping idle shards from being retired (or, under the
    /// autoscaler, from staying retired).
    pub fn is_tail(&self) -> bool {
        !self.full
    }
}

/// Pulls batches off a bounded channel according to the policy. Returns
/// None when the channel is closed and drained. Because the feeding
/// channel is bounded, a batcher that falls behind backpressures
/// `Coordinator::submit()` instead of letting the queue grow without
/// limit.
///
/// The deadline clock starts at the batch's first item's **enqueue**
/// time when the items carry one (`with_stamp`), falling back to
/// first-dequeue time otherwise (`new`). The distinction matters under
/// backpressure: an item that sat queued for 30ms behind a slow run
/// has already spent its latency budget, so the deadline is treated as
/// elapsed — the batch launches with whatever is queued instead of
/// waiting another `max_wait` — and `Batch::oldest_wait` reports the
/// true queue-to-launch wait.
pub struct Batcher<T> {
    rx: Receiver<T>,
    policy: BatchPolicy,
    closed: bool,
    stamp: Option<fn(&T) -> Instant>,
}

impl<T> Batcher<T> {
    /// Wrap the stage's input channel with a batching policy; the
    /// deadline clock starts when the first item of each batch is
    /// dequeued (blind to queue wait — prefer `with_stamp` when the
    /// item type records its enqueue time).
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy, closed: false, stamp: None }
    }

    /// Like `new`, but `stamp` extracts each item's enqueue timestamp
    /// and the deadline clock starts at the batch's first item's
    /// enqueue — time spent queued behind backpressure counts against
    /// `max_wait` and shows up in `Batch::oldest_wait`.
    pub fn with_stamp(rx: Receiver<T>, policy: BatchPolicy,
                      stamp: fn(&T) -> Instant) -> Self {
        Batcher { rx, policy, closed: false, stamp: Some(stamp) }
    }

    /// Block for the next batch (size or deadline triggered); `None`
    /// once the channel is closed and drained.
    pub fn next_batch(&mut self) -> Option<Batch<T>> {
        if self.closed {
            return None;
        }
        // block for the first item
        let Ok(first) = self.rx.recv() else {
            self.closed = true;
            return None;
        };
        let start = match self.stamp {
            Some(f) => f(&first),
            None => Instant::now(),
        };
        let mut items = vec![first];
        let mut full = false;
        while items.len() < self.policy.max_batch {
            let remaining = self.policy.max_wait
                .checked_sub(start.elapsed())
                .unwrap_or(Duration::ZERO);
            match self.rx.recv_timeout(remaining) {
                Ok(x) => items.push(x),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
        }
        if items.len() >= self.policy.max_batch {
            full = true;
        }
        Some(Batch { items, oldest_wait: start.elapsed(), full })
    }
}

/// Lane index of fresh (fast-tier) items in a [`TieredBatcher`].
pub const LANE_FRESH: usize = 0;
/// Lane index of re-queued (hq escalation) items in a
/// [`TieredBatcher`].
pub const LANE_REQUEUE: usize = 1;

/// Two-lane batcher for tiered serving: fresh items arrive on the
/// bounded intake channel and re-queued items (decode-confidence
/// escalations) on an unbounded side channel, each accumulating in its
/// own lane under the same size-or-deadline [`BatchPolicy`]. Lanes
/// never mix — a batch is entirely fresh ([`LANE_FRESH`]) or entirely
/// re-queued ([`LANE_REQUEUE`]) — and when both trigger at once the
/// re-queue lane flushes first (an escalated window is the oldest work
/// in the pipeline; its read is stalled on it).
///
/// The deadline clock is enqueue-anchored exactly like
/// [`Batcher::with_stamp`]: `stamp` extracts each item's enqueue (or
/// re-enqueue) timestamp and a lane launches when its **oldest** item
/// has waited `max_wait`.
///
/// Shutdown is two-phase because re-queued items chase in-flight work:
/// after the fresh channel disconnects, the batcher keeps serving the
/// re-queue lane until `pending` — the number of dispatched fast-tier
/// items whose keep-or-escalate decision has not been made yet, which
/// the dispatcher increments *before* sending a fast batch and the
/// decode workers decrement (`Release`) *after* sending any
/// escalation — reads zero, then drains the side channel once more
/// (the decrement follows the send, so a zero count proves any
/// escalation is already in the channel) and ends the stream. A
/// disconnected side channel ends it unconditionally.
pub struct TieredBatcher<T> {
    fresh: Receiver<T>,
    requeue: Receiver<T>,
    policy: BatchPolicy,
    stamp: fn(&T) -> Instant,
    pending: Arc<AtomicU64>,
    lanes: [VecDeque<T>; 2],
    fresh_open: bool,
    requeue_open: bool,
}

impl<T> TieredBatcher<T> {
    /// Wrap the fresh intake and the re-queue side channel. `stamp`
    /// extracts an item's (re-)enqueue timestamp; `pending` is the
    /// in-flight fast-tier decision counter shared with the decode
    /// workers (see the type docs for the shutdown protocol).
    pub fn new(fresh: Receiver<T>, requeue: Receiver<T>,
               policy: BatchPolicy, stamp: fn(&T) -> Instant,
               pending: Arc<AtomicU64>) -> Self {
        TieredBatcher {
            fresh,
            requeue,
            policy,
            stamp,
            pending,
            lanes: [VecDeque::new(), VecDeque::new()],
            fresh_open: true,
            requeue_open: true,
        }
    }

    /// Non-blocking drain of the re-queue side channel into its lane.
    /// The channel is unbounded, so take everything available.
    fn drain_requeue(&mut self) {
        while self.requeue_open {
            match self.requeue.try_recv() {
                Ok(x) => self.lanes[LANE_REQUEUE].push_back(x),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.requeue_open = false;
                }
            }
        }
    }

    /// Non-blocking drain of the fresh intake, capped at one batch in
    /// the lane so backpressure stays on the bounded channel.
    fn drain_fresh(&mut self) {
        while self.fresh_open
            && self.lanes[LANE_FRESH].len() < self.policy.max_batch
        {
            match self.fresh.try_recv() {
                Ok(x) => self.lanes[LANE_FRESH].push_back(x),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.fresh_open = false;
                }
            }
        }
    }

    /// Take up to one batch off the front of `lane`.
    fn flush(&mut self, lane: usize, full: bool) -> Batch<T> {
        let n = self.lanes[lane].len().min(self.policy.max_batch);
        let oldest_wait = self.lanes[lane].front()
            .map(|x| (self.stamp)(x).elapsed())
            .unwrap_or(Duration::ZERO);
        let items: Vec<T> = self.lanes[lane].drain(..n).collect();
        Batch { items, oldest_wait, full }
    }

    /// How long the blocking wait may sleep before re-polling the side
    /// channel: a fraction of `max_wait`, clamped so escalations are
    /// noticed promptly even under second-scale batch deadlines.
    fn poll_quantum(&self) -> Duration {
        (self.policy.max_wait / 4)
            .clamp(Duration::from_micros(500), Duration::from_millis(5))
    }

    /// Block for the next batch from either lane; `None` once the
    /// fresh channel is closed, both lanes are drained, and no
    /// in-flight fast-tier item can still produce a re-queue.
    pub fn next_batch(&mut self) -> Option<(usize, Batch<T>)> {
        loop {
            self.drain_requeue();
            self.drain_fresh();
            // size trigger, re-queue lane first
            for lane in [LANE_REQUEUE, LANE_FRESH] {
                if self.lanes[lane].len() >= self.policy.max_batch {
                    return Some((lane, self.flush(lane, true)));
                }
            }
            // deadline trigger on each lane's oldest stamp
            let now = Instant::now();
            for lane in [LANE_REQUEUE, LANE_FRESH] {
                if let Some(front) = self.lanes[lane].front() {
                    if now.duration_since((self.stamp)(front))
                        >= self.policy.max_wait
                    {
                        return Some((lane, self.flush(lane, false)));
                    }
                }
            }
            // no further input can arrive: flush what is left as tails
            if !self.fresh_open && !self.requeue_open {
                for lane in [LANE_REQUEUE, LANE_FRESH] {
                    if !self.lanes[lane].is_empty() {
                        return Some((lane, self.flush(lane, false)));
                    }
                }
                return None;
            }
            // fresh intake done and nothing buffered: end the stream
            // once no dispatched fast-tier item can still escalate.
            // The decode-side decrement (Release) follows its re-queue
            // send, so observing zero (Acquire) proves any escalation
            // is already in the side channel — drain once more to
            // close the race, then finish.
            if !self.fresh_open
                && self.lanes[LANE_FRESH].is_empty()
                && self.lanes[LANE_REQUEUE].is_empty()
                && self.pending.load(Ordering::Acquire) == 0
            {
                self.drain_requeue();
                if self.lanes[LANE_REQUEUE].is_empty() {
                    return None;
                }
                continue;
            }
            // block for more input, waking at the nearest lane
            // deadline — or at the poll quantum while escalations may
            // still land on the side channel
            let mut wait = if !self.lanes[LANE_REQUEUE].is_empty()
                || self.pending.load(Ordering::Acquire) > 0
            {
                self.poll_quantum()
            } else {
                self.policy.max_wait.max(self.poll_quantum())
            };
            for lane in [LANE_REQUEUE, LANE_FRESH] {
                if let Some(front) = self.lanes[lane].front() {
                    let spent = now.duration_since((self.stamp)(front));
                    wait = wait.min(
                        self.policy.max_wait.saturating_sub(spent));
                }
            }
            if self.fresh_open {
                match self.fresh.recv_timeout(wait) {
                    Ok(x) => self.lanes[LANE_FRESH].push_back(x),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        self.fresh_open = false;
                    }
                }
            } else {
                match self.requeue.recv_timeout(wait) {
                    Ok(x) => self.lanes[LANE_REQUEUE].push_back(x),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        self.requeue_open = false;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bounded::bounded;

    #[test]
    fn size_trigger() {
        let (tx, rx) = bounded(16);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut b = Batcher::new(rx, BatchPolicy {
            max_batch: 4, max_wait: Duration::from_secs(5),
        });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![0, 1, 2, 3]);
        assert!(batch.full);
        assert!(!batch.is_tail());
        assert_eq!(b.next_batch().unwrap().items, vec![4, 5, 6, 7]);
    }

    #[test]
    fn deadline_trigger() {
        let (tx, rx) = bounded(16);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let mut b = Batcher::new(rx, BatchPolicy {
            max_batch: 100, max_wait: Duration::from_millis(10),
        });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![1, 2]);
        assert!(!batch.full);
        assert!(batch.is_tail(), "deadline-triggered launch is a tail");
        assert!(batch.oldest_wait >= Duration::from_millis(9));
    }

    #[test]
    fn stamped_batcher_counts_queue_wait_toward_deadline() {
        // regression: the deadline clock used to start at first
        // DEQUEUE, so items queued behind backpressure waited a full
        // extra max_wait and oldest_wait under-reported their latency.
        struct J(Instant);
        let (tx, rx) = bounded::<J>(16);
        // pre-fill the queue BEFORE the batcher ever drains it
        for _ in 0..3 {
            tx.send(J(Instant::now())).unwrap();
        }
        std::thread::sleep(Duration::from_millis(30));
        let mut b = Batcher::with_stamp(rx, BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(20),
        }, |j: &J| j.0);
        let batch = b.next_batch().unwrap();
        // the 30ms already spent queued blew the 20ms budget: the
        // batch launches with what is queued, reporting the true wait
        assert_eq!(batch.items.len(), 3);
        assert!(batch.is_tail());
        assert!(batch.oldest_wait >= Duration::from_millis(29),
                "oldest_wait {:?} must include time queued before the \
                 first dequeue", batch.oldest_wait);
    }

    #[test]
    fn unstamped_batcher_keeps_dequeue_clock() {
        // without a stamp the old semantics hold: the clock starts at
        // first dequeue, so a pre-filled queue still waits max_wait
        let (tx, rx) = bounded::<u32>(16);
        tx.send(1).unwrap();
        std::thread::sleep(Duration::from_millis(40));
        let mut b = Batcher::new(rx, BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
        });
        let batch = b.next_batch().unwrap();
        assert!(batch.oldest_wait < Duration::from_millis(40),
                "unstamped oldest_wait {:?} starts at dequeue, not at \
                 the 40ms-old enqueue", batch.oldest_wait);
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = bounded(16);
        tx.send(7).unwrap();
        drop(tx);
        let mut b = Batcher::new(rx, BatchPolicy::default());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![7]);
        assert!(b.next_batch().is_none());
        assert!(b.next_batch().is_none());
    }

    /// Test item for the tiered batcher: enqueue stamp + payload.
    struct J(Instant, u32);

    fn j(v: u32) -> J {
        J(Instant::now(), v)
    }

    fn vals(batch: &Batch<J>) -> Vec<u32> {
        batch.items.iter().map(|x| x.1).collect()
    }

    #[test]
    fn tiered_lanes_never_mix_and_requeue_flushes_first() {
        let (ftx, frx) = bounded(16);
        let (rtx, rrx) = bounded(16);
        let pending = Arc::new(AtomicU64::new(0));
        let mut b = TieredBatcher::new(frx, rrx, BatchPolicy {
            max_batch: 2, max_wait: Duration::from_secs(5),
        }, |x: &J| x.0, pending);
        for i in 0..2 {
            ftx.send(j(i)).unwrap();
        }
        for i in 10..12 {
            rtx.send(j(i)).unwrap();
        }
        // both lanes are full: the re-queue lane wins the tie, and
        // neither batch carries the other lane's items
        let (lane, batch) = b.next_batch().unwrap();
        assert_eq!(lane, LANE_REQUEUE);
        assert_eq!(vals(&batch), vec![10, 11]);
        assert!(batch.full);
        let (lane, batch) = b.next_batch().unwrap();
        assert_eq!(lane, LANE_FRESH);
        assert_eq!(vals(&batch), vec![0, 1]);
        assert!(batch.full);
    }

    #[test]
    fn tiered_deadline_fires_per_lane() {
        let (ftx, frx) = bounded(16);
        let (rtx, rrx) = bounded(16);
        let pending = Arc::new(AtomicU64::new(0));
        let mut b = TieredBatcher::new(frx, rrx, BatchPolicy {
            max_batch: 100, max_wait: Duration::from_millis(10),
        }, |x: &J| x.0, pending);
        ftx.send(j(1)).unwrap();
        let (lane, batch) = b.next_batch().unwrap();
        assert_eq!(lane, LANE_FRESH);
        assert_eq!(vals(&batch), vec![1]);
        assert!(batch.is_tail(), "deadline launch is a tail");
        assert!(batch.oldest_wait >= Duration::from_millis(9));
        rtx.send(j(2)).unwrap();
        let (lane, batch) = b.next_batch().unwrap();
        assert_eq!(lane, LANE_REQUEUE);
        assert_eq!(vals(&batch), vec![2]);
        assert!(batch.is_tail());
    }

    #[test]
    fn tiered_stream_ends_only_when_no_escalation_can_arrive() {
        let (ftx, frx) = bounded::<J>(16);
        let (rtx, rrx) = bounded(16);
        let pending = Arc::new(AtomicU64::new(0));
        let mut b = TieredBatcher::new(frx, rrx, BatchPolicy {
            max_batch: 4, max_wait: Duration::from_millis(5),
        }, |x: &J| x.0, pending.clone());
        // one fast window dispatched, fresh intake closes, and the
        // escalation lands AFTER the close — the decode protocol:
        // send the re-queue, then release the pending count
        pending.store(1, Ordering::Release);
        drop(ftx);
        rtx.send(j(42)).unwrap();
        pending.store(0, Ordering::Release);
        let (lane, batch) = b.next_batch().unwrap();
        assert_eq!(lane, LANE_REQUEUE);
        assert_eq!(vals(&batch), vec![42]);
        // nothing pending: the stream ends even though the re-queue
        // sender is still alive (decode workers keep theirs open)
        assert!(b.next_batch().is_none());
        drop(rtx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn tiered_batcher_waits_out_inflight_escalations() {
        let (ftx, frx) = bounded::<J>(16);
        let (rtx, rrx) = bounded(16);
        let pending = Arc::new(AtomicU64::new(1));
        let mut b = TieredBatcher::new(frx, rrx, BatchPolicy {
            max_batch: 4, max_wait: Duration::from_millis(2),
        }, |x: &J| x.0, pending.clone());
        drop(ftx);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            rtx.send(j(7)).unwrap();
            pending.store(0, Ordering::Release);
        });
        // must block across the undecided window instead of ending
        let (lane, batch) = b.next_batch().unwrap();
        assert_eq!(lane, LANE_REQUEUE);
        assert_eq!(vals(&batch), vec![7]);
        t.join().unwrap();
        assert!(b.next_batch().is_none());
    }
}
