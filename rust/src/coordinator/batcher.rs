//! Dynamic batching: size-or-deadline policy over a bounded queue.

use std::time::{Duration, Instant};

use crate::util::bounded::{Receiver, RecvTimeoutError};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// launch as soon as this many items are queued.
    pub max_batch: usize,
    /// ... or when the oldest item has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(20) }
    }
}

/// A collected batch plus queueing telemetry.
#[derive(Debug)]
pub struct Batch<T> {
    /// the collected jobs, in arrival order.
    pub items: Vec<T>,
    /// how long the oldest item waited before launch.
    pub oldest_wait: Duration,
    /// whether the size (true) or the deadline (false) triggered launch.
    pub full: bool,
}

impl<T> Batch<T> {
    /// A *tail* batch: launched by the deadline before filling up.
    /// The coordinator routes these differently from full batches —
    /// full batches go to the least-loaded shard (spread the heavy
    /// work), tail batches go to the *busiest* live shard, so a
    /// trickle of small deadline-triggered launches rides along on the
    /// replica that is already hot instead of fragmenting the pool and
    /// keeping idle shards from being retired (or, under the
    /// autoscaler, from staying retired).
    pub fn is_tail(&self) -> bool {
        !self.full
    }
}

/// Pulls batches off a bounded channel according to the policy. Returns
/// None when the channel is closed and drained. Because the feeding
/// channel is bounded, a batcher that falls behind backpressures
/// `Coordinator::submit()` instead of letting the queue grow without
/// limit.
pub struct Batcher<T> {
    rx: Receiver<T>,
    policy: BatchPolicy,
    closed: bool,
}

impl<T> Batcher<T> {
    /// Wrap the stage's input channel with a batching policy.
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy, closed: false }
    }

    /// Block for the next batch (size or deadline triggered); `None`
    /// once the channel is closed and drained.
    pub fn next_batch(&mut self) -> Option<Batch<T>> {
        if self.closed {
            return None;
        }
        // block for the first item
        let first = match self.rx.recv() {
            Ok(x) => x,
            Err(_) => {
                self.closed = true;
                return None;
            }
        };
        let start = Instant::now();
        let mut items = vec![first];
        let mut full = false;
        while items.len() < self.policy.max_batch {
            let remaining = self.policy.max_wait
                .checked_sub(start.elapsed())
                .unwrap_or(Duration::ZERO);
            match self.rx.recv_timeout(remaining) {
                Ok(x) => items.push(x),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
        }
        if items.len() >= self.policy.max_batch {
            full = true;
        }
        Some(Batch { items, oldest_wait: start.elapsed(), full })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bounded::bounded;

    #[test]
    fn size_trigger() {
        let (tx, rx) = bounded(16);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut b = Batcher::new(rx, BatchPolicy {
            max_batch: 4, max_wait: Duration::from_secs(5),
        });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![0, 1, 2, 3]);
        assert!(batch.full);
        assert!(!batch.is_tail());
        assert_eq!(b.next_batch().unwrap().items, vec![4, 5, 6, 7]);
    }

    #[test]
    fn deadline_trigger() {
        let (tx, rx) = bounded(16);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let mut b = Batcher::new(rx, BatchPolicy {
            max_batch: 100, max_wait: Duration::from_millis(10),
        });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![1, 2]);
        assert!(!batch.full);
        assert!(batch.is_tail(), "deadline-triggered launch is a tail");
        assert!(batch.oldest_wait >= Duration::from_millis(9));
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = bounded(16);
        tx.send(7).unwrap();
        drop(tx);
        let mut b = Batcher::new(rx, BatchPolicy::default());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![7]);
        assert!(b.next_batch().is_none());
        assert!(b.next_batch().is_none());
    }
}
