//! Coordinator telemetry: lock-free counters, derived rates, and a
//! fixed-bucket latency histogram for per-read end-to-end latency
//! (submit -> CalledRead emitted by the collector).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Buckets in the latency histogram: bucket `i` covers `[2^i, 2^(i+1))`
/// µs, so 40 buckets span sub-µs to ~12 days.
const NUM_BUCKETS: usize = 40;

/// Power-of-two-bucketed histogram of microsecond latencies: bucket `i`
/// counts samples in `[2^i, 2^(i+1))` µs (bucket 0 also holds 0–1 µs).
/// Lock-free, fixed memory, no external crates; quantiles are accurate to
/// within one octave, which is plenty for a p50/p99 trend line.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
    max_micros: AtomicU64,
}

impl LatencyHistogram {
    fn bucket_of(us: u64) -> usize {
        // floor(log2(us)) for us >= 1; 0 µs lands in bucket 0
        (63 - (us | 1).leading_zeros() as usize).min(NUM_BUCKETS - 1)
    }

    /// Record one latency sample, in microseconds.
    pub fn record(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(us, Ordering::Relaxed);
        self.max_micros.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in µs over every sample (0.0 when empty).
    pub fn mean_micros(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_micros.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Largest latency sample seen so far, in µs.
    pub fn max_micros(&self) -> u64 {
        self.max_micros.load(Ordering::Relaxed)
    }

    /// Approximate quantile in µs: the upper edge of the bucket where the
    /// cumulative count crosses `q`, clamped to the observed max. The
    /// last bucket is open-ended (it absorbs everything past `2^39` µs),
    /// so a quantile landing there reports the observed max instead of a
    /// fabricated bucket edge.
    pub fn quantile_micros(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                if i + 1 >= NUM_BUCKETS {
                    return self.max_micros(); // saturated top bucket
                }
                let upper = 1u64 << (i as u64 + 1);
                return upper.min(self.max_micros());
            }
        }
        self.max_micros()
    }

    /// Point-in-time copy of the bucket counts, for *interval*
    /// quantiles: two snapshots bracket a window of samples, and
    /// [`LatencySnapshot::quantile_since`] reads the quantile of only
    /// the samples recorded between them. This is what lets the
    /// autoscale controller act on the p99 of the last tick instead of
    /// the run-cumulative p99 (which an early burst would pin forever).
    /// The copy is not atomic across buckets — a sample recorded
    /// mid-snapshot may or may not be included — which costs at most
    /// one sample of accuracy per interval, fine for a control signal.
    pub fn snapshot(&self) -> LatencySnapshot {
        // read `count` BEFORE the buckets: record() bumps the bucket
        // first and the count second, so this order can only
        // UNDER-count a racing sample (it shows up next interval). The
        // opposite order could capture the new count with the old
        // bucket — an interval whose quantile walk finds fewer bucketed
        // samples than `count_since` claims, falls off the end, and
        // reports the run-wide max as the interval p99 (a spurious SLO
        // breach).
        let count = self.count();
        LatencySnapshot {
            count,
            buckets: std::array::from_fn(
                |i| self.buckets[i].load(Ordering::Relaxed)),
            max_micros: self.max_micros(),
        }
    }
}

/// Frozen copy of a [`LatencyHistogram`]'s counts (see
/// [`LatencyHistogram::snapshot`]). Delta arithmetic between two
/// snapshots of the SAME histogram yields interval statistics.
#[derive(Clone, Debug)]
pub struct LatencySnapshot {
    buckets: [u64; NUM_BUCKETS],
    count: u64,
    max_micros: u64,
}

impl LatencySnapshot {
    /// Samples recorded up to this snapshot.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Samples recorded between `prev` (the older snapshot) and this
    /// one.
    pub fn count_since(&self, prev: &LatencySnapshot) -> u64 {
        self.count.saturating_sub(prev.count)
    }

    /// Approximate quantile in µs over only the samples recorded
    /// between `prev` and this snapshot: bucket-delta counts, upper
    /// bucket edge, clamped to the histogram's observed max (the
    /// global max, not the interval's — an octave-grade approximation,
    /// like `quantile_micros`). Returns 0 when the interval holds no
    /// samples, which callers must treat as *no signal*, not as "p99
    /// is zero" (a stalled pipeline completes nothing and therefore
    /// reports nothing here).
    pub fn quantile_since(&self, prev: &LatencySnapshot, q: f64) -> u64 {
        let n = self.count_since(prev);
        if n == 0 {
            return 0;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, (cur, old)) in
            self.buckets.iter().zip(prev.buckets.iter()).enumerate()
        {
            cum += cur.saturating_sub(*old);
            if cum >= target {
                if i + 1 >= NUM_BUCKETS {
                    return self.max_micros; // saturated top bucket
                }
                let upper = 1u64 << (i as u64 + 1);
                return upper.min(self.max_micros);
            }
        }
        self.max_micros
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
            max_micros: AtomicU64::new(0),
        }
    }
}

/// Counters for one DNN executor shard (one backend replica). The
/// numeric counters are written by exactly one shard thread and read by
/// `report()` / the benches, so `Relaxed` ordering is sufficient. With
/// the autoscaler enabled a slot can outlive its first shard: the
/// lifecycle flags record whether the slot was ever spawned and whether
/// it is currently retired, and the counters stay cumulative across a
/// retire/respawn of the same slot (`spawns` counts the generations).
#[derive(Debug, Default)]
pub struct ShardStats {
    /// batches this shard executed.
    pub batches: AtomicU64,
    /// windows (batch rows, padding excluded) this shard executed.
    pub windows: AtomicU64,
    /// wall-micros this shard spent inside the backend forward pass.
    pub busy_micros: AtomicU64,
    /// a shard thread was launched into this slot at least once.
    pub spawned: AtomicBool,
    /// the slot is currently retired (scaled down or spawn failed).
    pub retired: AtomicBool,
    /// shard generations launched into this slot (1 for a fixed pool).
    pub spawns: AtomicU64,
    /// epoch-micros when the current generation spawned (meaningful
    /// while live).
    live_since_micros: AtomicU64,
    /// accumulated live wall-micros of completed (retired) generations.
    live_micros_acc: AtomicU64,
}

impl ShardStats {
    /// Record a shard (re)launch into this slot, `at_micros` past the
    /// metrics epoch (`Metrics::epoch_micros`). The timestamp starts
    /// the slot's live window, which is the denominator
    /// `Metrics::shard_utilization` divides busy time by — a slot
    /// spawned mid-run is measured over the wall time it actually
    /// existed, not over the whole run.
    pub fn mark_spawned(&self, at_micros: u64) {
        self.live_since_micros.store(at_micros, Ordering::Relaxed);
        self.spawned.store(true, Ordering::Relaxed);
        self.retired.store(false, Ordering::Relaxed);
        self.spawns.fetch_add(1, Ordering::Relaxed);
    }

    /// Record this slot's shard retiring (scale-down or spawn failure)
    /// `at_micros` past the metrics epoch: the live window closes, so
    /// a retired slot's utilization freezes instead of decaying toward
    /// zero for the rest of the run.
    pub fn mark_retired(&self, at_micros: u64) {
        if !self.retired.swap(true, Ordering::Relaxed) {
            let since = self.live_since_micros.load(Ordering::Relaxed);
            self.live_micros_acc.fetch_add(
                at_micros.saturating_sub(since), Ordering::Relaxed);
        }
    }

    /// Spawned and not retired.
    pub fn is_live(&self) -> bool {
        self.spawned.load(Ordering::Relaxed)
            && !self.retired.load(Ordering::Relaxed)
    }

    /// Wall-micros this slot has been live up to `now_micros` (epoch
    /// time), summed across generations. A slot never marked spawned
    /// reports the full wall time — `Metrics` built outside a
    /// coordinator (no lifecycle marks) keep the original
    /// busy-over-total-wall utilization semantics.
    pub fn live_micros(&self, now_micros: u64) -> u64 {
        if !self.spawned.load(Ordering::Relaxed) {
            return now_micros;
        }
        let acc = self.live_micros_acc.load(Ordering::Relaxed);
        if self.retired.load(Ordering::Relaxed) {
            acc
        } else {
            let since = self.live_since_micros.load(Ordering::Relaxed);
            acc + now_micros.saturating_sub(since)
        }
    }
}

/// Which pipeline stage a pool, scale event, or stats row belongs to.
/// The DNN executor pool was the only resizable stage through PR 4;
/// the decode and vote pools now sit behind the same stage-pool
/// mechanics, so events and telemetry carry the stage explicitly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageId {
    /// the DNN executor shard pool (the fast tier when the pipeline
    /// runs tiered serving, the only tier otherwise).
    Dnn,
    /// the full-precision hq DNN shard pool a tiered pipeline escalates
    /// low-confidence windows to (absent in a single-tier run).
    DnnHq,
    /// the CTC decode worker pool.
    Decode,
    /// the vote/splice worker pool.
    Vote,
    /// the streaming genomics analysis pool (overlap → assembly →
    /// polish), fed by the vote stage (absent unless the pipeline runs
    /// with `analysis_threads > 0`).
    Analysis,
}

impl StageId {
    /// Stable lowercase name for logs and the bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            StageId::Dnn => "dnn",
            StageId::DnnHq => "dnn-hq",
            StageId::Decode => "decode",
            StageId::Vote => "vote",
            StageId::Analysis => "analysis",
        }
    }
}

/// Counters for one worker slot of a resizable *cheap-worker* stage
/// pool (CTC decode, vote/splice): the `ShardStats` lifecycle story —
/// per-slot work counters, spawn/retire flags, and a live-wall-time
/// window for honest utilization — minus the DNN-specific batch
/// accounting. Written by exactly one worker thread, read by
/// `report()` and the autoscale controller, so `Relaxed` suffices.
#[derive(Debug, Default)]
pub struct StageStats {
    /// jobs (windows decoded / reads voted) this worker processed.
    pub jobs: AtomicU64,
    /// wall-micros this worker spent inside its kernel.
    pub busy_micros: AtomicU64,
    /// a worker thread was launched into this slot at least once.
    pub spawned: AtomicBool,
    /// the slot is currently retired.
    pub retired: AtomicBool,
    /// worker generations launched into this slot.
    pub spawns: AtomicU64,
    live_since_micros: AtomicU64,
    live_micros_acc: AtomicU64,
}

impl StageStats {
    /// Record a worker (re)launch into this slot at epoch `at_micros`
    /// (see `ShardStats::mark_spawned`).
    pub fn mark_spawned(&self, at_micros: u64) {
        self.live_since_micros.store(at_micros, Ordering::Relaxed);
        self.spawned.store(true, Ordering::Relaxed);
        self.retired.store(false, Ordering::Relaxed);
        self.spawns.fetch_add(1, Ordering::Relaxed);
    }

    /// Record this slot's worker retiring at epoch `at_micros` (see
    /// `ShardStats::mark_retired`).
    pub fn mark_retired(&self, at_micros: u64) {
        if !self.retired.swap(true, Ordering::Relaxed) {
            let since = self.live_since_micros.load(Ordering::Relaxed);
            self.live_micros_acc.fetch_add(
                at_micros.saturating_sub(since), Ordering::Relaxed);
        }
    }

    /// Spawned and not retired.
    pub fn is_live(&self) -> bool {
        self.spawned.load(Ordering::Relaxed)
            && !self.retired.load(Ordering::Relaxed)
    }

    /// Wall-micros this slot has been live up to `now_micros` (see
    /// `ShardStats::live_micros`).
    pub fn live_micros(&self, now_micros: u64) -> u64 {
        if !self.spawned.load(Ordering::Relaxed) {
            return now_micros;
        }
        let acc = self.live_micros_acc.load(Ordering::Relaxed);
        if self.retired.load(Ordering::Relaxed) {
            acc
        } else {
            let since = self.live_since_micros.load(Ordering::Relaxed);
            acc + now_micros.saturating_sub(since)
        }
    }
}

/// Per-slot lifecycle surface shared by [`ShardStats`] and
/// [`StageStats`], so `report()` renders every utilization split —
/// shard, hq, decode, vote — through one formatter with one percent
/// format and one unspawned-slot rule.
trait SlotUtil {
    /// busy wall-micros accumulated by the slot.
    fn slot_busy(&self) -> u64;
    /// live wall-micros up to `now_micros`.
    fn slot_live(&self, now_micros: u64) -> u64;
    /// a worker was ever launched into the slot.
    fn slot_spawned(&self) -> bool;
    /// the slot is currently retired.
    fn slot_retired(&self) -> bool;
}

impl SlotUtil for ShardStats {
    fn slot_busy(&self) -> u64 {
        self.busy_micros.load(Ordering::Relaxed)
    }
    fn slot_live(&self, now_micros: u64) -> u64 {
        self.live_micros(now_micros)
    }
    fn slot_spawned(&self) -> bool {
        self.spawned.load(Ordering::Relaxed)
    }
    fn slot_retired(&self) -> bool {
        self.retired.load(Ordering::Relaxed)
    }
}

impl SlotUtil for StageStats {
    fn slot_busy(&self) -> u64 {
        self.busy_micros.load(Ordering::Relaxed)
    }
    fn slot_live(&self, now_micros: u64) -> u64 {
        self.live_micros(now_micros)
    }
    fn slot_spawned(&self) -> bool {
        self.spawned.load(Ordering::Relaxed)
    }
    fn slot_retired(&self) -> bool {
        self.retired.load(Ordering::Relaxed)
    }
}

/// The one utilization-row formatter every split in `report()` goes
/// through: one `i:pct.p%` / `i:pct.p%(retired)` row per slot, busy
/// time over the slot's live wall window (capped at 100%), and — once
/// any slot in the table was ever spawned — unspawned slots are
/// skipped, in every section alike. (A standalone `Metrics` with no
/// lifecycle marks still prints every row, the pre-lifecycle
/// behavior.) Retired slots keep their row, explicitly tagged, instead
/// of silently vanishing from the split.
fn util_rows<S: SlotUtil>(slots: &[S], now_micros: u64) -> Vec<String> {
    let any_spawned = slots.iter().any(|s| s.slot_spawned());
    slots.iter().enumerate()
        .filter(|(_, s)| !any_spawned || s.slot_spawned())
        .map(|(i, s)| {
            let live = s.slot_live(now_micros).max(1) as f64;
            let pct = (s.slot_busy() as f64 / live).min(1.0) * 100.0;
            if s.slot_retired() {
                format!("{i}:{pct:.1}%(retired)")
            } else {
                format!("{i}:{pct:.1}%")
            }
        })
        .collect()
}

/// What an autoscale event did to the shard pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    /// a new shard was spawned into the slot.
    Up,
    /// the slot's shard was retired (queue closed, drained gracefully).
    Down,
    /// a scale-up was attempted but the replica failed to open/warm;
    /// the slot was retired again without ever serving a batch.
    SpawnFailed,
}

impl ScaleAction {
    /// Stable lowercase name for logs and the bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            ScaleAction::Up => "up",
            ScaleAction::Down => "down",
            ScaleAction::SpawnFailed => "spawn-failed",
        }
    }
}

/// One entry in the autoscaler's scale-event log.
#[derive(Clone, Copy, Debug)]
pub struct ScaleEvent {
    /// µs since the pipeline's metrics epoch (`Metrics` construction).
    pub at_micros: u64,
    /// which stage pool was resized.
    pub stage: StageId,
    /// what happened.
    pub action: ScaleAction,
    /// the slot acted on.
    pub slot: usize,
    /// live worker count of that stage after the event was applied.
    pub live_after: usize,
}

/// Per-tenant serving counters for the TCP front-end
/// (`coordinator::net`): one row per connection, keyed by its tenant
/// id, so a noisy neighbour is visible as *that tenant's* shed count
/// instead of a blur in the global totals. Tenant 0 (the in-process
/// library path) is never tabulated here.
#[derive(Debug, Default)]
pub struct TenantStats {
    /// reads this tenant submitted that entered the pipeline.
    pub reads_in: AtomicU64,
    /// `CalledRead`s routed back to this tenant.
    pub reads_out: AtomicU64,
    /// windows this tenant's reads were chopped into.
    pub windows: AtomicU64,
    /// reads refused with an explicit `BUSY` (quota or SLO shed).
    pub shed: AtomicU64,
    /// completed reads dropped because the tenant disconnected first.
    pub dropped: AtomicU64,
    /// per-read end-to-end latency of this tenant's emitted reads.
    pub latency: LatencyHistogram,
}

/// Aggregate pipeline telemetry shared by every stage thread.
#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    /// reads accepted by `submit()`.
    pub reads_in: AtomicU64,
    /// `CalledRead`s emitted by the vote pool.
    pub reads_out: AtomicU64,
    /// windows produced by the windower.
    pub windows: AtomicU64,
    /// DNN batches launched (all shards).
    pub batches: AtomicU64,
    /// windows carried by those batches (all shards).
    pub batch_items: AtomicU64,
    /// batches launched by the size trigger rather than the deadline.
    pub full_batches: AtomicU64,
    /// total bases across emitted consensus sequences.
    pub bases_called: AtomicU64,
    /// wall-micros spent in the DNN forward pass, summed over shards.
    pub dnn_micros: AtomicU64,
    /// wall-micros spent in CTC beam search, summed over workers.
    pub decode_micros: AtomicU64,
    /// wall-micros spent in vote + splice, summed over workers.
    pub vote_micros: AtomicU64,
    /// per-read end-to-end latency, submit() -> CalledRead emitted.
    pub read_latency: LatencyHistogram,
    /// per-shard DNN counters, one per shard *slot*: the pipeline's
    /// `dnn_shards` for a fixed pool, `max_shards` under the
    /// autoscaler (slots the autoscaler never filled stay all-zero and
    /// unspawned). When the pipeline runs tiered serving this is the
    /// **fast** tier's pool; the hq pool lives in `hq_shards`.
    pub shards: Vec<ShardStats>,
    /// per-shard counters of the full-precision hq escalation pool —
    /// empty unless the pipeline runs tiered serving.
    pub hq_shards: Vec<ShardStats>,
    /// fast-tier windows whose decode confidence was measured (each is
    /// then either collected or escalated). Zero in a single-tier run.
    pub fast_decided: AtomicU64,
    /// fast-tier windows re-queued to the hq tier because their CTC
    /// top-beam margin fell below the escalation threshold.
    pub escalations: AtomicU64,
    /// escalation round-trip latency: hq re-queue -> hq decode
    /// complete, per escalated window.
    pub escalation_latency: LatencyHistogram,
    /// per-worker CTC decode counters, one per decode pool slot (empty
    /// for `Metrics` built outside a coordinator, e.g. `default()`).
    pub decode_workers: Vec<StageStats>,
    /// per-worker vote/splice counters, one per vote pool slot (empty
    /// for `Metrics` built outside a coordinator).
    pub vote_workers: Vec<StageStats>,
    /// per-worker streaming-analysis counters (overlap → assembly →
    /// polish), one per analysis pool slot (empty when the analysis
    /// stage is off).
    pub analysis_workers: Vec<StageStats>,
    /// wall-micros spent in incremental overlap discovery + consensus,
    /// summed over analysis workers.
    pub analysis_micros: AtomicU64,
    /// reads short-circuited by the early-rejection gate: their
    /// remaining windows skip decode and the read skips vote and
    /// analysis entirely. Zero when rejection is off.
    pub rejected_reads: AtomicU64,
    /// windows that skipped the CTC decode kernel because their read
    /// was already rejected (the rejecting window itself is decoded —
    /// that decode produced the margin).
    pub rejected_windows: AtomicU64,
    /// reads refused with an explicit `BUSY` response by the TCP
    /// front-end's admission gate (quota breach or SLO shed). Zero for
    /// in-process pipelines.
    pub shed_reads: AtomicU64,
    /// completed reads dropped at the collector because their owning
    /// connection disconnected mid-flight. Zero for in-process
    /// pipelines.
    pub dropped_reads: AtomicU64,
    /// per-tenant serving stats, created lazily on first touch (see
    /// [`Metrics::tenant`]). Empty for in-process pipelines.
    tenants: Mutex<HashMap<u64, Arc<TenantStats>>>,
    /// autoscaler scale-event log (empty for a fixed shard pool).
    scale_events: Mutex<Vec<ScaleEvent>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::with_shards(1)
    }
}

impl Metrics {
    /// Metrics for a pipeline running `n` DNN executor shards (min 1),
    /// with no per-worker decode/vote slots (stage pools record only
    /// their aggregate counters against such a `Metrics`).
    pub fn with_shards(n: usize) -> Metrics {
        Metrics::for_pipeline(n, 0, 0)
    }

    /// Metrics sized for a full single-tier pipeline: `n` DNN shard
    /// slots (min 1) plus `n_decode` decode-worker and `n_vote`
    /// vote-worker slots.
    pub fn for_pipeline(n: usize, n_decode: usize, n_vote: usize)
                        -> Metrics {
        Metrics::for_tiered_pipeline(n, 0, n_decode, n_vote)
    }

    /// Metrics sized for a tiered pipeline: `n` fast-tier DNN shard
    /// slots (min 1), `n_hq` hq-tier shard slots (0 = single tier),
    /// plus the decode and vote worker slots (no analysis slots).
    pub fn for_tiered_pipeline(n: usize, n_hq: usize, n_decode: usize,
                               n_vote: usize) -> Metrics {
        Metrics::for_full_pipeline(n, n_hq, n_decode, n_vote, 0)
    }

    /// Metrics sized for the full pipeline including the streaming
    /// analysis stage: `n_analysis` overlap/assembly/polish worker
    /// slots on top of the tiered layout (0 = analysis stage off).
    pub fn for_full_pipeline(n: usize, n_hq: usize, n_decode: usize,
                             n_vote: usize, n_analysis: usize)
                             -> Metrics {
        Metrics {
            start: Instant::now(),
            reads_in: AtomicU64::new(0),
            reads_out: AtomicU64::new(0),
            windows: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            full_batches: AtomicU64::new(0),
            bases_called: AtomicU64::new(0),
            dnn_micros: AtomicU64::new(0),
            decode_micros: AtomicU64::new(0),
            vote_micros: AtomicU64::new(0),
            read_latency: LatencyHistogram::default(),
            shards: (0..n.max(1)).map(|_| ShardStats::default()).collect(),
            hq_shards: (0..n_hq).map(|_| ShardStats::default()).collect(),
            fast_decided: AtomicU64::new(0),
            escalations: AtomicU64::new(0),
            escalation_latency: LatencyHistogram::default(),
            decode_workers: (0..n_decode)
                .map(|_| StageStats::default()).collect(),
            vote_workers: (0..n_vote)
                .map(|_| StageStats::default()).collect(),
            analysis_workers: (0..n_analysis)
                .map(|_| StageStats::default()).collect(),
            analysis_micros: AtomicU64::new(0),
            rejected_reads: AtomicU64::new(0),
            rejected_windows: AtomicU64::new(0),
            shed_reads: AtomicU64::new(0),
            dropped_reads: AtomicU64::new(0),
            tenants: Mutex::new(HashMap::new()),
            scale_events: Mutex::new(Vec::new()),
        }
    }

    /// This tenant's stats row, created on first touch. The row is an
    /// `Arc` so callers on hot paths can hold it across the lock.
    pub fn tenant(&self, id: u64) -> Arc<TenantStats> {
        self.tenants.lock().unwrap()
            .entry(id)
            .or_default()
            .clone()
    }

    /// Every tenant id with a stats row, ascending.
    pub fn tenant_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> =
            self.tenants.lock().unwrap().keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// The shard-stats table backing a DNN stage: `hq_shards` for the
    /// escalation pool, `shards` for everything else. This is how the
    /// shard hosts and the dispatch thread index per-slot counters
    /// without caring which tier they serve.
    pub fn stage_shards(&self, stage: StageId) -> &[ShardStats] {
        match stage {
            StageId::DnnHq => &self.hq_shards,
            _ => &self.shards,
        }
    }

    /// Fraction of confidence-measured fast-tier windows that were
    /// escalated to the hq tier (0.0 when none were measured).
    pub fn escalation_rate(&self) -> f64 {
        let decided = self.fast_decided.load(Ordering::Relaxed);
        if decided == 0 {
            return 0.0;
        }
        self.escalations.load(Ordering::Relaxed) as f64 / decided as f64
    }

    /// µs elapsed since this `Metrics` was constructed — the epoch all
    /// lifecycle timestamps (`mark_spawned`/`mark_retired`) and scale
    /// events are stamped against.
    pub fn epoch_micros(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// Append a scale event for `stage`, stamped with µs since the
    /// metrics epoch.
    pub fn record_scale(&self, stage: StageId, action: ScaleAction,
                        slot: usize, live_after: usize) {
        let at_micros = self.epoch_micros();
        self.scale_events.lock().unwrap().push(ScaleEvent {
            at_micros,
            stage,
            action,
            slot,
            live_after,
        });
    }

    /// Snapshot of the autoscaler's scale-event log, in order.
    pub fn scale_events(&self) -> Vec<ScaleEvent> {
        self.scale_events.lock().unwrap().clone()
    }

    /// Slots currently live (spawned and not retired). For a fixed
    /// pool this is simply the shard count.
    pub fn live_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.is_live()).count()
    }

    /// Bump a counter (any of the public `AtomicU64` fields, including
    /// the per-shard ones).
    pub fn add(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Per-shard busy fraction (0.0–1.0 each) of each slot's **live**
    /// wall time — the time a shard actually occupied the slot, not
    /// the time since `Metrics` construction. A shard the autoscaler
    /// spawns mid-run is no longer diluted by wall time it did not
    /// exist for, and a retired slot's fraction freezes at retirement
    /// instead of decaying for the rest of the run. (Slots never
    /// marked spawned — `Metrics` built outside a coordinator — fall
    /// back to total wall time, the pre-lifecycle behavior.)
    pub fn shard_utilization(&self) -> Vec<f64> {
        self.shard_utilization_at(self.epoch_micros())
    }

    /// `shard_utilization` evaluated at an explicit epoch timestamp
    /// (µs since construction); `report()` and tests use this to pin
    /// the live-window arithmetic without racing the wall clock.
    pub fn shard_utilization_at(&self, now_micros: u64) -> Vec<f64> {
        self.shards.iter()
            .map(|s| {
                let live = s.live_micros(now_micros).max(1) as f64;
                (s.busy_micros.load(Ordering::Relaxed) as f64 / live)
                    .min(1.0)
            })
            .collect()
    }

    /// DNN-stage throughput: windows executed per second of the busiest
    /// shard's forward-pass time. With one shard this is plain
    /// windows-per-DNN-second; with N balanced shards the busiest shard
    /// holds ~1/N of the work, so the stage's capacity scales — this is
    /// the scaling number `ci.sh bench` records.
    pub fn dnn_stage_windows_per_s(&self) -> f64 {
        let max_busy = self.shards.iter()
            .map(|s| s.busy_micros.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0);
        if max_busy == 0 {
            return 0.0;
        }
        self.batch_items.load(Ordering::Relaxed) as f64
            / (max_busy as f64 / 1e6)
    }

    /// Mean batch occupancy relative to `max_batch` (1.0 = every batch
    /// launched full).
    pub fn mean_batch_fill(&self, max_batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_items.load(Ordering::Relaxed) as f64
            / (b as f64 * max_batch as f64)
    }

    /// Base-calling throughput so far (bases/s).
    pub fn throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64().max(1e-9);
        self.bases_called.load(Ordering::Relaxed) as f64 / secs
    }

    /// One-line human-readable summary of every counter, including the
    /// per-shard DNN utilization split when more than one shard ran.
    pub fn report(&self, max_batch: usize) -> String {
        let mut s = format!(
            "reads {}->{}  windows {}  batches {} (fill {:.2})  bases {}  \
             t_dnn {:.1}ms t_decode {:.1}ms t_vote {:.1}ms  {:.0} bp/s",
            self.reads_in.load(Ordering::Relaxed),
            self.reads_out.load(Ordering::Relaxed),
            self.windows.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_fill(max_batch),
            self.bases_called.load(Ordering::Relaxed),
            self.dnn_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.decode_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.vote_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.throughput(),
        );
        if self.read_latency.count() > 0 {
            s.push_str(&format!(
                "  lat p50 {:.1}ms p99 {:.1}ms",
                self.read_latency.quantile_micros(0.50) as f64 / 1e3,
                self.read_latency.quantile_micros(0.99) as f64 / 1e3,
            ));
        }
        if self.batch_items.load(Ordering::Relaxed) > 0 {
            s.push_str(&format!("  dnn-stage {:.0} win/s",
                                self.dnn_stage_windows_per_s()));
        }
        // every per-slot split — shard, hq, decode, vote — renders
        // through util_rows, so retired- and live-slot utilization use
        // one percent format and one unspawned-slot rule throughout
        let now = self.epoch_micros();
        if self.shards.len() > 1 {
            s.push_str(&format!("  shard-util [{}]",
                                util_rows(&self.shards, now).join(" ")));
        }
        if self.hq_shards.len() > 1 {
            s.push_str(&format!("  hq-util [{}]",
                                util_rows(&self.hq_shards, now).join(" ")));
        }
        for (label, workers) in [("decode-util", &self.decode_workers),
                                 ("vote-util", &self.vote_workers),
                                 ("analysis-util",
                                  &self.analysis_workers)] {
            if workers.len() <= 1 {
                continue;
            }
            s.push_str(&format!("  {label} [{}]",
                                util_rows(workers, now).join(" ")));
        }
        // tiered-serving section: per-tier window counts, escalation
        // rate, and the escalation round-trip latency
        let decided = self.fast_decided.load(Ordering::Relaxed);
        if !self.hq_shards.is_empty() || decided > 0 {
            let fast_w: u64 = self.shards.iter()
                .map(|st| st.windows.load(Ordering::Relaxed)).sum();
            let hq_w: u64 = self.hq_shards.iter()
                .map(|st| st.windows.load(Ordering::Relaxed)).sum();
            s.push_str(&format!(
                "  tier fast {fast_w}w hq {hq_w}w  esc {}/{decided} \
                 ({:.1}%)",
                self.escalations.load(Ordering::Relaxed),
                self.escalation_rate() * 100.0,
            ));
            if self.escalation_latency.count() > 0 {
                s.push_str(&format!(
                    "  esc-lat p50 {:.1}ms p99 {:.1}ms",
                    self.escalation_latency.quantile_micros(0.50) as f64
                        / 1e3,
                    self.escalation_latency.quantile_micros(0.99) as f64
                        / 1e3,
                ));
            }
        }
        // early-rejection + streaming-analysis section: how many reads
        // the quality gate short-circuited (and the decode work those
        // reads' remaining windows skipped), plus the analysis stage's
        // kernel time when it ran
        let rej_r = self.rejected_reads.load(Ordering::Relaxed);
        let rej_w = self.rejected_windows.load(Ordering::Relaxed);
        if rej_r > 0 || rej_w > 0 {
            s.push_str(&format!("  rejected {rej_r}r/{rej_w}w"));
        }
        let t_analysis = self.analysis_micros.load(Ordering::Relaxed);
        if t_analysis > 0 {
            s.push_str(&format!("  t_analysis {:.1}ms",
                                t_analysis as f64 / 1e3));
        }
        // serving-ingress section: global shed/drop totals plus one
        // compact row per tenant, so one line still tells the whole
        // story when the pipeline fronts concurrent TCP clients
        let shed = self.shed_reads.load(Ordering::Relaxed);
        let dropped = self.dropped_reads.load(Ordering::Relaxed);
        if shed > 0 || dropped > 0 {
            s.push_str(&format!("  shed {shed} dropped {dropped}"));
        }
        let mut rows: Vec<(u64, Arc<TenantStats>)> = self.tenants.lock()
            .unwrap()
            .iter()
            .map(|(id, t)| (*id, t.clone()))
            .collect();
        if !rows.is_empty() {
            rows.sort_unstable_by_key(|(id, _)| *id);
            let body: Vec<String> = rows.iter().map(|(id, t)| {
                let mut row = format!(
                    "t{id} {}->{} {}w",
                    t.reads_in.load(Ordering::Relaxed),
                    t.reads_out.load(Ordering::Relaxed),
                    t.windows.load(Ordering::Relaxed));
                let shed = t.shed.load(Ordering::Relaxed);
                if shed > 0 {
                    row.push_str(&format!(" shed {shed}"));
                }
                let dropped = t.dropped.load(Ordering::Relaxed);
                if dropped > 0 {
                    row.push_str(&format!(" dropped {dropped}"));
                }
                if t.latency.count() > 0 {
                    row.push_str(&format!(
                        " p99 {:.1}ms",
                        t.latency.quantile_micros(0.99) as f64 / 1e3));
                }
                row
            }).collect();
            s.push_str(&format!("  tenants [{}]", body.join(" | ")));
        }
        let events = self.scale_events.lock().unwrap();
        if !events.is_empty() {
            let ups = events.iter()
                .filter(|e| e.action == ScaleAction::Up).count();
            let downs = events.iter()
                .filter(|e| e.action == ScaleAction::Down).count();
            let fails = events.iter()
                .filter(|e| e.action == ScaleAction::SpawnFailed).count();
            s.push_str(&format!("  autoscale +{ups}/-{downs} live {}",
                                self.live_shards()));
            if fails > 0 {
                s.push_str(&format!(" ({fails} spawn-failed)"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.windows, 5);
        m.add(&m.windows, 3);
        assert_eq!(m.windows.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn batch_fill() {
        let m = Metrics::default();
        m.add(&m.batches, 2);
        m.add(&m.batch_items, 48);
        assert!((m.mean_batch_fill(32) - 0.75).abs() < 1e-12);
        assert_eq!(Metrics::default().mean_batch_fill(32), 0.0);
    }

    #[test]
    fn report_contains_counts() {
        let m = Metrics::default();
        m.add(&m.bases_called, 123);
        assert!(m.report(32).contains("bases 123"));
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_micros(0.99), 0);
        // 99 fast samples, 1 slow one
        for _ in 0..99 {
            h.record(100);
        }
        h.record(100_000);
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_micros(0.50);
        assert!((64..=128).contains(&p50), "p50 {p50}");
        let p99 = h.quantile_micros(0.99);
        assert!(p99 <= 128, "p99 {p99} should still be in the fast bucket");
        let p100 = h.quantile_micros(1.0);
        assert_eq!(p100, 100_000, "max clamps the top bucket edge");
        assert_eq!(h.max_micros(), 100_000);
        assert!((h.mean_micros() - 1099.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_bucket_edges() {
        assert_eq!(LatencyHistogram::bucket_of(0), 0);
        assert_eq!(LatencyHistogram::bucket_of(1), 0);
        assert_eq!(LatencyHistogram::bucket_of(2), 1);
        assert_eq!(LatencyHistogram::bucket_of(3), 1);
        assert_eq!(LatencyHistogram::bucket_of(4), 2);
        assert_eq!(LatencyHistogram::bucket_of(u64::MAX), 39);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_micros(), 0.0);
        assert_eq!(h.max_micros(), 0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_micros(q), 0, "q={q}");
        }
    }

    #[test]
    fn single_sample_pins_every_quantile() {
        let h = LatencyHistogram::default();
        h.record(777);
        assert_eq!(h.count(), 1);
        assert!((h.mean_micros() - 777.0).abs() < 1e-9);
        // every quantile of a one-sample histogram is that sample
        // (bucket upper edge clamped to the observed max)
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_micros(q), 777, "q={q}");
        }
    }

    #[test]
    fn saturating_sample_lands_in_top_bucket() {
        let h = LatencyHistogram::default();
        // bucket_of(u64::MAX) == 39: the top bucket absorbs overflow
        // instead of indexing out of bounds, and the quantile clamps
        // its 2^40 upper edge to the recorded max
        h.record(u64::MAX);
        assert_eq!(h.quantile_micros(0.5), u64::MAX);
        assert_eq!(h.max_micros(), u64::MAX);
        // a second ordinary sample keeps the lower quantiles sane
        h.record(10);
        assert!(h.quantile_micros(0.25) <= 16);
        assert_eq!(h.quantile_micros(1.0), u64::MAX);
    }

    #[test]
    fn zero_micros_sample_counts() {
        let h = LatencyHistogram::default();
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile_micros(0.5), 0,
                   "upper edge must clamp to the observed max of 0");
    }

    #[test]
    fn shard_counters_are_independent() {
        let m = Metrics::with_shards(4);
        assert_eq!(m.shards.len(), 4);
        m.add(&m.shards[0].batches, 2);
        m.add(&m.shards[3].windows, 64);
        m.add(&m.shards[3].busy_micros, 500);
        assert_eq!(m.shards[0].batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.shards[1].batches.load(Ordering::Relaxed), 0);
        assert_eq!(m.shards[3].windows.load(Ordering::Relaxed), 64);
        // default stays single-shard, and with_shards clamps 0 to 1
        assert_eq!(Metrics::default().shards.len(), 1);
        assert_eq!(Metrics::with_shards(0).shards.len(), 1);
    }

    #[test]
    fn dnn_stage_throughput_uses_busiest_shard() {
        let m = Metrics::with_shards(2);
        assert_eq!(m.dnn_stage_windows_per_s(), 0.0, "no work yet");
        m.add(&m.batch_items, 100);
        m.add(&m.shards[0].busy_micros, 1_000_000);
        m.add(&m.shards[1].busy_micros, 500_000);
        // 100 windows / 1.0s of busiest-shard time
        assert!((m.dnn_stage_windows_per_s() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn report_shows_shard_util_only_when_sharded() {
        let m = Metrics::with_shards(2);
        m.add(&m.batch_items, 8);
        m.add(&m.shards[0].busy_micros, 100);
        let r = m.report(32);
        assert!(r.contains("shard-util ["), "{r}");
        assert!(r.contains("dnn-stage"), "{r}");
        let single = Metrics::default();
        assert!(!single.report(32).contains("shard-util"));
    }

    #[test]
    fn shard_lifecycle_flags_track_spawn_and_retire() {
        let st = ShardStats::default();
        assert!(!st.is_live(), "unspawned slot is not live");
        st.mark_spawned(0);
        assert!(st.is_live());
        assert_eq!(st.spawns.load(Ordering::Relaxed), 1);
        st.mark_retired(10);
        assert!(!st.is_live());
        // a respawn into the recycled slot revives it (generation 2)
        st.mark_spawned(20);
        assert!(st.is_live());
        assert_eq!(st.spawns.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn live_micros_spans_generations_and_freezes_on_retire() {
        let st = ShardStats::default();
        // never spawned: full wall time (standalone-Metrics fallback)
        assert_eq!(st.live_micros(500), 500);
        st.mark_spawned(100);
        assert_eq!(st.live_micros(300), 200, "live window starts at spawn");
        st.mark_retired(400);
        assert_eq!(st.live_micros(1_000), 300, "retire freezes the window");
        // double retire must not double-count
        st.mark_retired(900);
        assert_eq!(st.live_micros(1_000), 300);
        // a second generation accumulates on top of the first
        st.mark_spawned(1_000);
        assert_eq!(st.live_micros(1_250), 550);
        st.mark_retired(1_500);
        assert_eq!(st.live_micros(9_999), 800);
    }

    #[test]
    fn late_spawned_slot_utilization_uses_live_window() {
        // regression: utilization used to divide cumulative busy-micros
        // by wall time since Metrics construction, so a slot the
        // autoscaler spawned mid-run read as diluted forever
        let m = Metrics::with_shards(2);
        m.shards[0].mark_spawned(0);
        m.shards[1].mark_spawned(800); // spawned 80% into the run
        m.add(&m.shards[0].busy_micros, 100);
        m.add(&m.shards[1].busy_micros, 100);
        let u = m.shard_utilization_at(1_000);
        assert!((u[0] - 0.1).abs() < 1e-9, "{u:?}");
        assert!((u[1] - 0.5).abs() < 1e-9,
                "late spawn must not dilute utilization: {u:?}");
        // retirement freezes the fraction instead of decaying it
        m.shards[1].mark_retired(1_000);
        let u2 = m.shard_utilization_at(100_000);
        assert!((u2[1] - 0.5).abs() < 1e-9,
                "retired slot must not decay: {u2:?}");
    }

    #[test]
    fn stage_stats_mirror_shard_lifecycle() {
        let st = StageStats::default();
        assert!(!st.is_live());
        st.mark_spawned(50);
        assert!(st.is_live());
        assert_eq!(st.spawns.load(Ordering::Relaxed), 1);
        assert_eq!(st.live_micros(150), 100);
        st.mark_retired(200);
        assert!(!st.is_live());
        assert_eq!(st.live_micros(9_000), 150);
        assert_eq!(StageId::Dnn.name(), "dnn");
        assert_eq!(StageId::DnnHq.name(), "dnn-hq");
        assert_eq!(StageId::Decode.name(), "decode");
        assert_eq!(StageId::Vote.name(), "vote");
        assert_eq!(StageId::Analysis.name(), "analysis");
    }

    #[test]
    fn full_pipeline_metrics_size_analysis_slots() {
        let m = Metrics::for_full_pipeline(1, 0, 1, 1, 3);
        assert_eq!(m.analysis_workers.len(), 3);
        // tiered/plain constructors leave the analysis stage off
        assert!(Metrics::for_tiered_pipeline(1, 1, 1, 1)
                    .analysis_workers.is_empty());
        assert!(Metrics::default().analysis_workers.is_empty());
    }

    #[test]
    fn report_shows_rejection_and_analysis_sections() {
        let m = Metrics::for_full_pipeline(1, 0, 1, 1, 2);
        let r0 = m.report(32);
        assert!(!r0.contains("rejected"), "{r0}");
        assert!(!r0.contains("t_analysis"), "{r0}");
        m.add(&m.rejected_reads, 2);
        m.add(&m.rejected_windows, 7);
        m.add(&m.analysis_micros, 5_000);
        let r = m.report(32);
        assert!(r.contains("rejected 2r/7w"), "{r}");
        assert!(r.contains("t_analysis 5.0ms"), "{r}");
        // the analysis pool renders through the same util formatter
        m.analysis_workers[0].mark_spawned(0);
        m.add(&m.analysis_workers[0].busy_micros, 50);
        assert!(m.report(32).contains("analysis-util ["),
                "{}", m.report(32));
    }

    #[test]
    fn scale_events_accumulate_in_order() {
        let m = Metrics::with_shards(4);
        assert!(m.scale_events().is_empty());
        m.record_scale(StageId::Dnn, ScaleAction::Up, 1, 2);
        m.record_scale(StageId::Decode, ScaleAction::Down, 1, 1);
        let ev = m.scale_events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].action, ScaleAction::Up);
        assert_eq!(ev[0].stage, StageId::Dnn);
        assert_eq!(ev[0].slot, 1);
        assert_eq!(ev[0].live_after, 2);
        assert_eq!(ev[1].action, ScaleAction::Down);
        assert_eq!(ev[1].stage, StageId::Decode);
        assert!(ev[0].at_micros <= ev[1].at_micros);
        assert_eq!(ScaleAction::SpawnFailed.name(), "spawn-failed");
    }

    #[test]
    fn report_lists_retired_shards_with_percent_format() {
        let m = Metrics::with_shards(3);
        m.shards[0].mark_spawned(0);
        m.shards[1].mark_spawned(0);
        m.shards[1].mark_retired(m.epoch_micros());
        m.add(&m.shards[0].busy_micros, 100);
        let r = m.report(32);
        assert!(r.contains("shard-util ["), "{r}");
        // spawned slots print a percent; the retired one stays listed
        assert!(r.contains("0:"), "{r}");
        assert!(r.contains("%(retired)"), "{r}");
        // slot 2 was never spawned: no row for it
        assert!(!r.contains("2:"), "{r}");
        assert_eq!(m.live_shards(), 1);
    }

    #[test]
    fn report_shows_stage_worker_splits_when_pooled() {
        let m = Metrics::for_pipeline(1, 2, 2);
        m.decode_workers[0].mark_spawned(0);
        m.decode_workers[1].mark_spawned(0);
        m.vote_workers[0].mark_spawned(0);
        m.vote_workers[1].mark_spawned(0);
        m.vote_workers[1].mark_retired(m.epoch_micros());
        m.add(&m.decode_workers[0].busy_micros, 50);
        let r = m.report(32);
        assert!(r.contains("decode-util ["), "{r}");
        assert!(r.contains("vote-util ["), "{r}");
        assert!(r.contains("%(retired)"), "{r}");
        // stage splits only print for actual pools (>1 slot)
        let single = Metrics::for_pipeline(1, 1, 1);
        let rs = single.report(32);
        assert!(!rs.contains("decode-util"), "{rs}");
        assert!(!rs.contains("vote-util"), "{rs}");
        // and never for standalone Metrics (no stage slots at all)
        assert!(!Metrics::default().report(32).contains("decode-util"));
    }

    #[test]
    fn report_appends_autoscale_summary_when_events_exist() {
        let m = Metrics::with_shards(2);
        assert!(!m.report(32).contains("autoscale"));
        m.shards[0].mark_spawned(0);
        m.shards[1].mark_spawned(0);
        m.record_scale(StageId::Dnn, ScaleAction::Up, 1, 2);
        let r = m.report(32);
        assert!(r.contains("autoscale +1/-0 live 2"), "{r}");
        m.record_scale(StageId::Dnn, ScaleAction::SpawnFailed, 1, 1);
        assert!(m.report(32).contains("spawn-failed"));
    }

    #[test]
    fn snapshot_deltas_expose_interval_quantiles() {
        let h = LatencyHistogram::default();
        let empty = h.snapshot();
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.quantile_since(&empty, 0.99), 0,
                   "empty interval is no-signal zero");
        // interval 1: fast samples only
        for _ in 0..50 {
            h.record(100);
        }
        let s1 = h.snapshot();
        assert_eq!(s1.count_since(&empty), 50);
        let p99_fast = s1.quantile_since(&empty, 0.99);
        assert!(p99_fast <= 128, "fast interval p99 {p99_fast}");
        // interval 2: slow samples — the CUMULATIVE p99 stays pinned
        // low by the 50 fast samples, but the interval p99 must see
        // the regression immediately
        for _ in 0..10 {
            h.record(100_000);
        }
        let s2 = h.snapshot();
        assert_eq!(s2.count_since(&s1), 10);
        let p99_slow = s2.quantile_since(&s1, 0.99);
        assert!(p99_slow >= 65_536,
                "interval p99 {p99_slow} must reflect only new samples");
        // an interval with no samples reads 0 again
        let s3 = h.snapshot();
        assert_eq!(s3.quantile_since(&s2, 0.99), 0);
        // cumulative view for contrast: p50 still in the fast bucket
        assert!(h.quantile_micros(0.50) <= 128);
    }

    #[test]
    fn report_includes_latency_when_recorded() {
        let m = Metrics::default();
        assert!(!m.report(32).contains("lat p50"));
        m.read_latency.record(2_000);
        assert!(m.report(32).contains("lat p50"));
    }

    #[test]
    fn tiered_metrics_size_both_pools_and_expose_rate() {
        let m = Metrics::for_tiered_pipeline(3, 2, 1, 1);
        assert_eq!(m.shards.len(), 3);
        assert_eq!(m.hq_shards.len(), 2);
        // stage_shards routes hq traffic to its own table
        assert!(std::ptr::eq(m.stage_shards(StageId::Dnn).as_ptr(),
                             m.shards.as_ptr()));
        assert!(std::ptr::eq(m.stage_shards(StageId::DnnHq).as_ptr(),
                             m.hq_shards.as_ptr()));
        // single-tier pipelines carry no hq slots
        assert!(Metrics::for_pipeline(2, 1, 1).hq_shards.is_empty());
        assert_eq!(m.escalation_rate(), 0.0, "nothing decided yet");
        m.add(&m.fast_decided, 8);
        m.add(&m.escalations, 2);
        assert!((m.escalation_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn report_shows_tier_section_with_escalation_stats() {
        let m = Metrics::for_tiered_pipeline(1, 1, 1, 1);
        m.shards[0].mark_spawned(0);
        m.hq_shards[0].mark_spawned(0);
        m.add(&m.shards[0].windows, 10);
        m.add(&m.hq_shards[0].windows, 3);
        m.add(&m.fast_decided, 10);
        m.add(&m.escalations, 3);
        let r = m.report(32);
        assert!(r.contains("tier fast 10w hq 3w"), "{r}");
        assert!(r.contains("esc 3/10 (30.0%)"), "{r}");
        assert!(!r.contains("esc-lat"), "no samples yet: {r}");
        m.escalation_latency.record(2_000);
        assert!(m.report(32).contains("esc-lat p50"), "{}", m.report(32));
        // an untiered Metrics never prints the section
        assert!(!Metrics::default().report(32).contains("tier fast"));
    }

    #[test]
    fn tenant_rows_accumulate_and_render() {
        let m = Metrics::default();
        assert!(m.tenant_ids().is_empty());
        assert!(!m.report(32).contains("tenants ["),
                "no tenant section without tenants");
        let t2 = m.tenant(2);
        m.add(&t2.reads_in, 3);
        m.add(&t2.reads_out, 2);
        m.add(&t2.windows, 12);
        m.add(&t2.shed, 1);
        t2.latency.record(2_000);
        m.add(&m.tenant(1).reads_in, 1);
        // the same id returns the same row
        assert_eq!(m.tenant(2).reads_in.load(Ordering::Relaxed), 3);
        assert_eq!(m.tenant_ids(), vec![1, 2]);
        m.add(&m.shed_reads, 1);
        m.add(&m.dropped_reads, 2);
        let r = m.report(32);
        assert!(r.contains("shed 1 dropped 2"), "{r}");
        assert!(r.contains("t2 3->2 12w shed 1 p99"), "{r}");
        assert!(r.contains("t1 1->0 0w"), "{r}");
        // tenant 1 ordered before tenant 2
        assert!(r.find("t1 ").unwrap() < r.find("t2 ").unwrap(), "{r}");
    }

    /// The satellite fix this PR pins: every utilization split —
    /// shard, hq, decode, vote — must use the same percent format and
    /// the same unspawned-slot filter. Before, the decode/vote
    /// sections printed rows for slots no worker ever occupied while
    /// the shard section skipped them.
    #[test]
    fn report_percent_format_is_consistent_across_sections() {
        let m = Metrics::for_tiered_pipeline(2, 2, 2, 2);
        // slot 0 of each section spawned; slot 1 spawned only for hq,
        // where it is also retired
        m.shards[0].mark_spawned(0);
        m.hq_shards[0].mark_spawned(0);
        m.hq_shards[1].mark_spawned(0);
        m.hq_shards[1].mark_retired(m.epoch_micros());
        m.decode_workers[0].mark_spawned(0);
        m.vote_workers[0].mark_spawned(0);
        let r = m.report(32);
        let section = |label: &str| {
            let start = r.find(label)
                .unwrap_or_else(|| panic!("missing {label}: {r}"));
            let end = r[start..].find(']').unwrap() + start;
            r[start..=end].to_string()
        };
        for label in ["shard-util [", "hq-util [",
                      "decode-util [", "vote-util ["] {
            let sec = section(label);
            assert!(sec.contains("0:") && sec.contains('%'),
                    "{label}: {sec}");
            // the unspawned-slot rule applies to EVERY section: only
            // hq slot 1 ever spawned, so only hq lists a row for it
            assert_eq!(sec.contains("1:"), label == "hq-util [",
                       "{label}: {sec}");
        }
        assert!(section("hq-util [").contains("%(retired)"),
                "{}", section("hq-util ["));
    }
}
