//! Coordinator telemetry: lock-free counters + derived rates.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    pub reads_in: AtomicU64,
    pub reads_out: AtomicU64,
    pub windows: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    pub full_batches: AtomicU64,
    pub bases_called: AtomicU64,
    pub dnn_micros: AtomicU64,
    pub decode_micros: AtomicU64,
    pub vote_micros: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            start: Instant::now(),
            reads_in: AtomicU64::new(0),
            reads_out: AtomicU64::new(0),
            windows: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            full_batches: AtomicU64::new(0),
            bases_called: AtomicU64::new(0),
            dnn_micros: AtomicU64::new(0),
            decode_micros: AtomicU64::new(0),
            vote_micros: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    pub fn add(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn mean_batch_fill(&self, max_batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_items.load(Ordering::Relaxed) as f64
            / (b as f64 * max_batch as f64)
    }

    /// Base-calling throughput so far (bases/s).
    pub fn throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64().max(1e-9);
        self.bases_called.load(Ordering::Relaxed) as f64 / secs
    }

    pub fn report(&self, max_batch: usize) -> String {
        format!(
            "reads {}->{}  windows {}  batches {} (fill {:.2})  bases {}  \
             t_dnn {:.1}ms t_decode {:.1}ms t_vote {:.1}ms  {:.0} bp/s",
            self.reads_in.load(Ordering::Relaxed),
            self.reads_out.load(Ordering::Relaxed),
            self.windows.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_fill(max_batch),
            self.bases_called.load(Ordering::Relaxed),
            self.dnn_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.decode_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.vote_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.windows, 5);
        m.add(&m.windows, 3);
        assert_eq!(m.windows.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn batch_fill() {
        let m = Metrics::default();
        m.add(&m.batches, 2);
        m.add(&m.batch_items, 48);
        assert!((m.mean_batch_fill(32) - 0.75).abs() < 1e-12);
        assert_eq!(Metrics::default().mean_batch_fill(32), 0.0);
    }

    #[test]
    fn report_contains_counts() {
        let m = Metrics::default();
        m.add(&m.bases_called, 123);
        assert!(m.report(32).contains("bases 123"));
    }
}
