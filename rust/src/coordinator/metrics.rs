//! Coordinator telemetry: lock-free counters, derived rates, and a
//! fixed-bucket latency histogram for per-read end-to-end latency
//! (submit -> CalledRead emitted by the collector).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Buckets in the latency histogram: bucket `i` covers `[2^i, 2^(i+1))`
/// µs, so 40 buckets span sub-µs to ~12 days.
const NUM_BUCKETS: usize = 40;

/// Power-of-two-bucketed histogram of microsecond latencies: bucket `i`
/// counts samples in `[2^i, 2^(i+1))` µs (bucket 0 also holds 0–1 µs).
/// Lock-free, fixed memory, no external crates; quantiles are accurate to
/// within one octave, which is plenty for a p50/p99 trend line.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
    max_micros: AtomicU64,
}

impl LatencyHistogram {
    fn bucket_of(us: u64) -> usize {
        // floor(log2(us)) for us >= 1; 0 µs lands in bucket 0
        (63 - (us | 1).leading_zeros() as usize).min(NUM_BUCKETS - 1)
    }

    pub fn record(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(us, Ordering::Relaxed);
        self.max_micros.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_micros(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_micros.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn max_micros(&self) -> u64 {
        self.max_micros.load(Ordering::Relaxed)
    }

    /// Approximate quantile in µs: the upper edge of the bucket where the
    /// cumulative count crosses `q`, clamped to the observed max.
    pub fn quantile_micros(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                let upper = 1u64 << (i as u32 + 1).min(63);
                return upper.min(self.max_micros());
            }
        }
        self.max_micros()
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
            max_micros: AtomicU64::new(0),
        }
    }
}

#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    pub reads_in: AtomicU64,
    pub reads_out: AtomicU64,
    pub windows: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    pub full_batches: AtomicU64,
    pub bases_called: AtomicU64,
    pub dnn_micros: AtomicU64,
    pub decode_micros: AtomicU64,
    pub vote_micros: AtomicU64,
    /// per-read end-to-end latency, submit() -> CalledRead emitted.
    pub read_latency: LatencyHistogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            start: Instant::now(),
            reads_in: AtomicU64::new(0),
            reads_out: AtomicU64::new(0),
            windows: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            full_batches: AtomicU64::new(0),
            bases_called: AtomicU64::new(0),
            dnn_micros: AtomicU64::new(0),
            decode_micros: AtomicU64::new(0),
            vote_micros: AtomicU64::new(0),
            read_latency: LatencyHistogram::default(),
        }
    }
}

impl Metrics {
    pub fn add(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn mean_batch_fill(&self, max_batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_items.load(Ordering::Relaxed) as f64
            / (b as f64 * max_batch as f64)
    }

    /// Base-calling throughput so far (bases/s).
    pub fn throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64().max(1e-9);
        self.bases_called.load(Ordering::Relaxed) as f64 / secs
    }

    pub fn report(&self, max_batch: usize) -> String {
        let mut s = format!(
            "reads {}->{}  windows {}  batches {} (fill {:.2})  bases {}  \
             t_dnn {:.1}ms t_decode {:.1}ms t_vote {:.1}ms  {:.0} bp/s",
            self.reads_in.load(Ordering::Relaxed),
            self.reads_out.load(Ordering::Relaxed),
            self.windows.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_fill(max_batch),
            self.bases_called.load(Ordering::Relaxed),
            self.dnn_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.decode_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.vote_micros.load(Ordering::Relaxed) as f64 / 1e3,
            self.throughput(),
        );
        if self.read_latency.count() > 0 {
            s.push_str(&format!(
                "  lat p50 {:.1}ms p99 {:.1}ms",
                self.read_latency.quantile_micros(0.50) as f64 / 1e3,
                self.read_latency.quantile_micros(0.99) as f64 / 1e3,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.windows, 5);
        m.add(&m.windows, 3);
        assert_eq!(m.windows.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn batch_fill() {
        let m = Metrics::default();
        m.add(&m.batches, 2);
        m.add(&m.batch_items, 48);
        assert!((m.mean_batch_fill(32) - 0.75).abs() < 1e-12);
        assert_eq!(Metrics::default().mean_batch_fill(32), 0.0);
    }

    #[test]
    fn report_contains_counts() {
        let m = Metrics::default();
        m.add(&m.bases_called, 123);
        assert!(m.report(32).contains("bases 123"));
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_micros(0.99), 0);
        // 99 fast samples, 1 slow one
        for _ in 0..99 {
            h.record(100);
        }
        h.record(100_000);
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_micros(0.50);
        assert!((64..=128).contains(&p50), "p50 {p50}");
        let p99 = h.quantile_micros(0.99);
        assert!(p99 <= 128, "p99 {p99} should still be in the fast bucket");
        let p100 = h.quantile_micros(1.0);
        assert_eq!(p100, 100_000, "max clamps the top bucket edge");
        assert_eq!(h.max_micros(), 100_000);
        assert!((h.mean_micros() - 1099.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_bucket_edges() {
        assert_eq!(LatencyHistogram::bucket_of(0), 0);
        assert_eq!(LatencyHistogram::bucket_of(1), 0);
        assert_eq!(LatencyHistogram::bucket_of(2), 1);
        assert_eq!(LatencyHistogram::bucket_of(3), 1);
        assert_eq!(LatencyHistogram::bucket_of(4), 2);
        assert_eq!(LatencyHistogram::bucket_of(u64::MAX), 39);
    }

    #[test]
    fn report_includes_latency_when_recorded() {
        let m = Metrics::default();
        assert!(!m.report(32).contains("lat p50"));
        m.read_latency.record(2_000);
        assert!(m.report(32).contains("lat p50"));
    }
}
