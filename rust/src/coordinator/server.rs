//! The coordinator proper: read router -> window batcher -> DNN executor
//! (PJRT, single owner thread) -> CTC decode pool -> per-read collector +
//! voter.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::basecall::ctc::{beam_search, LogProbs};
use crate::basecall::vote::consensus;
use crate::genome::dataset::windows_from_read;
use crate::genome::synth::Read;
use crate::runtime::Engine;

use super::batcher::{Batcher, BatchPolicy};
use super::metrics::Metrics;

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub model: String,
    pub bits: u32,
    /// window hop in samples; window length comes from the artifact meta.
    pub hop: usize,
    pub beam_width: usize,
    pub decode_threads: usize,
    pub policy: BatchPolicy,
    pub artifacts_dir: String,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            hop: 100,
            beam_width: 10,
            decode_threads: 2,
            policy: BatchPolicy::default(),
            artifacts_dir: crate::runtime::meta::default_artifacts_dir(),
        }
    }
}

/// A fully base-called read: per-window decodes voted into a consensus and
/// spliced into one sequence.
#[derive(Clone, Debug)]
pub struct CalledRead {
    pub read_id: usize,
    pub seq: Vec<u8>,
    /// per-window decoded fragments (pre-splice), for accuracy accounting.
    pub window_decodes: Vec<Vec<u8>>,
}

struct WindowJob {
    read_id: usize,
    window_idx: usize,
    signal: Vec<f32>,
}

struct DecodeJob {
    read_id: usize,
    window_idx: usize,
    lp: LogProbs,
}

struct DecodedWindow {
    read_id: usize,
    window_idx: usize,
    seq: Vec<u8>,
}

/// Staged pipeline coordinator. Construct, `submit` reads, then `finish`.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    window: usize,
    tx_windows: Option<Sender<WindowJob>>,
    dnn_thread: Option<JoinHandle<Result<()>>>,
    decode_threads: Vec<JoinHandle<()>>,
    rx_decoded: Receiver<DecodedWindow>,
    pub metrics: Arc<Metrics>,
    expected: HashMap<usize, usize>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Result<Coordinator> {
        // validate metadata on the caller thread for early errors
        let meta = crate::runtime::Meta::load(&cfg.artifacts_dir)?;
        let window = meta.window;
        let batches = meta.batches(&cfg.model, cfg.bits);
        anyhow::ensure!(!batches.is_empty(),
                        "no artifacts for {}/{}b", cfg.model, cfg.bits);
        let metrics = Arc::new(Metrics::default());

        let (tx_windows, rx_windows) = channel::<WindowJob>();
        let (tx_decode, rx_decode) = channel::<DecodeJob>();
        let (tx_decoded, rx_decoded) = channel::<DecodedWindow>();
        let (tx_ready, rx_ready) = channel::<Result<()>>();

        // DNN executor: the PJRT client is not Send, so the engine is both
        // constructed and used inside its owner thread.
        let m = metrics.clone();
        let c = cfg.clone();
        let dnn_thread = std::thread::spawn(move || -> Result<()> {
            let mut engine = match Engine::new(&c.artifacts_dir) {
                Ok(mut e) => {
                    // warm the executable cache; report readiness
                    let mut init = Ok(());
                    for b in e.meta.batches(&c.model, c.bits) {
                        if let Err(err) = e.load(&c.model, c.bits, b) {
                            init = Err(err);
                            break;
                        }
                    }
                    let ok = init.is_ok();
                    let _ = tx_ready.send(init);
                    if !ok {
                        return Ok(());
                    }
                    e
                }
                Err(err) => {
                    let _ = tx_ready.send(Err(err));
                    return Ok(());
                }
            };
            let mut batcher = Batcher::new(rx_windows, c.policy);
            while let Some(batch) = batcher.next_batch() {
                let t0 = Instant::now();
                let sigs: Vec<Vec<f32>> = batch.items.iter()
                    .map(|j| j.signal.clone())
                    .collect();
                let lps = engine.run_windows(&c.model, c.bits, &sigs)?;
                m.add(&m.batches, 1);
                m.add(&m.batch_items, batch.items.len() as u64);
                if batch.full {
                    m.add(&m.full_batches, 1);
                }
                m.add(&m.dnn_micros, t0.elapsed().as_micros() as u64);
                for (job, lp) in batch.items.into_iter().zip(lps) {
                    let _ = tx_decode.send(DecodeJob {
                        read_id: job.read_id,
                        window_idx: job.window_idx,
                        lp,
                    });
                }
            }
            Ok(())
        });

        // decode pool.
        let rx_decode = Arc::new(Mutex::new(rx_decode));
        let mut decode_threads = Vec::new();
        for _ in 0..cfg.decode_threads.max(1) {
            let rx = rx_decode.clone();
            let tx = tx_decoded.clone();
            let m = metrics.clone();
            let beam = cfg.beam_width;
            decode_threads.push(std::thread::spawn(move || {
                loop {
                    let job = match rx.lock().unwrap().recv() {
                        Ok(j) => j,
                        Err(_) => break,
                    };
                    let t0 = Instant::now();
                    let seq = beam_search(&job.lp, beam);
                    m.add(&m.decode_micros, t0.elapsed().as_micros() as u64);
                    let _ = tx.send(DecodedWindow {
                        read_id: job.read_id,
                        window_idx: job.window_idx,
                        seq,
                    });
                }
            }));
        }
        drop(tx_decoded);

        // wait for the engine thread to finish compiling (or fail fast)
        rx_ready.recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during init"))??;

        Ok(Coordinator {
            cfg,
            window,
            tx_windows: Some(tx_windows),
            dnn_thread: Some(dnn_thread),
            decode_threads,
            rx_decoded,
            metrics,
            expected: HashMap::new(),
        })
    }

    /// Split a read into windows and enqueue them.
    pub fn submit(&mut self, read: &Read) {
        let ws = windows_from_read(read, self.window, self.cfg.hop);
        self.metrics.add(&self.metrics.reads_in, 1);
        self.metrics.add(&self.metrics.windows, ws.len() as u64);
        self.expected.insert(read.id, ws.len());
        if let Some(tx) = &self.tx_windows {
            for (i, w) in ws.into_iter().enumerate() {
                let _ = tx.send(WindowJob {
                    read_id: read.id,
                    window_idx: i,
                    signal: w.signal,
                });
            }
        }
    }

    /// Close the intake, drain the pipeline, vote per-read consensus, and
    /// splice window decodes into called reads.
    pub fn finish(mut self) -> Result<Vec<CalledRead>> {
        drop(self.tx_windows.take());
        if let Some(h) = self.dnn_thread.take() {
            h.join().map_err(|_| anyhow::anyhow!("dnn thread panicked"))??;
        }
        for h in self.decode_threads.drain(..) {
            let _ = h.join();
        }
        // collect decoded windows per read
        let mut per_read: HashMap<usize, Vec<(usize, Vec<u8>)>> =
            HashMap::new();
        while let Ok(d) = self.rx_decoded.recv_timeout(Duration::ZERO) {
            per_read.entry(d.read_id).or_default()
                .push((d.window_idx, d.seq));
        }
        let mut out = Vec::new();
        for (read_id, mut wins) in per_read {
            wins.sort_by_key(|(i, _)| *i);
            let decodes: Vec<Vec<u8>> = wins.into_iter()
                .map(|(_, s)| s)
                .collect();
            let t0 = Instant::now();
            // within-read voting (the ⌊L/T⌋-reads-per-signal vote of §2.2):
            // neighbouring windows overlap, so vote each window against its
            // neighbours before splicing.
            let voted: Vec<Vec<u8>> = (0..decodes.len())
                .map(|i| {
                    let mut nbrs: Vec<&[u8]> = Vec::new();
                    if i > 0 {
                        nbrs.push(&decodes[i - 1]);
                    }
                    if i + 1 < decodes.len() {
                        nbrs.push(&decodes[i + 1]);
                    }
                    consensus(&decodes[i], &nbrs)
                })
                .collect();
            let seq = crate::basecall::vote::merge_reads(&voted, 6);
            self.metrics.add(&self.metrics.vote_micros,
                             t0.elapsed().as_micros() as u64);
            self.metrics.add(&self.metrics.bases_called, seq.len() as u64);
            self.metrics.add(&self.metrics.reads_out, 1);
            out.push(CalledRead { read_id, seq, window_decodes: decodes });
        }
        out.sort_by_key(|r| r.read_id);
        Ok(out)
    }

    pub fn max_batch(&self) -> usize {
        self.cfg.policy.max_batch
    }
}
