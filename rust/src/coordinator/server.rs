//! Streaming pipeline lifecycle: construction, submission, drain.
//!
//! Window flow: windower -> size-or-deadline batcher (dispatch thread,
//! `coordinator::dispatch`) -> sharded DNN executor pool (each shard
//! thread owns its own `runtime::Backend` replica: the native quantized
//! executor by default, PJRT under the `xla` feature) -> CTC decode
//! pool (per-worker queues fed round-robin) -> collector router -> vote
//! worker pool -> output queue.
//!
//! Every interior stage boundary is a bounded channel (`util::bounded`),
//! so a slow stage backpressures its producer all the way up to
//! `submit()` instead of queues growing with run size; the output queue
//! alone is uncapped (see README). Each `CalledRead` is emitted the
//! moment its last window decodes (`try_recv`/`recv_timeout`);
//! `finish()` is a thin drain-the-rest shim for batch callers. See
//! `coordinator/README.md` for the stage/queue map.
//!
//! The DNN stage fans out over a pool of backend replicas reached
//! through a [`QueueSet`] of per-shard queues (`coordinator::pool`).
//! Dispatch is *batch-size-aware*: full (size-triggered) batches go to
//! the least-loaded live shard, small deadline-triggered tail batches go
//! to the *busiest* live shard so the heavy batches stay unsplit and
//! idle replicas stay genuinely idle. With `CoordinatorConfig::autoscale`
//! set, a controller thread (`coordinator::autoscale`) resizes the live
//! pool between `min_shards` and `max_shards` from observed
//! utilization — spawning replicas through the [`ShardFactory`] and
//! retiring them by closing their queue so they drain out through the
//! same skip-dead dispatch a crashed replica exercises. Because every
//! replica computes identical `LogProbs` for a given window (windows
//! never see their batch neighbours), the called result set is
//! byte-identical for any shard count, fixed or adaptive (mid-run
//! emission order remains completion order, as with one shard).
//!
//! With `CoordinatorConfig::escalate_margin` set, the pipeline runs
//! **speculative tiered serving**: fresh windows execute on a *fast*
//! low-bit shard pool, the decode stage measures each window's
//! top-two-beam confidence margin, and windows below the threshold are
//! re-queued — through an unbounded escalation side channel back into
//! the dispatcher's requeue lane — onto a full-precision *hq* pool. An
//! escalated fast decode emits nothing, so the collector naturally
//! waits for the hq replacement before voting; last-delivery-wins
//! routing keyed by `(read_id, window_idx)` makes the substitution
//! invisible downstream. Escalation off (`None`, the default) runs the
//! exact single-tier code path, byte-identical to pre-tier builds.
//!
//! Two further opt-ins extend the pipeline past the collector (see
//! `coordinator::analysis`): `CoordinatorConfig::analysis_threads`
//! arms a **streaming analysis stage** — every voted read is side-fed
//! from the vote workers into an autoscalable pool that grows an
//! incremental per-tenant overlap graph, queryable at any point for a
//! polished consensus byte-identical to the offline
//! `pipeline::consensus` over the same called reads — and
//! `CoordinatorConfig::reject_threshold` arms **GenPIP-style early
//! rejection**: the decode stage's confidence margin condemns hopeless
//! reads at chunk granularity, short-circuiting the rest of their
//! windows past the CTC kernel and dropping them before vote/analysis
//! spend on them. Both default off and change nothing when off.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::util::sync::AtomicU64;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::genome::dataset::windows_from_read;
use crate::genome::synth::Read;
use crate::runtime::{ShardFactory, Tier, TierSet};
use crate::util::bounded::{bounded, unbounded, Feeder, QueueSet,
                           Receiver, Sender};

use super::analysis::{spawn_analysis_pool, AnalysisState, RejectGate,
                      ANALYSIS_MIN_OVERLAP};
use super::autoscale::{self, StageControl, StagePool, WorkerPool};
use super::collector::{Collector, CollectorConfig, DecodedWindow,
                       ReadRegistry};
use super::dispatch::{spawn_dispatch, TierRouting};
use super::job::{AnalysisJob, DecodeJob, ShardBatch, WindowJob};
use super::metrics::{Metrics, StageId};
use super::pool::{spawn_decode_pool, Escalator, ShardHost,
                  SHARD_QUEUE_DEPTH};

pub use super::config::CoordinatorConfig;

/// A fully base-called read: per-window decodes voted into a consensus and
/// spliced into one sequence.
#[derive(Clone, Debug)]
pub struct CalledRead {
    /// id of the submitted `Read` this call answers.
    pub read_id: usize,
    /// owning tenant: 0 for reads submitted through the in-process
    /// library path (`submit`), the submitting connection's id for
    /// reads that arrived over the TCP front-end (`coordinator::net`),
    /// which uses this tag to route the completion back to its socket.
    pub tenant: u64,
    /// consensus base sequence (values 0–3, one per called base).
    pub seq: Vec<u8>,
    /// per-window decoded fragments (pre-splice), for accuracy accounting.
    pub window_decodes: Vec<Vec<u8>>,
}

/// Staged streaming pipeline coordinator. Construct, `submit` reads, pull
/// completed reads mid-run with `try_recv`/`recv_timeout`, then `finish`
/// to drain the rest.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    window: usize,
    registry: Arc<ReadRegistry>,
    tiers: Option<TierSet>,
    tx_windows: Option<Sender<WindowJob>>,
    dispatch_thread: Option<JoinHandle<()>>,
    host: Option<Arc<ShardHost>>,
    hq_host: Option<Arc<ShardHost>>,
    autoscale_stop: Option<Sender<()>>,
    autoscale_thread: Option<JoinHandle<()>>,
    decode_pool: Option<Arc<WorkerPool<DecodeJob>>>,
    collector: Option<Collector>,
    analysis_pool: Option<Arc<WorkerPool<AnalysisJob>>>,
    analysis: Option<Arc<AnalysisState>>,
    /// live pipeline telemetry (readable mid-run; see `Metrics`).
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Open the full pipeline: probe the artifact metadata, build the
    /// shard factory, spawn the dispatcher, the DNN shard pool(s), the
    /// decode pool, the collector, and (when configured) the autoscale
    /// controller, and block until every *initial* shard's backend has
    /// opened and warmed (so compile/load failures surface here, not
    /// mid-run).
    pub fn new(cfg: CoordinatorConfig) -> Result<Coordinator> {
        // validate metadata on the caller thread for early errors
        let meta = cfg.backend.probe_meta(&cfg.artifacts_dir)?;
        let window = meta.window;
        let batches = meta.batches(&cfg.model, cfg.bits);
        anyhow::ensure!(!batches.is_empty(),
                        "no artifacts for {}/{}b", cfg.model, cfg.bits);
        // tier plan: escalate_margin arms the fast/hq pair; the fast
        // bit-width comes from the artifact ladder (or the explicit
        // override), validated here so a ladder without a rung below
        // `bits` fails at construction, not mid-run
        let tiers = match cfg.escalate_margin {
            Some(_) => Some(TierSet::from_meta(
                &meta, &cfg.model, cfg.bits, cfg.tier_bits)?),
            None => None,
        };
        // the factory front-loads the one artifact load every replica
        // is cloned from (native), so open errors also surface here.
        // A native replica holds the quantized models for EVERY
        // exported bit-width, so one factory serves both tiers.
        let factory = Arc::new(
            ShardFactory::new(cfg.backend, &cfg.artifacts_dir)?);

        // shard plan: a fixed pool runs `dnn_shards` slots, all live;
        // an adaptive pool pre-allocates `max_shards` slots and starts
        // with `dnn_shards` clamped into [min_shards, max_shards]. The
        // hq pool (tiered only) gets the same treatment under its own
        // `hq_min_shards`/`hq_max_shards` bounds.
        let auto = cfg.autoscale.map(|a| a.normalized());
        let (n_slots, n_initial) = match &auto {
            Some(a) => (a.max_shards,
                        cfg.dnn_shards.clamp(a.min_shards, a.max_shards)),
            None => {
                let n = cfg.dnn_shards.max(1);
                (n, n)
            }
        };
        let (hq_slots, hq_initial) = match (&tiers, &auto) {
            (None, _) => (0, 0),
            (Some(_), Some(a)) => (a.hq_max_shards,
                                   cfg.dnn_shards.clamp(a.hq_min_shards,
                                                        a.hq_max_shards)),
            (Some(_), None) => {
                let n = cfg.dnn_shards.max(1);
                (n, n)
            }
        };
        let n_dec = cfg.decode_threads.max(1);
        let n_vote = cfg.vote_threads.max(1);
        let n_analysis = cfg.analysis_threads; // 0 = stage off
        let metrics = Arc::new(Metrics::for_full_pipeline(
            n_slots, hq_slots, n_dec, n_vote, n_analysis));
        let registry = Arc::new(ReadRegistry::default());
        // early rejection: the gate the decode pool marks and the
        // collector router drops/forgets through
        let gate = cfg.reject_threshold
            .map(|t| Arc::new(RejectGate::new(t)));

        let cap = cfg.queue_cap.max(1);
        let (tx_windows, rx_windows) = bounded::<WindowJob>(cap);
        let (tx_decoded, rx_decoded) = bounded::<DecodedWindow>(cap);

        // the escalation side channel is UNBOUNDED on purpose: an
        // escalating decode worker must never block on the dispatcher
        // (which may itself be blocked sending into a full shard queue
        // whose drain path runs through that same decode worker — a
        // bounded channel here closes that cycle into a deadlock).
        // Depth is bounded in practice by the windows in flight, which
        // the window queue already caps. `pending` counts fast windows
        // dispatched but not yet past their escalation decision; the
        // dispatcher increments BEFORE a fresh batch is sent and the
        // decode worker decrements AFTER its decision (send first), so
        // the tiered batcher only ends the stream when no escalation
        // can still arrive.
        let pending = Arc::new(AtomicU64::new(0));
        let (escalator, esc_parts) = match cfg.escalate_margin {
            Some(margin) => {
                let (tx_esc, rx_esc) = unbounded::<WindowJob>();
                (Some(Escalator {
                    margin,
                    tx: tx_esc.clone(),
                    pending: pending.clone(),
                }),
                 Some((tx_esc, rx_esc)))
            }
            None => (None, None),
        };

        let dec_cap = (cap / n_dec).max(8);
        let decode_pool = spawn_decode_pool(
            metrics.clone(), n_dec, dec_cap, cfg.beam_width, cfg.prune,
            tx_decoded, escalator, gate.clone());

        // streaming analysis stage (off at 0 threads): the state the
        // workers fold voted reads into, the pool, and the feeder the
        // vote workers will side-send through. The feeder moves into
        // the collector — its vote workers hold the only clones, so
        // the analysis queues seal exactly when the vote stage exits.
        let (analysis_state, analysis_pool, analysis_feed) =
            if n_analysis > 0 {
                let state = Arc::new(
                    AnalysisState::new(ANALYSIS_MIN_OVERLAP));
                let a_cap = (cap / n_analysis).max(8);
                let pool = spawn_analysis_pool(
                    metrics.clone(), n_analysis, a_cap, state.clone());
                let feed = Feeder::new(pool.queues());
                (Some(state), Some(pool), Some(feed))
            } else {
                (None, None, None)
            };

        // per-shard batch queues live in a QueueSet so the autoscaler
        // can add/retire slots mid-run. Install the initial queues
        // BEFORE the dispatcher spawns: dispatch must never observe an
        // empty set at startup (it would read as pool collapse).
        let queues = Arc::new(QueueSet::<ShardBatch>::with_slots(n_slots));
        let mut initial: Vec<(usize, u64, Receiver<ShardBatch>)> =
            Vec::with_capacity(n_initial);
        for _ in 0..n_initial {
            let (tx, rx) = bounded::<ShardBatch>(SHARD_QUEUE_DEPTH);
            let slot = queues.add(tx)
                .expect("a fresh queue set has a slot per initial shard");
            initial.push((slot, queues.generation(slot), rx));
        }
        let (routing, hq_queues, hq_tx) = match esc_parts {
            Some((tx_esc, rx_esc)) => {
                let qs = Arc::new(
                    QueueSet::<ShardBatch>::with_slots(hq_slots));
                (Some(TierRouting {
                    esc_rx: rx_esc,
                    pending: pending.clone(),
                    hq_queues: qs.clone(),
                }),
                 Some(qs), Some(tx_esc))
            }
            None => (None, None, None),
        };
        let mut hq_install: Vec<(usize, u64, Receiver<ShardBatch>)> =
            Vec::with_capacity(hq_initial);
        if let Some(qs) = &hq_queues {
            for _ in 0..hq_initial {
                let (tx, rx) = bounded::<ShardBatch>(SHARD_QUEUE_DEPTH);
                let slot = qs.add(tx)
                    .expect("a fresh queue set has a slot per initial \
                             shard");
                hq_install.push((slot, qs.generation(slot), rx));
            }
        }

        // the dispatch thread: single-tier batcher loop, or — with
        // escalation armed — the two-lane tiered loop routing fresh
        // batches to the fast pool and requeued ones to the hq pool
        let dispatch_thread = spawn_dispatch(
            rx_windows, cfg.policy, metrics.clone(), queues.clone(),
            routing);

        let host = Arc::new(ShardHost {
            factory: factory.clone(),
            model: cfg.model.clone(),
            bits: tiers.as_ref().map_or(cfg.bits, |t| t.fast_bits),
            stage: StageId::Dnn,
            tier: if tiers.is_some() { Tier::Fast } else { Tier::Hq },
            keep_signals: tiers.is_some(),
            queues: queues.clone(),
            dec: Feeder::new(decode_pool.queues()),
            metrics: metrics.clone(),
            handles: Mutex::new(Vec::new()),
            window_tx: tx_windows.clone(),
            window_cap: cap,
        });
        let hq_host = match (&tiers, hq_queues, hq_tx) {
            (Some(t), Some(qs), Some(tx_esc)) => Some(Arc::new(ShardHost {
                factory: factory.clone(),
                model: cfg.model.clone(),
                bits: t.hq_bits,
                stage: StageId::DnnHq,
                tier: Tier::Hq,
                keep_signals: false,
                queues: qs,
                dec: Feeder::new(decode_pool.queues()),
                metrics: metrics.clone(),
                handles: Mutex::new(Vec::new()),
                window_tx: tx_esc,
                window_cap: cap,
            })),
            _ => None,
        };

        // initial shard pools; every shard reports open+warm exactly
        // once through the shared ready channel
        let total_initial = n_initial + hq_initial;
        let (tx_ready, rx_ready) =
            bounded::<Result<()>>(total_initial.max(1));
        for (slot, generation, rx) in initial {
            host.launch(slot, generation, rx, Some(tx_ready.clone()));
        }
        if let Some(hq) = &hq_host {
            for (slot, generation, rx) in hq_install {
                hq.launch(slot, generation, rx, Some(tx_ready.clone()));
            }
        }
        drop(tx_ready); // shard threads hold the only ready senders

        // collector: assembles out-of-order windows, votes + splices in
        // its own worker pool, emits CalledReads eagerly — and, when
        // armed, side-feeds the analysis stage and drops rejected reads.
        let collector = Collector::spawn_full(
            registry.clone(),
            rx_decoded,
            metrics.clone(),
            CollectorConfig {
                vote_threads: n_vote,
                queue_cap: cap,
            },
            analysis_feed,
            gate,
        );

        // wait for every initial shard to finish opening + warming (or
        // fail fast: the first shard error aborts construction, and the
        // channel cascade tears the other stages down as this frame's
        // senders drop)
        for _ in 0..total_initial {
            rx_ready.recv()
                .map_err(|_| anyhow::anyhow!(
                    "a dnn shard thread died during init"))??;
        }
        if auto.is_none() {
            // fixed pool(s): no further replica will ever be built, so
            // release the factory's native prototype instead of
            // carrying an extra model copy for the whole run
            host.factory.discard_prototype();
        }

        // adaptive controller: one thread sizing every controlled
        // stage — the fast DNN pool always, the hq pool when tiered,
        // the decode/vote pools when `scale_decode`/`scale_vote` opt
        // them in (their configured widths become the per-stage
        // ceilings, floor 1). Runs sample → decide → scale/retire every
        // tick until finish() signals stop (see coordinator::autoscale).
        let (autoscale_stop, autoscale_thread) = match auto {
            Some(a) => {
                let (stop_tx, stop_rx) = bounded::<()>(1);
                let mut stages = vec![StageControl {
                    stage: StageId::Dnn,
                    pool: host.clone() as Arc<dyn StagePool>,
                    min: a.min_shards,
                    max: a.max_shards,
                }];
                if let Some(hq) = &hq_host {
                    stages.push(StageControl {
                        stage: StageId::DnnHq,
                        pool: hq.clone() as Arc<dyn StagePool>,
                        min: a.hq_min_shards,
                        max: a.hq_max_shards,
                    });
                }
                if a.scale_decode {
                    stages.push(StageControl {
                        stage: StageId::Decode,
                        pool: decode_pool.clone() as Arc<dyn StagePool>,
                        min: 1,
                        max: n_dec,
                    });
                }
                if a.scale_vote {
                    if let Some(pool) = collector.vote_stage_pool() {
                        stages.push(StageControl {
                            stage: StageId::Vote,
                            pool,
                            min: 1,
                            max: n_vote,
                        });
                    }
                }
                if a.scale_analysis {
                    if let Some(pool) = &analysis_pool {
                        stages.push(StageControl {
                            stage: StageId::Analysis,
                            pool: pool.clone() as Arc<dyn StagePool>,
                            min: 1,
                            max: n_analysis,
                        });
                    }
                }
                let m = metrics.clone();
                let handle = std::thread::spawn(move || {
                    autoscale::run(&stages, a, &m, &stop_rx);
                });
                (Some(stop_tx), Some(handle))
            }
            None => (None, None),
        };

        Ok(Coordinator {
            cfg,
            window,
            registry,
            tiers,
            tx_windows: Some(tx_windows),
            dispatch_thread: Some(dispatch_thread),
            host: Some(host),
            hq_host,
            autoscale_stop,
            autoscale_thread,
            decode_pool: Some(decode_pool),
            collector: Some(collector),
            analysis_pool,
            analysis: analysis_state,
            metrics,
        })
    }

    /// Split a read into windows and enqueue them. Blocks once
    /// `queue_cap` windows are in flight ahead of the DNN stage
    /// (backpressure), so raw-signal memory stays bounded for
    /// arbitrarily long runs. Completed reads accumulate on the
    /// (unbounded) output queue until taken; interleave `drain_ready()`
    /// in long submission loops to keep that flat too.
    pub fn submit(&mut self, read: &Read) {
        self.submit_tagged(read, 0);
    }

    /// `submit` with an explicit owning tenant: completions carry the
    /// tag in [`CalledRead::tenant`] so a front-end can route each one
    /// back to the connection that submitted it. Tenant 0 is the
    /// untenanted library path (`submit` delegates here with 0).
    pub fn submit_tagged(&mut self, read: &Read, tenant: u64) {
        let ws = windows_from_read(read, self.window, self.cfg.hop);
        let sigs: Vec<Vec<f32>> =
            ws.into_iter().map(|w| w.signal).collect();
        self.enqueue_windows(read.id, tenant, sigs);
    }

    /// Submit a bare signal with no truth labels — the TCP front-end's
    /// intake, where a client streams raw samples and nothing else. The
    /// signal is chopped into hop-strided windows exactly like
    /// `submit`'s windower chops a simulated read (every full window of
    /// a real-length read carries whole bases, so the two paths produce
    /// byte-identical window sets — pinned by the network byte-identity
    /// test). Returns the number of windows delivered into the
    /// pipeline: 0 means the read was trivially complete (shorter than
    /// one window) or the pipeline is already torn down, and no
    /// `CalledRead` will ever be emitted for it.
    pub fn submit_signal(&mut self, read_id: usize, signal: &[f32],
                         tenant: u64) -> usize {
        let window = self.window;
        let mut sigs = Vec::new();
        let mut start = 0usize;
        while start + window <= signal.len() {
            sigs.push(signal[start..start + window].to_vec());
            start += self.cfg.hop;
        }
        self.enqueue_windows(read_id, tenant, sigs)
    }

    /// Shared intake tail of `submit_tagged`/`submit_signal`: register,
    /// enqueue, count.
    fn enqueue_windows(&mut self, read_id: usize, tenant: u64,
                       sigs: Vec<Vec<f32>>) -> usize {
        if sigs.is_empty() {
            // shorter than one window: accepted, trivially complete
            self.metrics.add(&self.metrics.reads_in, 1);
            if tenant != 0 {
                self.metrics.add(&self.metrics.tenant(tenant).reads_in, 1);
            }
            return 0;
        }
        // register BEFORE the first window enters the pipeline so the
        // collector always knows the expected count. Counters, by
        // contrast, track what actually ENTERS the pipeline: windows
        // are counted per successful enqueue and the read once its
        // first window is in, so a mid-run DNN failure cannot leave
        // `windows` claiming deliveries that never happened (a
        // partially-sent read counts only its delivered prefix, and a
        // fully-refused read counts nothing at all).
        self.registry.register_tenant(read_id, sigs.len(), tenant);
        // fresh windows enter at the fast tier when tiering is armed;
        // a single-tier pipeline tags everything hq (the only model)
        let tier = if self.tiers.is_some() { Tier::Fast } else { Tier::Hq };
        let mut delivered: u64 = 0;
        if let Some(tx) = &self.tx_windows {
            for (i, signal) in sigs.into_iter().enumerate() {
                if tx.send(WindowJob {
                    read_id,
                    window_idx: i,
                    tenant,
                    signal,
                    tier,
                    enqueued_at: Instant::now(),
                    escalated_at: None,
                }).is_err() {
                    // DNN stage already exited (mid-run failure). If no
                    // window of this read got in, drop the registration
                    // so in_flight() doesn't count it forever.
                    if i == 0 {
                        self.registry.unregister(read_id);
                    }
                    break;
                }
                delivered += 1;
            }
        } else {
            self.registry.unregister(read_id);
        }
        if delivered > 0 {
            self.metrics.add(&self.metrics.reads_in, 1);
            self.metrics.add(&self.metrics.windows, delivered);
            if tenant != 0 {
                let ts = self.metrics.tenant(tenant);
                self.metrics.add(&ts.reads_in, 1);
                self.metrics.add(&ts.windows, delivered);
            }
        }
        delivered as usize
    }

    /// Mark every in-flight read of `tenant` cancelled (its owning
    /// connection died): the windows keep draining through the
    /// pipeline, but the collector drops each completed assembly
    /// instead of voting and emitting it, so nothing leaks and
    /// `in_flight()` settles to 0 on its own. Also purges the
    /// tenant's streaming-analysis state (and tombstones the id so
    /// jobs still draining out of the analysis queues are discarded)
    /// — a disconnected TCP client must not leak partial contigs.
    /// Returns the number of reads marked. See
    /// [`ReadRegistry::cancel_tenant`] and
    /// [`AnalysisState::drop_tenant`].
    pub fn cancel_tenant(&self, tenant: u64) -> usize {
        let n = self.registry.cancel_tenant(tenant);
        if let Some(state) = &self.analysis {
            state.drop_tenant(tenant);
        }
        n
    }

    /// The model's window length in samples (from the artifact meta) —
    /// what `submit_signal` chops against.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Non-blocking: the next read whose last window has decoded, if any.
    /// Reads stream out mid-run, in completion order (not id order).
    pub fn try_recv(&self) -> Option<CalledRead> {
        self.collector.as_ref()?.try_recv()
    }

    /// Block up to `timeout` for the next completed read.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<CalledRead> {
        self.collector.as_ref()?.recv_timeout(timeout)
    }

    /// Every read that has completed so far, without blocking. Calling
    /// this inside long submission loops keeps output memory flat; batch
    /// callers may skip it (the output queue is unbounded, so results
    /// simply accumulate there until `finish()`).
    pub fn drain_ready(&self) -> Vec<CalledRead> {
        let mut out = Vec::new();
        while let Some(r) = self.try_recv() {
            out.push(r);
        }
        out
    }

    /// Close the intake and deterministically drain the pipeline: blocks
    /// until every stage disconnects, then returns the remaining called
    /// reads sorted by id. Reads already taken via `try_recv`/
    /// `recv_timeout` are not returned again.
    pub fn finish(mut self) -> Result<Vec<CalledRead>> {
        // halt the autoscaler FIRST: once its thread is joined no scale
        // event can race the drain, and no new shard handle can appear
        // after we take them below.
        drop(self.autoscale_stop.take());
        if let Some(h) = self.autoscale_thread.take() {
            let _ = h.join();
        }
        // release the hosts' channel handles (window/escalation senders
        // + decode feeders): the recv-until-disconnect barrier below
        // relies on every sender dropping. The controller's host Arcs
        // are already gone. Dropping the hq host here also releases its
        // escalation-channel sender — together with the decode pool
        // release below, that guarantees the tiered dispatcher's
        // requeue lane disconnects even if the decode stage died with
        // escalations still counted pending.
        let mut shard_handles: Vec<JoinHandle<Result<()>>> = Vec::new();
        if let Some(host) = self.host.take() {
            shard_handles = host.handles.lock().unwrap()
                .drain(..).collect();
        }
        if let Some(hq) = self.hq_host.take() {
            shard_handles.extend(hq.handles.lock().unwrap().drain(..));
        }
        // release the decode pool: its respawn closure holds the
        // decoded-queue prototype sender (and, tiered, an escalation
        // sender), which must drop before the drain barrier can see the
        // collector disconnect. (The controller — the only other pool
        // holder — is joined above, so no worker can spawn after the
        // handles are taken.)
        let decode_handles: Vec<JoinHandle<()>> =
            match self.decode_pool.take() {
                Some(pool) => pool.take_handles(),
                None => Vec::new(),
            };
        drop(self.tx_windows.take());
        // drain first: recv-until-disconnect is the shutdown barrier —
        // it returns exactly when the last stage has emptied, after
        // which every join below is immediate.
        let collected = match self.collector.take() {
            Some(c) => c.finish(),
            None => Ok(Vec::new()),
        };
        // the collector drain joined the vote workers, whose feeder
        // clones were the analysis queues' only producers — the
        // analysis workers are draining out now, so their joins below
        // are immediate. (The controller — the only other pool holder
        // — was joined above, so the handle set is complete.)
        let analysis_handles: Vec<JoinHandle<()>> =
            match self.analysis_pool.take() {
                Some(pool) => pool.take_handles(),
                None => Vec::new(),
            };
        let mut err = None;
        if let Some(h) = self.dispatch_thread.take() {
            if h.join().is_err() {
                err = Some(anyhow::anyhow!("dispatch thread panicked"));
            }
        }
        for h in shard_handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if err.is_none() {
                        err = Some(e);
                    }
                }
                Err(_) => {
                    if err.is_none() {
                        err = Some(anyhow::anyhow!(
                            "dnn shard thread panicked"));
                    }
                }
            }
        }
        for h in decode_handles {
            if h.join().is_err() && err.is_none() {
                err = Some(anyhow::anyhow!("decode worker panicked"));
            }
        }
        for h in analysis_handles {
            if h.join().is_err() && err.is_none() {
                err = Some(anyhow::anyhow!("analysis worker panicked"));
            }
        }
        // a collector panic is the root cause of any knock-on DNN
        // "decode stage disconnected" error, so report it first
        let mut out = match (collected, err) {
            (Err(ce), _) => return Err(ce),
            (Ok(_), Some(e)) => return Err(e),
            (Ok(v), None) => v,
        };
        out.sort_by_key(|r| r.read_id);
        Ok(out)
    }

    /// The batching policy's size trigger (for batch-fill accounting).
    pub fn max_batch(&self) -> usize {
        self.cfg.policy.max_batch
    }

    /// The DNN shard count the pipeline actually *started with*: the
    /// fixed pool size, or — under the autoscaler — the configured
    /// `dnn_shards` clamped into `[min_shards, max_shards]`, exactly
    /// as `new()` clamps the initial live count. (It used to return
    /// the raw configured value, which with autoscaling enabled could
    /// name a shard count that never existed.)
    pub fn dnn_shards(&self) -> usize {
        let n = self.cfg.dnn_shards.max(1);
        match &self.cfg.autoscale {
            Some(a) => {
                let a = a.normalized();
                n.clamp(a.min_shards, a.max_shards)
            }
            None => n,
        }
    }

    /// DNN shards live right now: equals `dnn_shards()` for a fixed
    /// pool (until a replica dies), varies between the autoscale
    /// bounds under the controller. 0 once the pipeline is torn down.
    /// On a tiered pipeline this counts the *fast* pool; see
    /// `live_hq_shards`.
    pub fn live_dnn_shards(&self) -> usize {
        self.host.as_ref().map_or(0, |h| h.queues.live_count())
    }

    /// Hq-tier DNN shards live right now; 0 on a single-tier pipeline
    /// or once the pipeline is torn down.
    pub fn live_hq_shards(&self) -> usize {
        self.hq_host.as_ref().map_or(0, |h| h.queues.live_count())
    }

    /// The fast/hq model pair this pipeline serves, when
    /// `escalate_margin` armed tiered serving.
    pub fn tier_set(&self) -> Option<&TierSet> {
        self.tiers.as_ref()
    }

    /// CTC decode workers live right now: the configured
    /// `decode_threads` until the controller (with
    /// `AutoscaleConfig::scale_decode`) resizes the pool. 0 once the
    /// pipeline is torn down.
    pub fn live_decode_workers(&self) -> usize {
        self.decode_pool.as_ref().map_or(0, |p| p.live_count())
    }

    /// Vote workers live right now: the configured `vote_threads`
    /// until the controller (with `AutoscaleConfig::scale_vote`)
    /// resizes the pool. 0 once the pipeline is torn down.
    pub fn live_vote_workers(&self) -> usize {
        self.collector.as_ref().map_or(0, |c| c.live_vote_workers())
    }

    /// Analysis workers live right now: the configured
    /// `analysis_threads` until the controller (with
    /// `AutoscaleConfig::scale_analysis`) resizes the pool. 0 when
    /// the stage is off or once the pipeline is torn down.
    pub fn live_analysis_workers(&self) -> usize {
        self.analysis_pool.as_ref().map_or(0, |p| p.live_count())
    }

    /// The streaming analysis state, when
    /// `CoordinatorConfig::analysis_threads` armed the stage. Clone
    /// the `Arc` BEFORE `finish()` (which consumes the coordinator)
    /// to query the polished consensus after the drain:
    /// `finish()` returns only after the analysis workers have folded
    /// every voted read in, so `consensus(0)` is complete then.
    pub fn analysis_state(&self) -> Option<Arc<AnalysisState>> {
        self.analysis.clone()
    }

    /// Reads submitted but not yet emitted.
    pub fn in_flight(&self) -> usize {
        self.registry.in_flight()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::pore::PoreModel;
    use crate::genome::synth::{RunSpec, SequencingRun};

    fn no_artifacts_dir() -> String {
        std::env::temp_dir()
            .join("helix_server_unit_no_artifacts")
            .join("nonexistent")
            .to_str().unwrap().to_string()
    }

    /// Regression for the submit() counter drift: `reads_in`/`windows`
    /// used to be bumped before any window was delivered, so a submit
    /// against a dead pipeline (mid-run DNN failure) kept inflating
    /// both counters with work that never entered the pipeline.
    #[test]
    fn dead_pipeline_submit_counts_nothing() {
        let pm = PoreModel::synthetic(7);
        let run = SequencingRun::simulate(&pm, RunSpec {
            genome_len: 600,
            coverage: 2,
            seed: 9,
            ..Default::default()
        });
        assert!(run.reads.len() >= 2, "need at least two reads");
        let mut coord = Coordinator::new(CoordinatorConfig {
            artifacts_dir: no_artifacts_dir(),
            ..Default::default()
        }).unwrap();
        let m = coord.metrics.clone();
        // kill every shard queue: the dispatcher's next send fails,
        // it exits, and the window receiver drops — the same state a
        // total mid-run DNN failure leaves behind
        coord.host.as_ref().unwrap().queues.close_all();
        // feed probes until the dead dispatcher is observable from
        // submit() (a probe that delivers no window)
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let before = m.windows.load(Ordering::Relaxed);
            coord.submit(&run.reads[0]);
            if m.windows.load(Ordering::Relaxed) == before {
                break;
            }
            assert!(Instant::now() < deadline,
                    "dispatcher never observed the closed shard queues");
            std::thread::sleep(Duration::from_millis(2));
        }
        // THE regression assertions: a submit against the dead
        // pipeline must not move reads_in/windows, and must not leave
        // a registration stuck in flight
        let reads_before = m.reads_in.load(Ordering::Relaxed);
        let windows_before = m.windows.load(Ordering::Relaxed);
        let in_flight_before = coord.in_flight();
        coord.submit(&run.reads[1]);
        assert_eq!(m.reads_in.load(Ordering::Relaxed), reads_before,
                   "undelivered read must not count as read in");
        assert_eq!(m.windows.load(Ordering::Relaxed), windows_before,
                   "undelivered windows must not count");
        assert_eq!(coord.in_flight(), in_flight_before,
                   "undelivered read must be unregistered");
    }

    /// A healthy pipeline still counts every submitted read and all of
    /// its windows (the counter fix must not change the happy path).
    #[test]
    fn healthy_submit_counts_all_windows() {
        let pm = PoreModel::synthetic(7);
        let run = SequencingRun::simulate(&pm, RunSpec {
            genome_len: 500,
            coverage: 1,
            seed: 17,
            ..Default::default()
        });
        let mut coord = Coordinator::new(CoordinatorConfig {
            artifacts_dir: no_artifacts_dir(),
            ..Default::default()
        }).unwrap();
        let m = coord.metrics.clone();
        let mut expected_windows = 0u64;
        for r in &run.reads {
            let ws = windows_from_read(r, coord.window, coord.cfg.hop);
            expected_windows += ws.len() as u64;
            coord.submit(r);
        }
        assert_eq!(m.reads_in.load(Ordering::Relaxed),
                   run.reads.len() as u64);
        assert_eq!(m.windows.load(Ordering::Relaxed), expected_windows);
        coord.finish().unwrap();
    }

    /// A tiered pipeline opens both shard pools and a margin of zero
    /// never escalates (margins are non-negative), so the run drains
    /// cleanly with every window decided at the fast tier.
    #[test]
    fn tiered_pipeline_opens_and_zero_margin_never_escalates() {
        let pm = PoreModel::synthetic(7);
        let run = SequencingRun::simulate(&pm, RunSpec {
            genome_len: 500,
            coverage: 1,
            seed: 21,
            ..Default::default()
        });
        let mut coord = Coordinator::new(CoordinatorConfig {
            artifacts_dir: no_artifacts_dir(),
            escalate_margin: Some(0.0),
            ..Default::default()
        }).unwrap();
        let t = coord.tier_set().expect("margin arms tiering").clone();
        assert!(t.fast_bits < t.hq_bits);
        assert_eq!(t.hq_bits, 32);
        assert_eq!(coord.live_hq_shards(), 1);
        let m = coord.metrics.clone();
        for r in &run.reads {
            coord.submit(r);
        }
        let out = coord.finish().unwrap();
        assert_eq!(out.len(), run.reads.len());
        assert_eq!(m.escalations.load(Ordering::Relaxed), 0,
                   "zero margin must never escalate");
        assert!(m.fast_decided.load(Ordering::Relaxed) > 0,
                "every window was decided at the fast tier");
    }
}
