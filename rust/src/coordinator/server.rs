//! The coordinator proper: read router -> window batcher -> sharded DNN
//! executor pool (each shard thread owns its own `runtime::Backend`
//! replica: the native quantized executor by default, PJRT under the
//! `xla` feature) -> CTC decode pool (per-worker queues fed
//! round-robin) -> collector router -> vote worker pool -> output queue.
//!
//! Every interior stage boundary is a bounded channel (`util::bounded`),
//! so a slow stage backpressures its producer all the way up to
//! `submit()` instead of queues growing with run size; the output queue
//! alone is uncapped (see README). Each `CalledRead` is emitted the
//! moment its last window decodes (`try_recv`/`recv_timeout`);
//! `finish()` is a thin drain-the-rest shim for batch callers. See
//! `coordinator/README.md` for the stage/queue map.
//!
//! The DNN stage fans out over `CoordinatorConfig::dnn_shards` backend
//! replicas: the batcher dispatches each finished batch to the
//! least-loaded shard queue, and because every replica computes
//! identical `LogProbs` for a given window (the native weights are
//! deterministic; windows never see their batch neighbours), the
//! called result set is byte-identical for any shard count (mid-run
//! emission order remains completion order, as with one shard).

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::basecall::ctc::{beam_search, LogProbs};
use crate::genome::dataset::windows_from_read;
use crate::genome::synth::Read;
use crate::runtime::{Backend, BackendKind, NativeBackend};
use crate::util::bounded::{bounded, send_least_loaded, send_round_robin,
                           Receiver, Sender};

use super::batcher::{Batcher, BatchPolicy};
use super::collector::{Collector, CollectorConfig, DecodedWindow,
                       ReadRegistry};
use super::metrics::Metrics;

/// Batches a shard can hold QUEUED ahead of its forward pass (the
/// executing batch has already been dequeued): one staged batch while
/// one executes — classic double buffering — keeps a replica busy
/// without parking a deep backlog of signal memory behind a slow shard
/// (the window queue is the intended buffering point — it
/// backpressures `submit()`).
const SHARD_QUEUE_DEPTH: usize = 1;

/// Everything the `Coordinator` needs to open a pipeline: model
/// selection, backend kind, stage widths, and queue bounds.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// model family to execute (e.g. "guppy").
    pub model: String,
    /// bit-width variant of the model (32 = the fp32-trained baseline).
    pub bits: u32,
    /// which inference backend the DNN stage opens (native by default;
    /// `xla` requires the cargo feature).
    pub backend: BackendKind,
    /// window hop in samples; window length comes from the artifact meta.
    pub hop: usize,
    /// CTC beam width used by the decode pool.
    pub beam_width: usize,
    /// number of DNN executor shards. Each shard owns an independent
    /// `Backend` replica (in-memory clone for native, `open_shard` for
    /// non-`Send` backends) fed through its own bounded batch queue by
    /// least-loaded dispatch; 1 reproduces the single-owner layout.
    /// The called result set is byte-identical for any value.
    pub dnn_shards: usize,
    /// CTC decode worker count.
    pub decode_threads: usize,
    /// vote/splice worker count.
    pub vote_threads: usize,
    /// bound on in-flight windows per queue: `submit()` blocks once the
    /// window queue holds this many undecoded windows (backpressure).
    pub queue_cap: usize,
    /// size-or-deadline batching policy for the DNN stage.
    pub policy: BatchPolicy,
    /// artifact directory (meta.json + weights; the native backend
    /// falls back to its builtin model when absent).
    pub artifacts_dir: String,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            backend: BackendKind::default(),
            hop: 100,
            beam_width: 10,
            dnn_shards: 1,
            decode_threads: 2,
            vote_threads: 2,
            queue_cap: 256,
            policy: BatchPolicy::default(),
            artifacts_dir: crate::runtime::meta::default_artifacts_dir(),
        }
    }
}

impl CoordinatorConfig {
    /// Shard count selected by `HELIX_SHARDS` (default 1; zero or an
    /// unparsable value also fall back to 1).
    pub fn shards_from_env() -> usize {
        std::env::var("HELIX_SHARDS").ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1)
    }
}

/// A fully base-called read: per-window decodes voted into a consensus and
/// spliced into one sequence.
#[derive(Clone, Debug)]
pub struct CalledRead {
    /// id of the submitted `Read` this call answers.
    pub read_id: usize,
    /// consensus base sequence (values 0–3, one per called base).
    pub seq: Vec<u8>,
    /// per-window decoded fragments (pre-splice), for accuracy accounting.
    pub window_decodes: Vec<Vec<u8>>,
}

struct WindowJob {
    read_id: usize,
    window_idx: usize,
    signal: Vec<f32>,
}

/// One batch en route from the batcher to a DNN shard: the window keys
/// and their signals, split so a shard can hand the signal block to the
/// backend without re-walking the jobs.
struct ShardBatch {
    keys: Vec<(usize, usize)>,
    sigs: Vec<Vec<f32>>,
    full: bool,
}

struct DecodeJob {
    read_id: usize,
    window_idx: usize,
    lp: LogProbs,
}

/// Staged streaming pipeline coordinator. Construct, `submit` reads, pull
/// completed reads mid-run with `try_recv`/`recv_timeout`, then `finish`
/// to drain the rest.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    window: usize,
    registry: Arc<ReadRegistry>,
    tx_windows: Option<Sender<WindowJob>>,
    batcher_thread: Option<JoinHandle<()>>,
    shard_threads: Vec<JoinHandle<Result<()>>>,
    decode_threads: Vec<JoinHandle<()>>,
    collector: Option<Collector>,
    /// live pipeline telemetry (readable mid-run; see `Metrics`).
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Open the full pipeline: probe the artifact metadata, spawn the
    /// batcher, the DNN shard pool, the decode pool, and the collector,
    /// and block until every shard's backend has opened and warmed (so
    /// compile/load failures surface here, not mid-run).
    pub fn new(cfg: CoordinatorConfig) -> Result<Coordinator> {
        // validate metadata on the caller thread for early errors
        let meta = cfg.backend.probe_meta(&cfg.artifacts_dir)?;
        let window = meta.window;
        let batches = meta.batches(&cfg.model, cfg.bits);
        anyhow::ensure!(!batches.is_empty(),
                        "no artifacts for {}/{}b", cfg.model, cfg.bits);
        let n_shards = cfg.dnn_shards.max(1);
        let metrics = Arc::new(Metrics::with_shards(n_shards));
        let registry = Arc::new(ReadRegistry::default());

        let cap = cfg.queue_cap.max(1);
        let (tx_windows, rx_windows) = bounded::<WindowJob>(cap);
        let (tx_decoded, rx_decoded) = bounded::<DecodedWindow>(cap);
        // every shard reports open+warm exactly once
        let (tx_ready, rx_ready) = bounded::<Result<()>>(n_shards);

        // per-worker decode queues, fed round-robin by the DNN shards (no
        // shared Mutex<Receiver> hot spot).
        let n_dec = cfg.decode_threads.max(1);
        let dec_cap = (cap / n_dec).max(8);
        let mut dec_txs: Vec<Sender<DecodeJob>> = Vec::with_capacity(n_dec);
        let mut dec_rxs: Vec<Receiver<DecodeJob>> =
            Vec::with_capacity(n_dec);
        for _ in 0..n_dec {
            let (tx, rx) = bounded::<DecodeJob>(dec_cap);
            dec_txs.push(tx);
            dec_rxs.push(rx);
        }

        // per-shard batch queues, fed by least-loaded dispatch
        let mut shard_txs: Vec<Sender<ShardBatch>> =
            Vec::with_capacity(n_shards);
        let mut shard_rxs: Vec<Receiver<ShardBatch>> =
            Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let (tx, rx) = bounded::<ShardBatch>(SHARD_QUEUE_DEPTH);
            shard_txs.push(tx);
            shard_rxs.push(rx);
        }

        // batcher: drains the window queue with the size-or-deadline
        // policy and hands each finished batch to the shallowest shard
        // queue. It owns the only shard senders, so when it exits the
        // shard pool drains out.
        let batcher_thread = {
            let policy = cfg.policy;
            std::thread::spawn(move || {
                let mut batcher = Batcher::new(rx_windows, policy);
                let mut rr = 0usize;
                while let Some(batch) = batcher.next_batch() {
                    let n_items = batch.items.len();
                    // move the signals out of the jobs — no per-window
                    // clone on this hot path
                    let mut keys = Vec::with_capacity(n_items);
                    let mut sigs = Vec::with_capacity(n_items);
                    for j in batch.items {
                        keys.push((j.read_id, j.window_idx));
                        sigs.push(j.signal);
                    }
                    if !send_least_loaded(&shard_txs, &mut rr, ShardBatch {
                        keys,
                        sigs,
                        full: batch.full,
                    }) {
                        // every shard is gone (all replicas failed):
                        // stop pulling windows so submit() sees the
                        // disconnect instead of feeding a dead stage
                        break;
                    }
                }
            })
        };

        // Native replicas are plain `Send` data: open ONE backend on
        // the caller thread and stamp out in-memory clones
        // (`NativeBackend::clone_for_shard`), so N shards cost one
        // artifact load + quantization instead of N. Non-`Send`
        // backends (the PJRT client) get `None` here and are
        // constructed inside their shard thread via `open_shard`.
        let mut prebuilt: Vec<Option<NativeBackend>> =
            (0..n_shards).map(|_| None).collect();
        if cfg.backend == BackendKind::Native {
            let first = NativeBackend::open(&cfg.artifacts_dir)?;
            for slot in prebuilt.iter_mut().skip(1) {
                *slot = Some(first.clone_for_shard());
            }
            prebuilt[0] = Some(first);
        }

        // DNN shard pool: each shard thread owns its own backend
        // replica (moved in when prebuilt, constructed in-thread
        // otherwise). Shards hold clones of the decode senders; when
        // the last shard exits they drop and the decode pool drains
        // out.
        let mut shard_threads = Vec::with_capacity(n_shards);
        for (shard_id, rx_batch) in shard_rxs.into_iter().enumerate() {
            let m = metrics.clone();
            let c = cfg.clone();
            let dec = dec_txs.clone();
            let ready = tx_ready.clone();
            let pre = prebuilt[shard_id].take();
            shard_threads.push(std::thread::spawn(
                move || -> Result<()> {
                // open + warm (compile cache / weight quantization) so
                // failures surface through the ready channel at init,
                // not mid-run
                let opened = match pre {
                    Some(replica) => {
                        Ok(Box::new(replica) as Box<dyn Backend>)
                    }
                    None => c.backend
                        .open_shard(&c.artifacts_dir, shard_id),
                }
                    .and_then(|mut b| {
                        b.warm(&c.model, c.bits).map(|()| b)
                    });
                let mut backend = match opened {
                    Ok(b) => {
                        let _ = ready.send(Ok(()));
                        drop(ready); // init handshake complete
                        b
                    }
                    Err(err) => {
                        let _ = ready.send(Err(err));
                        return Ok(());
                    }
                };
                // spread the decode round-robin start points so shards
                // do not gang up on decode worker 0
                let mut rr = shard_id;
                let stats = &m.shards[shard_id];
                while let Ok(batch) = rx_batch.recv() {
                    let t0 = Instant::now();
                    let lps = backend.run_windows(&c.model, c.bits,
                                                  &batch.sigs)?;
                    let busy = t0.elapsed().as_micros() as u64;
                    let n_items = batch.keys.len();
                    m.add(&m.batches, 1);
                    m.add(&m.batch_items, n_items as u64);
                    if batch.full {
                        m.add(&m.full_batches, 1);
                    }
                    m.add(&m.dnn_micros, busy);
                    m.add(&stats.batches, 1);
                    m.add(&stats.windows, n_items as u64);
                    m.add(&stats.busy_micros, busy);
                    for ((read_id, window_idx), lp) in
                        batch.keys.into_iter().zip(lps)
                    {
                        // skip-over-backlogged round-robin; if every
                        // decode queue is gone the pipeline has
                        // collapsed downstream — stop burning
                        // inference on it
                        if !send_round_robin(&dec, &mut rr, DecodeJob {
                            read_id,
                            window_idx,
                            lp,
                        }) {
                            anyhow::bail!("decode stage disconnected \
                                           mid-run (downstream failure)");
                        }
                    }
                }
                Ok(())
            }));
        }
        // the shards hold the only decode senders and ready senders now
        drop(dec_txs);
        drop(tx_ready);

        // decode pool: one private queue per worker.
        let mut decode_threads = Vec::with_capacity(n_dec);
        for rx in dec_rxs {
            let tx = tx_decoded.clone();
            let m = metrics.clone();
            let beam = cfg.beam_width;
            decode_threads.push(std::thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    let t0 = Instant::now();
                    let seq = beam_search(&job.lp, beam);
                    m.add(&m.decode_micros,
                          t0.elapsed().as_micros() as u64);
                    if tx.send(DecodedWindow {
                        read_id: job.read_id,
                        window_idx: job.window_idx,
                        seq,
                    }).is_err() {
                        break;
                    }
                }
            }));
        }
        drop(tx_decoded); // decode workers hold the only senders

        // collector: assembles out-of-order windows, votes + splices in
        // its own worker pool, emits CalledReads eagerly.
        let collector = Collector::spawn(
            registry.clone(),
            rx_decoded,
            metrics.clone(),
            CollectorConfig {
                vote_threads: cfg.vote_threads.max(1),
                queue_cap: cap,
            },
        );

        // wait for every shard to finish opening + warming (or fail
        // fast: the first shard error aborts construction, and the
        // channel cascade tears the other stages down as this frame's
        // senders drop)
        for _ in 0..n_shards {
            rx_ready.recv()
                .map_err(|_| anyhow::anyhow!(
                    "a dnn shard thread died during init"))??;
        }

        Ok(Coordinator {
            cfg,
            window,
            registry,
            tx_windows: Some(tx_windows),
            batcher_thread: Some(batcher_thread),
            shard_threads,
            decode_threads,
            collector: Some(collector),
            metrics,
        })
    }

    /// Split a read into windows and enqueue them. Blocks once
    /// `queue_cap` windows are in flight ahead of the DNN stage
    /// (backpressure), so raw-signal memory stays bounded for
    /// arbitrarily long runs. Completed reads accumulate on the
    /// (unbounded) output queue until taken; interleave `drain_ready()`
    /// in long submission loops to keep that flat too.
    pub fn submit(&mut self, read: &Read) {
        let ws = windows_from_read(read, self.window, self.cfg.hop);
        self.metrics.add(&self.metrics.reads_in, 1);
        self.metrics.add(&self.metrics.windows, ws.len() as u64);
        if ws.is_empty() {
            return; // shorter than one window: nothing to call
        }
        // register BEFORE the first window enters the pipeline so the
        // collector always knows the expected count
        self.registry.register(read.id, ws.len());
        if let Some(tx) = &self.tx_windows {
            for (i, w) in ws.into_iter().enumerate() {
                if tx.send(WindowJob {
                    read_id: read.id,
                    window_idx: i,
                    signal: w.signal,
                }).is_err() {
                    // DNN stage already exited (mid-run failure). If no
                    // window of this read got in, drop the registration
                    // so in_flight() doesn't count it forever.
                    if i == 0 {
                        self.registry.unregister(read.id);
                    }
                    return;
                }
            }
        }
    }

    /// Non-blocking: the next read whose last window has decoded, if any.
    /// Reads stream out mid-run, in completion order (not id order).
    pub fn try_recv(&self) -> Option<CalledRead> {
        self.collector.as_ref()?.try_recv()
    }

    /// Block up to `timeout` for the next completed read.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<CalledRead> {
        self.collector.as_ref()?.recv_timeout(timeout)
    }

    /// Every read that has completed so far, without blocking. Calling
    /// this inside long submission loops keeps output memory flat; batch
    /// callers may skip it (the output queue is unbounded, so results
    /// simply accumulate there until `finish()`).
    pub fn drain_ready(&self) -> Vec<CalledRead> {
        let mut out = Vec::new();
        while let Some(r) = self.try_recv() {
            out.push(r);
        }
        out
    }

    /// Close the intake and deterministically drain the pipeline: blocks
    /// until every stage disconnects, then returns the remaining called
    /// reads sorted by id. Reads already taken via `try_recv`/
    /// `recv_timeout` are not returned again.
    pub fn finish(mut self) -> Result<Vec<CalledRead>> {
        drop(self.tx_windows.take());
        // drain first: recv-until-disconnect is the shutdown barrier —
        // it returns exactly when the last stage has emptied, after
        // which every join below is immediate.
        let collected = match self.collector.take() {
            Some(c) => c.finish(),
            None => Ok(Vec::new()),
        };
        let mut err = None;
        if let Some(h) = self.batcher_thread.take() {
            if h.join().is_err() {
                err = Some(anyhow::anyhow!("batcher thread panicked"));
            }
        }
        for h in self.shard_threads.drain(..) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if err.is_none() {
                        err = Some(e);
                    }
                }
                Err(_) => {
                    if err.is_none() {
                        err = Some(anyhow::anyhow!(
                            "dnn shard thread panicked"));
                    }
                }
            }
        }
        for h in self.decode_threads.drain(..) {
            if h.join().is_err() && err.is_none() {
                err = Some(anyhow::anyhow!("decode worker panicked"));
            }
        }
        // a collector panic is the root cause of any knock-on DNN
        // "decode stage disconnected" error, so report it first
        let mut out = match (collected, err) {
            (Err(ce), _) => return Err(ce),
            (Ok(_), Some(e)) => return Err(e),
            (Ok(v), None) => v,
        };
        out.sort_by_key(|r| r.read_id);
        Ok(out)
    }

    /// The batching policy's size trigger (for batch-fill accounting).
    pub fn max_batch(&self) -> usize {
        self.cfg.policy.max_batch
    }

    /// Number of DNN executor shards this pipeline is running.
    pub fn dnn_shards(&self) -> usize {
        self.cfg.dnn_shards.max(1)
    }

    /// Reads submitted but not yet emitted.
    pub fn in_flight(&self) -> usize {
        self.registry.in_flight()
    }
}
