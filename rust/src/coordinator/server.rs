//! The coordinator proper: read router -> window batcher -> DNN executor
//! (a `runtime::Backend` owned by a single thread: the native quantized
//! executor by default, PJRT under the `xla` feature) -> CTC decode pool
//! (per-worker queues fed round-robin) -> collector router -> vote
//! worker pool -> output queue.
//!
//! Every interior stage boundary is a bounded channel (`util::bounded`),
//! so a slow stage backpressures its producer all the way up to
//! `submit()` instead of queues growing with run size; the output queue
//! alone is uncapped (see README). Each `CalledRead` is emitted the
//! moment its last window decodes (`try_recv`/`recv_timeout`);
//! `finish()` is a thin drain-the-rest shim for batch callers. See
//! `coordinator/README.md` for the stage/queue map.

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::basecall::ctc::{beam_search, LogProbs};
use crate::genome::dataset::windows_from_read;
use crate::genome::synth::Read;
use crate::runtime::{Backend, BackendKind};
use crate::util::bounded::{bounded, send_round_robin, Receiver, Sender};

use super::batcher::{Batcher, BatchPolicy};
use super::collector::{Collector, CollectorConfig, DecodedWindow,
                       ReadRegistry};
use super::metrics::Metrics;

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub model: String,
    pub bits: u32,
    /// which inference backend the DNN stage opens (native by default;
    /// `xla` requires the cargo feature).
    pub backend: BackendKind,
    /// window hop in samples; window length comes from the artifact meta.
    pub hop: usize,
    pub beam_width: usize,
    pub decode_threads: usize,
    pub vote_threads: usize,
    /// bound on in-flight windows per queue: `submit()` blocks once the
    /// window queue holds this many undecoded windows (backpressure).
    pub queue_cap: usize,
    pub policy: BatchPolicy,
    pub artifacts_dir: String,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            backend: BackendKind::default(),
            hop: 100,
            beam_width: 10,
            decode_threads: 2,
            vote_threads: 2,
            queue_cap: 256,
            policy: BatchPolicy::default(),
            artifacts_dir: crate::runtime::meta::default_artifacts_dir(),
        }
    }
}

/// A fully base-called read: per-window decodes voted into a consensus and
/// spliced into one sequence.
#[derive(Clone, Debug)]
pub struct CalledRead {
    pub read_id: usize,
    pub seq: Vec<u8>,
    /// per-window decoded fragments (pre-splice), for accuracy accounting.
    pub window_decodes: Vec<Vec<u8>>,
}

struct WindowJob {
    read_id: usize,
    window_idx: usize,
    signal: Vec<f32>,
}

struct DecodeJob {
    read_id: usize,
    window_idx: usize,
    lp: LogProbs,
}

/// Staged streaming pipeline coordinator. Construct, `submit` reads, pull
/// completed reads mid-run with `try_recv`/`recv_timeout`, then `finish`
/// to drain the rest.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    window: usize,
    registry: Arc<ReadRegistry>,
    tx_windows: Option<Sender<WindowJob>>,
    dnn_thread: Option<JoinHandle<Result<()>>>,
    decode_threads: Vec<JoinHandle<()>>,
    collector: Option<Collector>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Result<Coordinator> {
        // validate metadata on the caller thread for early errors
        let meta = cfg.backend.probe_meta(&cfg.artifacts_dir)?;
        let window = meta.window;
        let batches = meta.batches(&cfg.model, cfg.bits);
        anyhow::ensure!(!batches.is_empty(),
                        "no artifacts for {}/{}b", cfg.model, cfg.bits);
        let metrics = Arc::new(Metrics::default());
        let registry = Arc::new(ReadRegistry::default());

        let cap = cfg.queue_cap.max(1);
        let (tx_windows, rx_windows) = bounded::<WindowJob>(cap);
        let (tx_decoded, rx_decoded) = bounded::<DecodedWindow>(cap);
        let (tx_ready, rx_ready) = bounded::<Result<()>>(1);

        // per-worker decode queues, fed round-robin by the DNN stage (no
        // shared Mutex<Receiver> hot spot).
        let n_dec = cfg.decode_threads.max(1);
        let dec_cap = (cap / n_dec).max(8);
        let mut dec_txs: Vec<Sender<DecodeJob>> = Vec::with_capacity(n_dec);
        let mut dec_rxs: Vec<Receiver<DecodeJob>> =
            Vec::with_capacity(n_dec);
        for _ in 0..n_dec {
            let (tx, rx) = bounded::<DecodeJob>(dec_cap);
            dec_txs.push(tx);
            dec_rxs.push(rx);
        }

        // DNN executor: backends may not be Send (the PJRT client is
        // not), so the backend is both constructed and used inside its
        // owner thread. It owns the decode senders; when it exits they
        // drop and the pool drains out.
        let m = metrics.clone();
        let c = cfg.clone();
        let dnn_thread = std::thread::spawn(move || -> Result<()> {
            // open + warm (compile cache / weight quantization) so
            // failures surface through tx_ready at init, not mid-run
            let mut backend = match c.backend.open(&c.artifacts_dir)
                .and_then(|mut b| b.warm(&c.model, c.bits).map(|()| b))
            {
                Ok(b) => {
                    let _ = tx_ready.send(Ok(()));
                    b
                }
                Err(err) => {
                    let _ = tx_ready.send(Err(err));
                    return Ok(());
                }
            };
            let mut batcher = Batcher::new(rx_windows, c.policy);
            let mut rr = 0usize;
            while let Some(batch) = batcher.next_batch() {
                let t0 = Instant::now();
                let n_items = batch.items.len();
                // move the signals out of the jobs — no per-window clone
                let mut keys = Vec::with_capacity(n_items);
                let mut sigs = Vec::with_capacity(n_items);
                for j in batch.items {
                    keys.push((j.read_id, j.window_idx));
                    sigs.push(j.signal);
                }
                let lps = backend.run_windows(&c.model, c.bits, &sigs)?;
                m.add(&m.batches, 1);
                m.add(&m.batch_items, n_items as u64);
                if batch.full {
                    m.add(&m.full_batches, 1);
                }
                m.add(&m.dnn_micros, t0.elapsed().as_micros() as u64);
                for ((read_id, window_idx), lp) in
                    keys.into_iter().zip(lps)
                {
                    // skip-over-backlogged round-robin; if every decode
                    // queue is gone the pipeline has collapsed
                    // downstream — stop burning inference on it
                    if !send_round_robin(&dec_txs, &mut rr, DecodeJob {
                        read_id,
                        window_idx,
                        lp,
                    }) {
                        anyhow::bail!("decode stage disconnected mid-run \
                                       (downstream failure)");
                    }
                }
            }
            Ok(())
        });

        // decode pool: one private queue per worker.
        let mut decode_threads = Vec::with_capacity(n_dec);
        for rx in dec_rxs {
            let tx = tx_decoded.clone();
            let m = metrics.clone();
            let beam = cfg.beam_width;
            decode_threads.push(std::thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    let t0 = Instant::now();
                    let seq = beam_search(&job.lp, beam);
                    m.add(&m.decode_micros,
                          t0.elapsed().as_micros() as u64);
                    if tx.send(DecodedWindow {
                        read_id: job.read_id,
                        window_idx: job.window_idx,
                        seq,
                    }).is_err() {
                        break;
                    }
                }
            }));
        }
        drop(tx_decoded); // decode workers hold the only senders

        // collector: assembles out-of-order windows, votes + splices in
        // its own worker pool, emits CalledReads eagerly.
        let collector = Collector::spawn(
            registry.clone(),
            rx_decoded,
            metrics.clone(),
            CollectorConfig {
                vote_threads: cfg.vote_threads.max(1),
                queue_cap: cap,
            },
        );

        // wait for the engine thread to finish compiling (or fail fast)
        rx_ready.recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during init"))??;

        Ok(Coordinator {
            cfg,
            window,
            registry,
            tx_windows: Some(tx_windows),
            dnn_thread: Some(dnn_thread),
            decode_threads,
            collector: Some(collector),
            metrics,
        })
    }

    /// Split a read into windows and enqueue them. Blocks once
    /// `queue_cap` windows are in flight ahead of the DNN stage
    /// (backpressure), so raw-signal memory stays bounded for
    /// arbitrarily long runs. Completed reads accumulate on the
    /// (unbounded) output queue until taken; interleave `drain_ready()`
    /// in long submission loops to keep that flat too.
    pub fn submit(&mut self, read: &Read) {
        let ws = windows_from_read(read, self.window, self.cfg.hop);
        self.metrics.add(&self.metrics.reads_in, 1);
        self.metrics.add(&self.metrics.windows, ws.len() as u64);
        if ws.is_empty() {
            return; // shorter than one window: nothing to call
        }
        // register BEFORE the first window enters the pipeline so the
        // collector always knows the expected count
        self.registry.register(read.id, ws.len());
        if let Some(tx) = &self.tx_windows {
            for (i, w) in ws.into_iter().enumerate() {
                if tx.send(WindowJob {
                    read_id: read.id,
                    window_idx: i,
                    signal: w.signal,
                }).is_err() {
                    // DNN stage already exited (mid-run failure). If no
                    // window of this read got in, drop the registration
                    // so in_flight() doesn't count it forever.
                    if i == 0 {
                        self.registry.unregister(read.id);
                    }
                    return;
                }
            }
        }
    }

    /// Non-blocking: the next read whose last window has decoded, if any.
    /// Reads stream out mid-run, in completion order (not id order).
    pub fn try_recv(&self) -> Option<CalledRead> {
        self.collector.as_ref()?.try_recv()
    }

    /// Block up to `timeout` for the next completed read.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<CalledRead> {
        self.collector.as_ref()?.recv_timeout(timeout)
    }

    /// Every read that has completed so far, without blocking. Calling
    /// this inside long submission loops keeps output memory flat; batch
    /// callers may skip it (the output queue is unbounded, so results
    /// simply accumulate there until `finish()`).
    pub fn drain_ready(&self) -> Vec<CalledRead> {
        let mut out = Vec::new();
        while let Some(r) = self.try_recv() {
            out.push(r);
        }
        out
    }

    /// Close the intake and deterministically drain the pipeline: blocks
    /// until every stage disconnects, then returns the remaining called
    /// reads sorted by id. Reads already taken via `try_recv`/
    /// `recv_timeout` are not returned again.
    pub fn finish(mut self) -> Result<Vec<CalledRead>> {
        drop(self.tx_windows.take());
        // drain first: recv-until-disconnect is the shutdown barrier —
        // it returns exactly when the last stage has emptied, after
        // which every join below is immediate.
        let collected = match self.collector.take() {
            Some(c) => c.finish(),
            None => Ok(Vec::new()),
        };
        let mut err = None;
        if let Some(h) = self.dnn_thread.take() {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => err = Some(e),
                Err(_) => {
                    err = Some(anyhow::anyhow!("dnn thread panicked"));
                }
            }
        }
        for h in self.decode_threads.drain(..) {
            if h.join().is_err() && err.is_none() {
                err = Some(anyhow::anyhow!("decode worker panicked"));
            }
        }
        // a collector panic is the root cause of any knock-on DNN
        // "decode stage disconnected" error, so report it first
        let mut out = match (collected, err) {
            (Err(ce), _) => return Err(ce),
            (Ok(_), Some(e)) => return Err(e),
            (Ok(v), None) => v,
        };
        out.sort_by_key(|r| r.read_id);
        Ok(out)
    }

    pub fn max_batch(&self) -> usize {
        self.cfg.policy.max_batch
    }

    /// Reads submitted but not yet emitted.
    pub fn in_flight(&self) -> usize {
        self.registry.in_flight()
    }
}
