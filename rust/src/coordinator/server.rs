//! The coordinator proper: read router -> window batcher -> sharded DNN
//! executor pool (each shard thread owns its own `runtime::Backend`
//! replica: the native quantized executor by default, PJRT under the
//! `xla` feature) -> CTC decode pool (per-worker queues fed
//! round-robin) -> collector router -> vote worker pool -> output queue.
//!
//! Every interior stage boundary is a bounded channel (`util::bounded`),
//! so a slow stage backpressures its producer all the way up to
//! `submit()` instead of queues growing with run size; the output queue
//! alone is uncapped (see README). Each `CalledRead` is emitted the
//! moment its last window decodes (`try_recv`/`recv_timeout`);
//! `finish()` is a thin drain-the-rest shim for batch callers. See
//! `coordinator/README.md` for the stage/queue map.
//!
//! The DNN stage fans out over a pool of backend replicas reached
//! through a [`QueueSet`] of per-shard queues. Dispatch is
//! *batch-size-aware*: full (size-triggered) batches go to the
//! least-loaded live shard, small deadline-triggered tail batches go to
//! the *busiest* live shard so the heavy batches stay unsplit and idle
//! replicas stay genuinely idle. With `CoordinatorConfig::autoscale`
//! set, a controller thread (`coordinator::autoscale`) resizes the live
//! pool between `min_shards` and `max_shards` from observed
//! utilization — spawning replicas through the [`ShardFactory`] and
//! retiring them by closing their queue so they drain out through the
//! same skip-dead dispatch a crashed replica exercises. Because every
//! replica computes identical `LogProbs` for a given window (windows
//! never see their batch neighbours), the called result set is
//! byte-identical for any shard count, fixed or adaptive (mid-run
//! emission order remains completion order, as with one shard).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::basecall::ctc::{beam_search, beam_search_pruned, BeamPrune,
                           LogProbs};
use crate::genome::dataset::windows_from_read;
use crate::genome::synth::Read;
use crate::runtime::{Backend, BackendKind, ShardFactory};
use crate::util::bounded::{bounded, Feeder, QueueSet, Receiver, Sender};

use super::autoscale::{self, AutoscaleConfig, StageControl, StagePool,
                       WorkerPool};
use super::batcher::{Batcher, BatchPolicy};
use super::collector::{Collector, CollectorConfig, DecodedWindow,
                       ReadRegistry};
use super::metrics::{Metrics, ScaleAction, StageId};

/// Batches a shard can hold QUEUED ahead of its forward pass (the
/// executing batch has already been dequeued): one staged batch while
/// one executes — classic double buffering — keeps a replica busy
/// without parking a deep backlog of signal memory behind a slow shard
/// (the window queue is the intended buffering point — it
/// backpressures `submit()`). Depth 1 is also what makes retirement
/// cheap: a closed queue drains at most one staged batch before the
/// shard thread sees the disconnect and exits.
const SHARD_QUEUE_DEPTH: usize = 1;

/// Everything the `Coordinator` needs to open a pipeline: model
/// selection, backend kind, stage widths, and queue bounds.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// model family to execute (e.g. "guppy").
    pub model: String,
    /// bit-width variant of the model (32 = the fp32-trained baseline).
    pub bits: u32,
    /// which inference backend the DNN stage opens (native by default;
    /// `xla` requires the cargo feature).
    pub backend: BackendKind,
    /// window hop in samples; window length comes from the artifact meta.
    pub hop: usize,
    /// CTC beam width used by the decode pool.
    pub beam_width: usize,
    /// number of DNN executor shards. Each shard owns an independent
    /// `Backend` replica (built by the [`ShardFactory`]: an in-memory
    /// clone for native, `open_shard` in-thread for non-`Send`
    /// backends) fed through its own bounded batch queue; 1 reproduces
    /// the single-owner layout. With `autoscale` set this is only the
    /// *initial* live count (clamped into `[min_shards, max_shards]`).
    /// The called result set is byte-identical for any value.
    pub dnn_shards: usize,
    /// CTC decode worker count.
    pub decode_threads: usize,
    /// vote/splice worker count.
    pub vote_threads: usize,
    /// bound on in-flight windows per queue: `submit()` blocks once the
    /// window queue holds this many undecoded windows (backpressure).
    pub queue_cap: usize,
    /// size-or-deadline batching policy for the DNN stage.
    pub policy: BatchPolicy,
    /// adaptive shard autoscaling: `None` (default) pins the pool at
    /// `dnn_shards` for the whole run; `Some(cfg)` starts a controller
    /// thread that resizes the live pool between `cfg.min_shards` and
    /// `cfg.max_shards` from observed utilization (see
    /// `coordinator::autoscale`). Scaling never changes called output.
    pub autoscale: Option<AutoscaleConfig>,
    /// artifact directory (meta.json + weights; the native backend
    /// falls back to its builtin model when absent).
    pub artifacts_dir: String,
    /// beam-search pruning thresholds for the decode pool. `None`
    /// (default) runs the exhaustive search — byte-identical to the
    /// pre-knob pipeline. `Some(BeamPrune::OFF)` also reproduces the
    /// exhaustive arithmetic exactly; finite thresholds trade decode
    /// work for a bounded heuristic (see `basecall::ctc::BeamPrune`).
    pub prune: Option<BeamPrune>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            backend: BackendKind::default(),
            hop: 100,
            beam_width: 10,
            dnn_shards: 1,
            decode_threads: 2,
            vote_threads: 2,
            queue_cap: 256,
            policy: BatchPolicy::default(),
            autoscale: None,
            artifacts_dir: crate::runtime::meta::default_artifacts_dir(),
            prune: None,
        }
    }
}

impl CoordinatorConfig {
    /// Shard count selected by `HELIX_SHARDS` (default 1; zero or an
    /// unparsable value also fall back to 1).
    pub fn shards_from_env() -> usize {
        std::env::var("HELIX_SHARDS").ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1)
    }
}

/// A fully base-called read: per-window decodes voted into a consensus and
/// spliced into one sequence.
#[derive(Clone, Debug)]
pub struct CalledRead {
    /// id of the submitted `Read` this call answers.
    pub read_id: usize,
    /// consensus base sequence (values 0–3, one per called base).
    pub seq: Vec<u8>,
    /// per-window decoded fragments (pre-splice), for accuracy accounting.
    pub window_decodes: Vec<Vec<u8>>,
}

struct WindowJob {
    read_id: usize,
    window_idx: usize,
    signal: Vec<f32>,
    /// stamped by `submit()` as the window enters the window queue, so
    /// the batcher's deadline clock (and `Batch::oldest_wait`) counts
    /// time spent queued behind backpressure, not just time since the
    /// batcher's first dequeue.
    enqueued_at: Instant,
}

/// One batch en route from the batcher to a DNN shard: the window keys
/// and their signals, split so a shard can hand the signal block to the
/// backend without re-walking the jobs.
struct ShardBatch {
    keys: Vec<(usize, usize)>,
    sigs: Vec<Vec<f32>>,
    full: bool,
}

struct DecodeJob {
    read_id: usize,
    window_idx: usize,
    lp: LogProbs,
}

/// Shard-pool state shared by everyone who touches the pool: the
/// batcher dispatches through `queues`, the autoscaler (when enabled)
/// adds and retires slots through the [`StagePool`] impl, and
/// `Coordinator::finish` drains `handles`. Shard threads hold only the
/// individual Arcs they need (factory, queue set, metrics) — never
/// this struct — so teardown has no reference cycles: once the
/// controller is joined and the coordinator drops its host Arc, the
/// host's window/decode senders drop and the stage-by-stage disconnect
/// cascade proceeds exactly as in the fixed-pool design.
struct ShardHost {
    factory: Arc<ShardFactory>,
    model: String,
    bits: u32,
    queues: Arc<QueueSet<ShardBatch>>,
    /// producer guard over the decode pool's queue set: every shard
    /// thread holds a clone, and the last holder's drop seals the set
    /// so the decode workers disconnect exactly when no shard remains
    /// (the host itself is dropped by `finish()` before the drain).
    dec: Feeder<DecodeJob>,
    metrics: Arc<Metrics>,
    handles: Mutex<Vec<JoinHandle<Result<()>>>>,
    window_tx: Sender<WindowJob>,
    window_cap: usize,
}

impl ShardHost {
    /// Spawn the shard thread that owns slot `slot`'s backend replica.
    /// The replica is opened + warmed *inside* the thread (it may not
    /// be `Send`). `ready` carries the outcome for init-time shards so
    /// `Coordinator::new` fails fast; autoscaled spawns pass `None` —
    /// on failure they retire *their own installation* of the slot
    /// (generation-checked, so a slow failing spawn can never close a
    /// successor that recycled the slot) and log a `SpawnFailed` scale
    /// event, degrading the pool instead of failing the run.
    fn launch(&self, slot: usize, generation: u64,
              rx: Receiver<ShardBatch>,
              ready: Option<Sender<Result<()>>>) {
        self.metrics.shards[slot]
            .mark_spawned(self.metrics.epoch_micros());
        let factory = self.factory.clone();
        let queues = self.queues.clone();
        let dec = self.dec.clone();
        let m = self.metrics.clone();
        let model = self.model.clone();
        let bits = self.bits;
        let handle = std::thread::spawn(move || -> Result<()> {
            let opened = factory.replica(slot)
                .and_then(|mut b| b.warm(&model, bits).map(|()| b));
            let mut backend = match opened {
                Ok(b) => {
                    if let Some(tx) = &ready {
                        let _ = tx.send(Ok(()));
                    }
                    b
                }
                Err(err) => {
                    match ready {
                        Some(tx) => {
                            let _ = tx.send(Err(err));
                        }
                        None => {
                            // only touch the slot if this thread's
                            // installation still owns it — it may have
                            // been retired (and even recycled by a
                            // healthy successor) while we were opening
                            if queues.retire_generation(slot,
                                                        generation) {
                                m.shards[slot]
                                    .mark_retired(m.epoch_micros());
                                let live = queues.live_count();
                                m.record_scale(StageId::Dnn,
                                               ScaleAction::SpawnFailed,
                                               slot, live);
                            }
                        }
                    }
                    return Ok(());
                }
            };
            drop(ready); // init handshake complete
            // spread the decode round-robin start points so shards
            // do not gang up on decode worker 0
            let mut rr = slot;
            let stats = &m.shards[slot];
            while let Ok(batch) = rx.recv() {
                let t0 = Instant::now();
                let lps = backend.run_windows(&model, bits, &batch.sigs)?;
                let busy = t0.elapsed().as_micros() as u64;
                let n_items = batch.keys.len();
                m.add(&m.batches, 1);
                m.add(&m.batch_items, n_items as u64);
                if batch.full {
                    m.add(&m.full_batches, 1);
                }
                m.add(&m.dnn_micros, busy);
                m.add(&stats.batches, 1);
                m.add(&stats.windows, n_items as u64);
                m.add(&stats.busy_micros, busy);
                for ((read_id, window_idx), lp) in
                    batch.keys.into_iter().zip(lps)
                {
                    // skip-over-backlogged round-robin; if every
                    // decode queue is gone the pipeline has
                    // collapsed downstream — stop burning
                    // inference on it
                    if !dec.send_round_robin(&mut rr, DecodeJob {
                        read_id,
                        window_idx,
                        lp,
                    }) {
                        anyhow::bail!("decode stage disconnected \
                                       mid-run (downstream failure)");
                    }
                }
            }
            Ok(())
        });
        self.handles.lock().unwrap().push(handle);
    }
}

impl StagePool for ShardHost {
    fn slots(&self) -> usize {
        self.queues.slots()
    }

    fn live_slots(&self) -> Vec<usize> {
        self.queues.live_slots()
    }

    fn busy_micros(&self, slot: usize) -> u64 {
        self.metrics.shards[slot].busy_micros.load(Ordering::Relaxed)
    }

    fn backlog(&self) -> f64 {
        self.window_tx.len() as f64 / self.window_cap.max(1) as f64
    }

    fn scale_up(&self) -> Option<usize> {
        // add() fails once the batcher has sealed the set at shutdown
        // (or total pool collapse), so a racing scale-up can never
        // install a queue that nobody will close again
        let (tx, rx) = bounded::<ShardBatch>(SHARD_QUEUE_DEPTH);
        let slot = self.queues.add(tx)?;
        let generation = self.queues.generation(slot);
        self.launch(slot, generation, rx, None);
        Some(slot)
    }

    fn retire(&self, slot: usize) -> bool {
        if self.queues.retire(slot) {
            self.metrics.shards[slot]
                .mark_retired(self.metrics.epoch_micros());
            true
        } else {
            false
        }
    }
}

/// Live slots ranked busiest-first for tail-batch routing: descending
/// cumulative forward-pass micros, ties toward the lower slot id so the
/// ranking is total. Small deadline-triggered batches consistently pile
/// onto the hottest replica, leaving the rest free to take full batches
/// (and, under the autoscaler, free to be retired).
fn rank_busiest(m: &Metrics, qs: &QueueSet<ShardBatch>) -> Vec<usize> {
    let mut live = qs.live_slots();
    live.sort_by_key(|&s| {
        (u64::MAX - m.shards[s].busy_micros.load(Ordering::Relaxed), s)
    });
    live
}

/// Staged streaming pipeline coordinator. Construct, `submit` reads, pull
/// completed reads mid-run with `try_recv`/`recv_timeout`, then `finish`
/// to drain the rest.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    window: usize,
    registry: Arc<ReadRegistry>,
    tx_windows: Option<Sender<WindowJob>>,
    batcher_thread: Option<JoinHandle<()>>,
    host: Option<Arc<ShardHost>>,
    autoscale_stop: Option<Sender<()>>,
    autoscale_thread: Option<JoinHandle<()>>,
    decode_pool: Option<Arc<WorkerPool<DecodeJob>>>,
    collector: Option<Collector>,
    /// live pipeline telemetry (readable mid-run; see `Metrics`).
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Open the full pipeline: probe the artifact metadata, build the
    /// shard factory, spawn the batcher, the DNN shard pool, the decode
    /// pool, the collector, and (when configured) the autoscale
    /// controller, and block until every *initial* shard's backend has
    /// opened and warmed (so compile/load failures surface here, not
    /// mid-run).
    pub fn new(cfg: CoordinatorConfig) -> Result<Coordinator> {
        // validate metadata on the caller thread for early errors
        let meta = cfg.backend.probe_meta(&cfg.artifacts_dir)?;
        let window = meta.window;
        let batches = meta.batches(&cfg.model, cfg.bits);
        anyhow::ensure!(!batches.is_empty(),
                        "no artifacts for {}/{}b", cfg.model, cfg.bits);
        // the factory front-loads the one artifact load every replica
        // is cloned from (native), so open errors also surface here
        let factory = Arc::new(
            ShardFactory::new(cfg.backend, &cfg.artifacts_dir)?);

        // shard plan: a fixed pool runs `dnn_shards` slots, all live;
        // an adaptive pool pre-allocates `max_shards` slots and starts
        // with `dnn_shards` clamped into [min_shards, max_shards].
        let auto = cfg.autoscale.map(|a| a.normalized());
        let (n_slots, n_initial) = match &auto {
            Some(a) => (a.max_shards,
                        cfg.dnn_shards.clamp(a.min_shards, a.max_shards)),
            None => {
                let n = cfg.dnn_shards.max(1);
                (n, n)
            }
        };
        let n_dec = cfg.decode_threads.max(1);
        let n_vote = cfg.vote_threads.max(1);
        let metrics = Arc::new(
            Metrics::for_pipeline(n_slots, n_dec, n_vote));
        let registry = Arc::new(ReadRegistry::default());

        let cap = cfg.queue_cap.max(1);
        let (tx_windows, rx_windows) = bounded::<WindowJob>(cap);
        let (tx_decoded, rx_decoded) = bounded::<DecodedWindow>(cap);

        // decode pool: per-worker queues in a QueueSet-backed
        // WorkerPool, fed round-robin by the DNN shards (no shared
        // Mutex<Receiver> hot spot), resizable by the controller when
        // `autoscale.scale_decode` is set. The spawn closure moves the
        // decoded-queue prototype sender in; each worker clones it —
        // finish() drops the pool before draining so the collector can
        // observe the disconnect.
        let dec_cap = (cap / n_dec).max(8);
        let decode_pool = {
            let m = metrics.clone();
            let beam = cfg.beam_width;
            let prune = cfg.prune;
            WorkerPool::new(
                StageId::Decode, metrics.clone(), n_dec, dec_cap,
                Box::new(move |slot, rx: Receiver<DecodeJob>| {
                    let tx = tx_decoded.clone();
                    let m = m.clone();
                    std::thread::spawn(move || {
                        while let Ok(job) = rx.recv() {
                            let t0 = Instant::now();
                            let seq = match prune {
                                Some(p) => beam_search_pruned(
                                    &job.lp, beam, p),
                                None => beam_search(&job.lp, beam),
                            };
                            let busy = t0.elapsed().as_micros() as u64;
                            m.add(&m.decode_micros, busy);
                            if let Some(st) = m.decode_workers.get(slot) {
                                m.add(&st.jobs, 1);
                                m.add(&st.busy_micros, busy);
                            }
                            if tx.send(DecodedWindow {
                                read_id: job.read_id,
                                window_idx: job.window_idx,
                                seq,
                            }).is_err() {
                                break;
                            }
                        }
                    })
                }))
        };

        // per-shard batch queues live in a QueueSet so the autoscaler
        // can add/retire slots mid-run. Install the initial queues
        // BEFORE the batcher spawns: dispatch must never observe an
        // empty set at startup (it would read as pool collapse).
        let queues = Arc::new(QueueSet::<ShardBatch>::with_slots(n_slots));
        let mut initial: Vec<(usize, u64, Receiver<ShardBatch>)> =
            Vec::with_capacity(n_initial);
        for _ in 0..n_initial {
            let (tx, rx) = bounded::<ShardBatch>(SHARD_QUEUE_DEPTH);
            let slot = queues.add(tx)
                .expect("a fresh queue set has a slot per initial shard");
            initial.push((slot, queues.generation(slot), rx));
        }

        // batcher: drains the window queue with the size-or-deadline
        // policy and routes each finished batch by size — full batches
        // to the least-loaded live shard, tail batches to the busiest.
        // On exit it closes every shard queue (the host and autoscaler
        // also hold the set, so merely dropping this thread's Arc
        // would not disconnect the shard receivers).
        let batcher_thread = {
            let policy = cfg.policy;
            let qs = queues.clone();
            let m = metrics.clone();
            std::thread::spawn(move || {
                // deadline clock anchored at each window's enqueue, so
                // time queued behind backpressure counts toward the
                // batching deadline and oldest_wait telemetry
                let mut batcher = Batcher::with_stamp(
                    rx_windows, policy, |j: &WindowJob| j.enqueued_at);
                let mut rr = 0usize;
                while let Some(batch) = batcher.next_batch() {
                    let tail = batch.is_tail();
                    let n_items = batch.items.len();
                    // move the signals out of the jobs — no per-window
                    // clone on this hot path
                    let mut keys = Vec::with_capacity(n_items);
                    let mut sigs = Vec::with_capacity(n_items);
                    for j in batch.items {
                        keys.push((j.read_id, j.window_idx));
                        sigs.push(j.signal);
                    }
                    let job = ShardBatch { keys, sigs, full: !tail };
                    let delivered = if tail {
                        // batch-size-aware dispatch: a small deadline
                        // batch rides on the already-hot replica so
                        // full batches stay unsplit across idle shards
                        qs.send_preferring(&rank_busiest(&m, &qs), job)
                    } else {
                        qs.send_least_loaded(&mut rr, job)
                    };
                    if !delivered {
                        // every shard is gone (all replicas failed):
                        // stop pulling windows so submit() sees the
                        // disconnect instead of feeding a dead stage
                        break;
                    }
                }
                qs.close_all();
            })
        };

        let host = Arc::new(ShardHost {
            factory,
            model: cfg.model.clone(),
            bits: cfg.bits,
            queues: queues.clone(),
            dec: Feeder::new(decode_pool.queues()),
            metrics: metrics.clone(),
            handles: Mutex::new(Vec::new()),
            window_tx: tx_windows.clone(),
            window_cap: cap,
        });

        // initial shard pool; every shard reports open+warm exactly once
        let (tx_ready, rx_ready) =
            bounded::<Result<()>>(n_initial.max(1));
        for (slot, generation, rx) in initial {
            host.launch(slot, generation, rx, Some(tx_ready.clone()));
        }
        drop(tx_ready); // shard threads hold the only ready senders

        // collector: assembles out-of-order windows, votes + splices in
        // its own worker pool, emits CalledReads eagerly.
        let collector = Collector::spawn(
            registry.clone(),
            rx_decoded,
            metrics.clone(),
            CollectorConfig {
                vote_threads: n_vote,
                queue_cap: cap,
            },
        );

        // wait for every initial shard to finish opening + warming (or
        // fail fast: the first shard error aborts construction, and the
        // channel cascade tears the other stages down as this frame's
        // senders drop)
        for _ in 0..n_initial {
            rx_ready.recv()
                .map_err(|_| anyhow::anyhow!(
                    "a dnn shard thread died during init"))??;
        }
        if auto.is_none() {
            // fixed pool: no further replica will ever be built, so
            // release the factory's native prototype instead of
            // carrying an (N+1)-th model copy for the whole run
            host.factory.discard_prototype();
        }

        // adaptive controller: one thread sizing every controlled
        // stage — the DNN pool always, the decode/vote pools when
        // `scale_decode`/`scale_vote` opt them in (their configured
        // widths become the per-stage ceilings, floor 1). Runs sample
        // → decide → scale/retire every tick until finish() signals
        // stop (see coordinator::autoscale).
        let (autoscale_stop, autoscale_thread) = match auto {
            Some(a) => {
                let (stop_tx, stop_rx) = bounded::<()>(1);
                let mut stages = vec![StageControl {
                    stage: StageId::Dnn,
                    pool: host.clone() as Arc<dyn StagePool>,
                    min: a.min_shards,
                    max: a.max_shards,
                }];
                if a.scale_decode {
                    stages.push(StageControl {
                        stage: StageId::Decode,
                        pool: decode_pool.clone() as Arc<dyn StagePool>,
                        min: 1,
                        max: n_dec,
                    });
                }
                if a.scale_vote {
                    if let Some(pool) = collector.vote_stage_pool() {
                        stages.push(StageControl {
                            stage: StageId::Vote,
                            pool,
                            min: 1,
                            max: n_vote,
                        });
                    }
                }
                let m = metrics.clone();
                let h = std::thread::spawn(move || {
                    autoscale::run(stages, a, m, stop_rx);
                });
                (Some(stop_tx), Some(h))
            }
            None => (None, None),
        };

        Ok(Coordinator {
            cfg,
            window,
            registry,
            tx_windows: Some(tx_windows),
            batcher_thread: Some(batcher_thread),
            host: Some(host),
            autoscale_stop,
            autoscale_thread,
            decode_pool: Some(decode_pool),
            collector: Some(collector),
            metrics,
        })
    }

    /// Split a read into windows and enqueue them. Blocks once
    /// `queue_cap` windows are in flight ahead of the DNN stage
    /// (backpressure), so raw-signal memory stays bounded for
    /// arbitrarily long runs. Completed reads accumulate on the
    /// (unbounded) output queue until taken; interleave `drain_ready()`
    /// in long submission loops to keep that flat too.
    pub fn submit(&mut self, read: &Read) {
        let ws = windows_from_read(read, self.window, self.cfg.hop);
        if ws.is_empty() {
            // shorter than one window: accepted, trivially complete
            self.metrics.add(&self.metrics.reads_in, 1);
            return;
        }
        // register BEFORE the first window enters the pipeline so the
        // collector always knows the expected count. Counters, by
        // contrast, track what actually ENTERS the pipeline: windows
        // are counted per successful enqueue and the read once its
        // first window is in, so a mid-run DNN failure cannot leave
        // `windows` claiming deliveries that never happened (a
        // partially-sent read counts only its delivered prefix, and a
        // fully-refused read counts nothing at all).
        self.registry.register(read.id, ws.len());
        let mut delivered: u64 = 0;
        if let Some(tx) = &self.tx_windows {
            for (i, w) in ws.into_iter().enumerate() {
                if tx.send(WindowJob {
                    read_id: read.id,
                    window_idx: i,
                    signal: w.signal,
                    enqueued_at: Instant::now(),
                }).is_err() {
                    // DNN stage already exited (mid-run failure). If no
                    // window of this read got in, drop the registration
                    // so in_flight() doesn't count it forever.
                    if i == 0 {
                        self.registry.unregister(read.id);
                    }
                    break;
                }
                delivered += 1;
            }
        } else {
            self.registry.unregister(read.id);
        }
        if delivered > 0 {
            self.metrics.add(&self.metrics.reads_in, 1);
            self.metrics.add(&self.metrics.windows, delivered);
        }
    }

    /// Non-blocking: the next read whose last window has decoded, if any.
    /// Reads stream out mid-run, in completion order (not id order).
    pub fn try_recv(&self) -> Option<CalledRead> {
        self.collector.as_ref()?.try_recv()
    }

    /// Block up to `timeout` for the next completed read.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<CalledRead> {
        self.collector.as_ref()?.recv_timeout(timeout)
    }

    /// Every read that has completed so far, without blocking. Calling
    /// this inside long submission loops keeps output memory flat; batch
    /// callers may skip it (the output queue is unbounded, so results
    /// simply accumulate there until `finish()`).
    pub fn drain_ready(&self) -> Vec<CalledRead> {
        let mut out = Vec::new();
        while let Some(r) = self.try_recv() {
            out.push(r);
        }
        out
    }

    /// Close the intake and deterministically drain the pipeline: blocks
    /// until every stage disconnects, then returns the remaining called
    /// reads sorted by id. Reads already taken via `try_recv`/
    /// `recv_timeout` are not returned again.
    pub fn finish(mut self) -> Result<Vec<CalledRead>> {
        // halt the autoscaler FIRST: once its thread is joined no scale
        // event can race the drain, and no new shard handle can appear
        // after we take them below.
        drop(self.autoscale_stop.take());
        if let Some(h) = self.autoscale_thread.take() {
            let _ = h.join();
        }
        // release the host's channel handles (window sender + decode
        // feeder): the recv-until-disconnect barrier below relies on
        // every sender dropping. The controller's host Arc is already
        // gone.
        let mut shard_handles: Vec<JoinHandle<Result<()>>> = Vec::new();
        if let Some(host) = self.host.take() {
            shard_handles = host.handles.lock().unwrap()
                .drain(..).collect();
        }
        // release the decode pool: its respawn closure holds the
        // decoded-queue prototype sender, which must drop before the
        // drain barrier can see the collector disconnect. (The
        // controller — the only other pool holder — is joined above,
        // so no worker can spawn after the handles are taken.)
        let decode_handles: Vec<JoinHandle<()>> =
            match self.decode_pool.take() {
                Some(pool) => pool.take_handles(),
                None => Vec::new(),
            };
        drop(self.tx_windows.take());
        // drain first: recv-until-disconnect is the shutdown barrier —
        // it returns exactly when the last stage has emptied, after
        // which every join below is immediate.
        let collected = match self.collector.take() {
            Some(c) => c.finish(),
            None => Ok(Vec::new()),
        };
        let mut err = None;
        if let Some(h) = self.batcher_thread.take() {
            if h.join().is_err() {
                err = Some(anyhow::anyhow!("batcher thread panicked"));
            }
        }
        for h in shard_handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if err.is_none() {
                        err = Some(e);
                    }
                }
                Err(_) => {
                    if err.is_none() {
                        err = Some(anyhow::anyhow!(
                            "dnn shard thread panicked"));
                    }
                }
            }
        }
        for h in decode_handles {
            if h.join().is_err() && err.is_none() {
                err = Some(anyhow::anyhow!("decode worker panicked"));
            }
        }
        // a collector panic is the root cause of any knock-on DNN
        // "decode stage disconnected" error, so report it first
        let mut out = match (collected, err) {
            (Err(ce), _) => return Err(ce),
            (Ok(_), Some(e)) => return Err(e),
            (Ok(v), None) => v,
        };
        out.sort_by_key(|r| r.read_id);
        Ok(out)
    }

    /// The batching policy's size trigger (for batch-fill accounting).
    pub fn max_batch(&self) -> usize {
        self.cfg.policy.max_batch
    }

    /// The DNN shard count the pipeline actually *started with*: the
    /// fixed pool size, or — under the autoscaler — the configured
    /// `dnn_shards` clamped into `[min_shards, max_shards]`, exactly
    /// as `new()` clamps the initial live count. (It used to return
    /// the raw configured value, which with autoscaling enabled could
    /// name a shard count that never existed.)
    pub fn dnn_shards(&self) -> usize {
        let n = self.cfg.dnn_shards.max(1);
        match &self.cfg.autoscale {
            Some(a) => {
                let a = a.normalized();
                n.clamp(a.min_shards, a.max_shards)
            }
            None => n,
        }
    }

    /// DNN shards live right now: equals `dnn_shards()` for a fixed
    /// pool (until a replica dies), varies between the autoscale
    /// bounds under the controller. 0 once the pipeline is torn down.
    pub fn live_dnn_shards(&self) -> usize {
        self.host.as_ref().map_or(0, |h| h.queues.live_count())
    }

    /// CTC decode workers live right now: the configured
    /// `decode_threads` until the controller (with
    /// `AutoscaleConfig::scale_decode`) resizes the pool. 0 once the
    /// pipeline is torn down.
    pub fn live_decode_workers(&self) -> usize {
        self.decode_pool.as_ref().map_or(0, |p| p.live_count())
    }

    /// Vote workers live right now: the configured `vote_threads`
    /// until the controller (with `AutoscaleConfig::scale_vote`)
    /// resizes the pool. 0 once the pipeline is torn down.
    pub fn live_vote_workers(&self) -> usize {
        self.collector.as_ref().map_or(0, |c| c.live_vote_workers())
    }

    /// Reads submitted but not yet emitted.
    pub fn in_flight(&self) -> usize {
        self.registry.in_flight()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::pore::PoreModel;
    use crate::genome::synth::{RunSpec, SequencingRun};

    fn no_artifacts_dir() -> String {
        std::env::temp_dir()
            .join("helix_server_unit_no_artifacts")
            .join("nonexistent")
            .to_str().unwrap().to_string()
    }

    /// Regression for the submit() counter drift: `reads_in`/`windows`
    /// used to be bumped before any window was delivered, so a submit
    /// against a dead pipeline (mid-run DNN failure) kept inflating
    /// both counters with work that never entered the pipeline.
    #[test]
    fn dead_pipeline_submit_counts_nothing() {
        let pm = PoreModel::synthetic(7);
        let run = SequencingRun::simulate(&pm, RunSpec {
            genome_len: 600,
            coverage: 2,
            seed: 9,
            ..Default::default()
        });
        assert!(run.reads.len() >= 2, "need at least two reads");
        let mut coord = Coordinator::new(CoordinatorConfig {
            artifacts_dir: no_artifacts_dir(),
            ..Default::default()
        }).unwrap();
        let m = coord.metrics.clone();
        // kill every shard queue: the batcher's next dispatch fails,
        // it exits, and the window receiver drops — the same state a
        // total mid-run DNN failure leaves behind
        coord.host.as_ref().unwrap().queues.close_all();
        // feed probes until the dead batcher is observable from
        // submit() (a probe that delivers no window)
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let before = m.windows.load(Ordering::Relaxed);
            coord.submit(&run.reads[0]);
            if m.windows.load(Ordering::Relaxed) == before {
                break;
            }
            assert!(Instant::now() < deadline,
                    "batcher never observed the closed shard queues");
            std::thread::sleep(Duration::from_millis(2));
        }
        // THE regression assertions: a submit against the dead
        // pipeline must not move reads_in/windows, and must not leave
        // a registration stuck in flight
        let reads_before = m.reads_in.load(Ordering::Relaxed);
        let windows_before = m.windows.load(Ordering::Relaxed);
        let in_flight_before = coord.in_flight();
        coord.submit(&run.reads[1]);
        assert_eq!(m.reads_in.load(Ordering::Relaxed), reads_before,
                   "undelivered read must not count as read in");
        assert_eq!(m.windows.load(Ordering::Relaxed), windows_before,
                   "undelivered windows must not count");
        assert_eq!(coord.in_flight(), in_flight_before,
                   "undelivered read must be unregistered");
    }

    /// A healthy pipeline still counts every submitted read and all of
    /// its windows (the counter fix must not change the happy path).
    #[test]
    fn healthy_submit_counts_all_windows() {
        let pm = PoreModel::synthetic(7);
        let run = SequencingRun::simulate(&pm, RunSpec {
            genome_len: 500,
            coverage: 1,
            seed: 17,
            ..Default::default()
        });
        let mut coord = Coordinator::new(CoordinatorConfig {
            artifacts_dir: no_artifacts_dir(),
            ..Default::default()
        }).unwrap();
        let m = coord.metrics.clone();
        let mut expected_windows = 0u64;
        for r in &run.reads {
            let ws = windows_from_read(r, coord.window, coord.cfg.hop);
            expected_windows += ws.len() as u64;
            coord.submit(r);
        }
        assert_eq!(m.reads_in.load(Ordering::Relaxed),
                   run.reads.len() as u64);
        assert_eq!(m.windows.load(Ordering::Relaxed), expected_windows);
        coord.finish().unwrap();
    }
}
