//! Stage spawning: the DNN shard host (one per tier) and the CTC
//! decode worker pool, plus the escalation hub the decode workers use
//! to re-queue low-confidence fast-tier windows.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::util::sync::AtomicU64;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::basecall::ctc::{beam_search, beam_search_pruned,
                           beam_search_pruned_n, BeamPrune};
use crate::runtime::{ShardFactory, Tier};
use crate::util::bounded::{bounded, Feeder, QueueSet, Receiver, Sender};

use super::analysis::RejectGate;
use super::autoscale::{StagePool, WorkerPool};
use super::collector::DecodedWindow;
use super::job::{DecodeJob, ShardBatch, WindowJob};
use super::metrics::{Metrics, ScaleAction, ShardStats, StageId};

/// Batches a shard can hold QUEUED ahead of its forward pass (the
/// executing batch has already been dequeued): one staged batch while
/// one executes — classic double buffering — keeps a replica busy
/// without parking a deep backlog of signal memory behind a slow shard
/// (the window queue is the intended buffering point — it
/// backpressures `submit()`). Depth 1 is also what makes retirement
/// cheap: a closed queue drains at most one staged batch before the
/// shard thread sees the disconnect and exits.
pub(crate) const SHARD_QUEUE_DEPTH: usize = 1;

/// The decode workers' handle on the escalation path: the confidence
/// threshold, the re-queue sender back to the dispatcher, and the
/// shared count of dispatched-but-undecided fast-tier windows (see
/// `TieredBatcher` for the shutdown protocol it anchors).
#[derive(Clone)]
pub(crate) struct Escalator {
    pub(crate) margin: f32,
    pub(crate) tx: Sender<WindowJob>,
    pub(crate) pending: Arc<AtomicU64>,
}

/// Shard-pool state shared by everyone who touches one tier's pool:
/// the dispatcher routes through `queues`, the autoscaler (when
/// enabled) adds and retires slots through the [`StagePool`] impl, and
/// `Coordinator::finish` drains `handles`. Shard threads hold only the
/// individual Arcs they need (factory, queue set, metrics) — never
/// this struct — so teardown has no reference cycles: once the
/// controller is joined and the coordinator drops its host Arcs, the
/// hosts' input senders and decode feeders drop and the stage-by-stage
/// disconnect cascade proceeds exactly as in the fixed-pool design.
///
/// A tiered pipeline runs two hosts over ONE [`ShardFactory`]: a
/// native replica holds the quantized models for every exported
/// bit-width and `warm(model, bits)` selects one, so the hq pool costs
/// what a same-size single-tier pool costs.
pub(crate) struct ShardHost {
    pub(crate) factory: Arc<ShardFactory>,
    pub(crate) model: String,
    pub(crate) bits: u32,
    /// which stage this host's slots report as: `Dnn` for the fast /
    /// only pool (stats in `Metrics::shards`), `DnnHq` for the
    /// escalation pool (stats in `Metrics::hq_shards`).
    pub(crate) stage: StageId,
    /// the tier tag stamped on every `DecodeJob` this host emits.
    pub(crate) tier: Tier,
    /// carry each window's signal into its `DecodeJob` so a
    /// low-confidence decode can re-queue it — true only on the fast
    /// host of an escalation-armed pipeline.
    pub(crate) keep_signals: bool,
    pub(crate) queues: Arc<QueueSet<ShardBatch>>,
    /// producer guard over the decode pool's queue set: every shard
    /// thread holds a clone, and the last holder's drop seals the set
    /// so the decode workers disconnect exactly when no shard remains
    /// (the hosts themselves are dropped by `finish()` before the
    /// drain).
    pub(crate) dec: Feeder<DecodeJob>,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) handles: Mutex<Vec<JoinHandle<Result<()>>>>,
    /// this host's input-queue sender, held only for backlog sampling:
    /// the bounded window queue for the fast/only host, the escalation
    /// side channel for the hq host.
    pub(crate) window_tx: Sender<WindowJob>,
    pub(crate) window_cap: usize,
}

impl ShardHost {
    /// Spawn the shard thread that owns slot `slot`'s backend replica.
    /// The replica is opened + warmed *inside* the thread (it may not
    /// be `Send`). `ready` carries the outcome for init-time shards so
    /// `Coordinator::new` fails fast; autoscaled spawns pass `None` —
    /// on failure they retire *their own installation* of the slot
    /// (generation-checked, so a slow failing spawn can never close a
    /// successor that recycled the slot) and log a `SpawnFailed` scale
    /// event, degrading the pool instead of failing the run.
    pub(crate) fn launch(&self, slot: usize, generation: u64,
                         rx: Receiver<ShardBatch>,
                         ready: Option<Sender<Result<()>>>) {
        let stage = self.stage;
        self.metrics.stage_shards(stage)[slot]
            .mark_spawned(self.metrics.epoch_micros());
        let factory = self.factory.clone();
        let queues = self.queues.clone();
        let dec = self.dec.clone();
        let m = self.metrics.clone();
        let model = self.model.clone();
        let bits = self.bits;
        let tier = self.tier;
        let keep_signals = self.keep_signals;
        let handle = std::thread::spawn(move || -> Result<()> {
            let opened = factory.replica(slot)
                .and_then(|mut b| b.warm(&model, bits).map(|()| b));
            let mut backend = match opened {
                Ok(b) => {
                    if let Some(tx) = &ready {
                        let _ = tx.send(Ok(()));
                    }
                    b
                }
                Err(err) => {
                    match ready {
                        Some(tx) => {
                            let _ = tx.send(Err(err));
                        }
                        None => {
                            // only touch the slot if this thread's
                            // installation still owns it — it may have
                            // been retired (and even recycled by a
                            // healthy successor) while we were opening
                            if queues.retire_generation(slot,
                                                        generation) {
                                m.stage_shards(stage)[slot]
                                    .mark_retired(m.epoch_micros());
                                let live = queues.live_count();
                                m.record_scale(stage,
                                               ScaleAction::SpawnFailed,
                                               slot, live);
                            }
                        }
                    }
                    return Ok(());
                }
            };
            drop(ready); // init handshake complete
            // spread the decode round-robin start points so shards
            // do not gang up on decode worker 0
            let mut rr = slot;
            let stats = &m.stage_shards(stage)[slot];
            while let Ok(batch) = rx.recv() {
                let t0 = Instant::now();
                let lps = backend.run_windows(&model, bits, &batch.sigs)?;
                let busy = t0.elapsed().as_micros() as u64;
                let n_items = batch.keys.len();
                m.add(&m.batches, 1);
                m.add(&m.batch_items, n_items as u64);
                if batch.full {
                    m.add(&m.full_batches, 1);
                }
                m.add(&m.dnn_micros, busy);
                m.add(&stats.batches, 1);
                m.add(&stats.windows, n_items as u64);
                m.add(&stats.busy_micros, busy);
                // move the signals back out only when the decode pool
                // may need them for an escalation re-queue
                let mut sigs = batch.sigs.into_iter();
                for (key, lp) in batch.keys.into_iter().zip(lps) {
                    let signal = if keep_signals {
                        sigs.next()
                    } else {
                        None
                    };
                    // skip-over-backlogged round-robin; if every
                    // decode queue is gone the pipeline has
                    // collapsed downstream — stop burning
                    // inference on it
                    if !dec.send_round_robin(&mut rr, DecodeJob {
                        read_id: key.read_id,
                        window_idx: key.window_idx,
                        tenant: key.tenant,
                        lp,
                        tier,
                        signal,
                        escalated_at: key.escalated_at,
                    }) {
                        anyhow::bail!("decode stage disconnected \
                                       mid-run (downstream failure)");
                    }
                }
            }
            Ok(())
        });
        self.handles.lock().unwrap().push(handle);
    }
}

impl StagePool for ShardHost {
    fn slots(&self) -> usize {
        self.queues.slots()
    }

    fn live_slots(&self) -> Vec<usize> {
        self.queues.live_slots()
    }

    fn busy_micros(&self, slot: usize) -> u64 {
        self.metrics.stage_shards(self.stage)[slot]
            .busy_micros.load(Ordering::Relaxed)
    }

    fn backlog(&self) -> f64 {
        // the fraction can exceed 1 for the hq host (its input is the
        // unbounded escalation channel, measured against the window
        // cap); the controller only thresholds it, so saturation is
        // fine
        self.window_tx.len() as f64 / self.window_cap.max(1) as f64
    }

    fn scale_up(&self) -> Option<usize> {
        // add() fails once the dispatcher has sealed the set at
        // shutdown (or total pool collapse), so a racing scale-up can
        // never install a queue that nobody will close again
        let (tx, rx) = bounded::<ShardBatch>(SHARD_QUEUE_DEPTH);
        let slot = self.queues.add(tx)?;
        let generation = self.queues.generation(slot);
        self.launch(slot, generation, rx, None);
        Some(slot)
    }

    fn retire(&self, slot: usize) -> bool {
        if self.queues.retire(slot) {
            self.metrics.stage_shards(self.stage)[slot]
                .mark_retired(self.metrics.epoch_micros());
            true
        } else {
            false
        }
    }
}

/// Live slots ranked busiest-first for tail-batch routing: descending
/// cumulative forward-pass micros over the given tier's stats table,
/// ties toward the lower slot id so the ranking is total. Small
/// deadline-triggered batches consistently pile onto the hottest
/// replica, leaving the rest free to take full batches (and, under the
/// autoscaler, free to be retired).
pub(crate) fn rank_busiest(stats: &[ShardStats],
                           qs: &QueueSet<ShardBatch>) -> Vec<usize> {
    let mut live = qs.live_slots();
    live.sort_by_key(|&s| {
        (u64::MAX - stats[s].busy_micros.load(Ordering::Relaxed), s)
    });
    live
}

/// Build the CTC decode worker pool: per-worker queues in a
/// QueueSet-backed [`WorkerPool`], fed round-robin by the DNN shards
/// (no shared `Mutex<Receiver>` hot spot), resizable by the controller
/// when `autoscale.scale_decode` is set. The spawn closure moves the
/// decoded-queue prototype sender in; each worker clones it —
/// `finish()` drops the pool before draining so the collector can
/// observe the disconnect.
///
/// With `esc` set (tiered serving), a fast-tier job decodes the top
/// TWO beams and its confidence margin — top beam's score minus the
/// runner-up's — is compared against the escalation threshold: below
/// it, the window is re-queued to the hq tier instead of being
/// collected. Hq-tier jobs (and every job when `esc` is `None`) run
/// the exact single-best search of the single-tier pipeline, which is
/// what keeps escalation-off output byte-identical.
///
/// With `gate` set (early rejection), every branch measures the same
/// top-two margin (the top-2 traversal is identical to the top-1, so
/// the best decode is unchanged) and a margin below the gate's
/// threshold condemns the whole read: the window is delivered with
/// `DecodedWindow::rejected` set, and every LATER window of that read
/// skips the beam search entirely — the GenPIP-style early exit. On
/// the fast tier, rejection is checked BEFORE escalation, so a
/// hopeless window never burns an hq re-run.
pub(crate) fn spawn_decode_pool(
    metrics: Arc<Metrics>,
    n_dec: usize,
    dec_cap: usize,
    beam: usize,
    prune: Option<BeamPrune>,
    tx_decoded: Sender<DecodedWindow>,
    esc: Option<Escalator>,
    gate: Option<Arc<RejectGate>>,
) -> Arc<WorkerPool<DecodeJob>> {
    let m = metrics.clone();
    WorkerPool::new(
        StageId::Decode, metrics, n_dec, dec_cap,
        Box::new(move |slot, rx: Receiver<DecodeJob>| {
            let tx = tx_decoded.clone();
            let m = m.clone();
            let esc = esc.clone();
            let gate = gate.clone();
            std::thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    // a read already condemned skips the CTC kernel:
                    // its window still flows to the collector (tagged
                    // rejected) so the read completes and drains, but
                    // no decode compute is spent on it
                    if let Some(g) = &gate {
                        if g.is_rejected(job.read_id) {
                            m.add(&m.rejected_windows, 1);
                            if let (Some(e), Tier::Fast) =
                                (&esc, job.tier)
                            {
                                e.pending.fetch_sub(1,
                                                    Ordering::Release);
                            }
                            if tx.send(DecodedWindow {
                                read_id: job.read_id,
                                window_idx: job.window_idx,
                                tenant: job.tenant,
                                seq: Vec::new(),
                                rejected: true,
                            }).is_err() {
                                break;
                            }
                            continue;
                        }
                    }
                    let t0 = Instant::now();
                    if let (Some(e), Tier::Fast) = (&esc, job.tier) {
                        // confidence-gated fast tier: decode the top
                        // two beams so the margin is observable
                        let mut top = beam_search_pruned_n(
                            &job.lp, beam, 2,
                            prune.unwrap_or(BeamPrune::OFF));
                        // beam_search_*_n returns best LAST
                        let (best, best_score) =
                            top.pop().unwrap_or_default();
                        let margin = match top.pop() {
                            Some((_, runner)) => best_score - runner,
                            // a single surviving beam: no runner-up to
                            // doubt it, treat as fully confident
                            None => f32::INFINITY,
                        };
                        let busy = t0.elapsed().as_micros() as u64;
                        m.add(&m.decode_micros, busy);
                        if let Some(st) = m.decode_workers.get(slot) {
                            m.add(&st.jobs, 1);
                            m.add(&st.busy_micros, busy);
                        }
                        m.add(&m.fast_decided, 1);
                        // rejection beats escalation: a hopeless read
                        // must not burn an hq re-run on its way out
                        if let Some(g) = &gate {
                            if margin < g.threshold() {
                                g.mark(job.read_id);
                                e.pending.fetch_sub(1,
                                                    Ordering::Release);
                                if tx.send(DecodedWindow {
                                    read_id: job.read_id,
                                    window_idx: job.window_idx,
                                    tenant: job.tenant,
                                    seq: Vec::new(),
                                    rejected: true,
                                }).is_err() {
                                    break;
                                }
                                continue;
                            }
                        }
                        if margin < e.margin {
                            // low confidence: re-queue at the hq tier
                            // instead of collecting. The send must
                            // precede the pending release — the
                            // dispatcher's shutdown check relies on
                            // that order (see TieredBatcher). A send
                            // error means the dispatcher is gone
                            // (shutdown/collapse); the window is
                            // dropped like any in-flight work then.
                            m.add(&m.escalations, 1);
                            let now = Instant::now();
                            let _ = e.tx.send(WindowJob {
                                read_id: job.read_id,
                                window_idx: job.window_idx,
                                // the tenant tag rides the re-queue, so
                                // an escalated window still routes its
                                // (single) completion to its owner
                                tenant: job.tenant,
                                signal: job.signal.unwrap_or_default(),
                                tier: Tier::Hq,
                                enqueued_at: now,
                                escalated_at: Some(now),
                            });
                            e.pending.fetch_sub(1, Ordering::Release);
                            continue;
                        }
                        e.pending.fetch_sub(1, Ordering::Release);
                        if tx.send(DecodedWindow {
                            read_id: job.read_id,
                            window_idx: job.window_idx,
                            tenant: job.tenant,
                            seq: best,
                            rejected: false,
                        }).is_err() {
                            break;
                        }
                        continue;
                    }
                    // hq tier, or escalation disabled: the exact
                    // single-tier decode path. With the reject gate
                    // armed the margin must be observable here too, so
                    // decode the top two beams — same traversal, same
                    // best result, byte-identical output.
                    let (seq, margin) = match &gate {
                        Some(_) => {
                            let mut top = beam_search_pruned_n(
                                &job.lp, beam, 2,
                                prune.unwrap_or(BeamPrune::OFF));
                            let (best, best_score) =
                                top.pop().unwrap_or_default();
                            let margin = match top.pop() {
                                Some((_, runner)) =>
                                    best_score - runner,
                                None => f32::INFINITY,
                            };
                            (best, Some(margin))
                        }
                        None => (match prune {
                            Some(p) =>
                                beam_search_pruned(&job.lp, beam, p),
                            None => beam_search(&job.lp, beam),
                        }, None),
                    };
                    let busy = t0.elapsed().as_micros() as u64;
                    m.add(&m.decode_micros, busy);
                    if let Some(st) = m.decode_workers.get(slot) {
                        m.add(&st.jobs, 1);
                        m.add(&st.busy_micros, busy);
                    }
                    if let Some(at) = job.escalated_at {
                        m.escalation_latency.record(
                            at.elapsed().as_micros() as u64);
                    }
                    if let (Some(g), Some(margin)) = (&gate, margin) {
                        if margin < g.threshold() {
                            g.mark(job.read_id);
                            if tx.send(DecodedWindow {
                                read_id: job.read_id,
                                window_idx: job.window_idx,
                                tenant: job.tenant,
                                seq: Vec::new(),
                                rejected: true,
                            }).is_err() {
                                break;
                            }
                            continue;
                        }
                    }
                    if tx.send(DecodedWindow {
                        read_id: job.read_id,
                        window_idx: job.window_idx,
                        tenant: job.tenant,
                        seq,
                        rejected: false,
                    }).is_err() {
                        break;
                    }
                }
            })
        }))
}
