//! Coordinator configuration: the pipeline-shaping
//! [`CoordinatorConfig`] struct plus the table-driven CLI-flag /
//! `HELIX_*` environment resolver every serving knob goes through.
//!
//! Precedence is one rule for every knob: **an explicit flag beats the
//! environment, the environment beats the built-in default.** A flag
//! that is present but unparsable is a hard error (the operator typed
//! it; silently ignoring it would run a different configuration than
//! they asked for), while an unparsable environment value falls back
//! silently (matching the long-standing `*_from_env` behavior — env
//! vars travel through CI configs and containers where stray values
//! must not brick the binary).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::basecall::ctc::BeamPrune;
use crate::runtime::BackendKind;

use super::autoscale::AutoscaleConfig;
use super::batcher::BatchPolicy;

/// Everything the `Coordinator` needs to open a pipeline: model
/// selection, backend kind, stage widths, queue bounds, and the tiered
/// serving knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// model family to execute (e.g. "guppy").
    pub model: String,
    /// bit-width variant of the model (32 = the fp32-trained baseline).
    /// With tiered serving armed this is the **hq** tier's width; the
    /// fast tier's comes from `tier_bits`.
    pub bits: u32,
    /// which inference backend the DNN stage opens (native by default;
    /// `xla` requires the cargo feature).
    pub backend: BackendKind,
    /// window hop in samples; window length comes from the artifact meta.
    pub hop: usize,
    /// CTC beam width used by the decode pool.
    pub beam_width: usize,
    /// number of DNN executor shards. Each shard owns an independent
    /// `Backend` replica (built by the [`ShardFactory`]: an in-memory
    /// clone for native, `open_shard` in-thread for non-`Send`
    /// backends) fed through its own bounded batch queue; 1 reproduces
    /// the single-owner layout. With `autoscale` set this is only the
    /// *initial* live count (clamped into `[min_shards, max_shards]`).
    /// The called result set is byte-identical for any value.
    ///
    /// [`ShardFactory`]: crate::runtime::ShardFactory
    pub dnn_shards: usize,
    /// CTC decode worker count.
    pub decode_threads: usize,
    /// vote/splice worker count.
    pub vote_threads: usize,
    /// bound on in-flight windows per queue: `submit()` blocks once the
    /// window queue holds this many undecoded windows (backpressure).
    pub queue_cap: usize,
    /// size-or-deadline batching policy for the DNN stage.
    pub policy: BatchPolicy,
    /// adaptive shard autoscaling: `None` (default) pins the pool at
    /// `dnn_shards` for the whole run; `Some(cfg)` starts a controller
    /// thread that resizes the live pool between `cfg.min_shards` and
    /// `cfg.max_shards` from observed utilization (see
    /// `coordinator::autoscale`). Scaling never changes called output.
    pub autoscale: Option<AutoscaleConfig>,
    /// artifact directory (meta.json + weights; the native backend
    /// falls back to its builtin model when absent).
    pub artifacts_dir: String,
    /// beam-search pruning thresholds for the decode pool. `None`
    /// (default) runs the exhaustive search — byte-identical to the
    /// pre-knob pipeline. `Some(BeamPrune::OFF)` also reproduces the
    /// exhaustive arithmetic exactly; finite thresholds trade decode
    /// work for a bounded heuristic (see `basecall::ctc::BeamPrune`).
    pub prune: Option<BeamPrune>,
    /// confidence threshold that arms speculative tiered serving.
    /// `None` (default) runs the single-tier pipeline — byte-identical
    /// to pre-tier builds. `Some(m)` routes fresh windows through a
    /// low-bit fast tier and re-queues any window whose top-two-beam
    /// CTC score margin falls below `m` onto a full-precision hq tier.
    /// `0.0` never escalates (margins are non-negative);
    /// `f32::INFINITY` escalates every window, reproducing hq-only
    /// output byte-for-byte at two-pass cost.
    pub escalate_margin: Option<f32>,
    /// fast-tier bit-width override. `None` picks automatically (the
    /// 8-bit rung when it sits below `bits` in the artifact ladder,
    /// else the widest rung below `bits`). Ignored unless
    /// `escalate_margin` is set.
    pub tier_bits: Option<u32>,
    /// GenPIP-style early-rejection threshold over the CTC
    /// top-two-beam score margin. `None` (default) never rejects —
    /// byte-identical to pre-gate builds, and so is `Some(0.0)`
    /// (margins are non-negative). `Some(m)` marks a read rejected the
    /// first time one of its windows decodes with margin `< m`; the
    /// read's remaining windows skip the CTC kernel and the read skips
    /// vote/analysis entirely (it still completes through the
    /// collector, so `in_flight()` drains to 0).
    pub reject_threshold: Option<f32>,
    /// streaming-analysis worker count (overlap → assembly → polish
    /// fed from the vote stage). 0 (default) leaves the analysis stage
    /// off — the pipeline ends at `CalledRead`, byte-identical to
    /// pre-analysis builds.
    pub analysis_threads: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            model: "guppy".into(),
            bits: 32,
            backend: BackendKind::default(),
            hop: 100,
            beam_width: 10,
            dnn_shards: 1,
            decode_threads: 2,
            vote_threads: 2,
            queue_cap: 256,
            policy: BatchPolicy::default(),
            autoscale: None,
            artifacts_dir: crate::runtime::meta::default_artifacts_dir(),
            prune: None,
            escalate_margin: None,
            tier_bits: None,
            reject_threshold: None,
            analysis_threads: 0,
        }
    }
}

/// Shape of the multi-tenant TCP front-end (`coordinator::net`):
/// where to listen and how admission control treats each connection.
/// Orthogonal to [`CoordinatorConfig`] — the same pipeline config
/// serves the library path and the wire path unchanged.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// listen address (`host:port`; port 0 binds an ephemeral port —
    /// the default, which tests and benches read back via
    /// `Server::local_addr`).
    pub addr: String,
    /// per-tenant in-flight read quota: a connection with this many
    /// reads unanswered has further submissions refused with
    /// `BUSY(quota)` until results come back — the greedy client
    /// blocks itself, never its neighbours. 0 = unlimited (only the
    /// global `queue_cap` backpressure applies).
    pub tenant_quota: usize,
    /// latency SLO for load shedding: when the interval p99 of the
    /// per-read latency breaches this budget, new submissions from
    /// EVERY tenant are refused with `BUSY(slo)` until the interval
    /// p99 recovers. `None` (default) never sheds on latency.
    pub slo: Option<std::time::Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            tenant_quota: 64,
            slo: None,
        }
    }
}

impl CoordinatorConfig {
    /// Shard count selected by `HELIX_SHARDS` (default 1; zero or an
    /// unparsable value also fall back to 1).
    pub fn shards_from_env() -> usize {
        std::env::var("HELIX_SHARDS").ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1)
    }
}

/// Where a resolved knob's value came from — callers use this to apply
/// flag-only validation (e.g. an *explicitly typed* orphan refinement
/// flag is an error, while the same setting inherited from a CI
/// environment is silently ignored).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnobSource {
    /// the value was typed on the command line.
    Flag,
    /// the value came from a `HELIX_*` environment variable.
    Env,
}

/// Resolve one serving knob by the uniform precedence rule:
///
/// 1. `flags[flag]` present and parsable → `Some((value, Flag))`.
/// 2. `flags[flag]` present but unparsable → `Err` naming the flag and
///    the expected shape (`want`).
/// 3. `$env` set and parsable → `Some((value, Env))`.
/// 4. anything else (including an unparsable environment value) →
///    `Ok(None)`: the caller's default stands.
///
/// `parse` returns `None` to reject a candidate string; range checks
/// (positivity, finiteness) belong inside it so flag and env values
/// are held to the same contract.
pub fn resolve_knob<T>(
    flags: &HashMap<String, String>,
    flag: &str,
    env: &str,
    want: &str,
    parse: impl Fn(&str) -> Option<T>,
) -> Result<Option<(T, KnobSource)>> {
    if let Some(raw) = flags.get(flag) {
        return match parse(raw) {
            Some(v) => Ok(Some((v, KnobSource::Flag))),
            None => Err(anyhow!("invalid --{flag} '{raw}' (want {want})")),
        };
    }
    if let Ok(raw) = std::env::var(env) {
        if let Some(v) = parse(&raw) {
            return Ok(Some((v, KnobSource::Env)));
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    }

    fn parse_pos(s: &str) -> Option<usize> {
        s.parse::<usize>().ok().filter(|&n| n >= 1)
    }

    #[test]
    fn flag_beats_env() {
        std::env::set_var("HELIX_TEST_RESOLVER_A", "7");
        let got = resolve_knob(&flags(&[("shards", "3")]), "shards",
                               "HELIX_TEST_RESOLVER_A",
                               "a positive integer", parse_pos)
            .unwrap();
        assert_eq!(got, Some((3, KnobSource::Flag)));
        std::env::remove_var("HELIX_TEST_RESOLVER_A");
    }

    #[test]
    fn env_fills_in_when_flag_absent() {
        std::env::set_var("HELIX_TEST_RESOLVER_B", "5");
        let got = resolve_knob(&flags(&[]), "shards",
                               "HELIX_TEST_RESOLVER_B",
                               "a positive integer", parse_pos)
            .unwrap();
        assert_eq!(got, Some((5, KnobSource::Env)));
        std::env::remove_var("HELIX_TEST_RESOLVER_B");
    }

    #[test]
    fn unparsable_flag_is_a_hard_error() {
        let err = resolve_knob(&flags(&[("shards", "zero")]), "shards",
                               "HELIX_TEST_RESOLVER_C",
                               "a positive integer", parse_pos)
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--shards"), "names the flag: {msg}");
        assert!(msg.contains("zero"), "echoes the value: {msg}");
        assert!(msg.contains("positive integer"),
                "states the shape: {msg}");
    }

    #[test]
    fn unparsable_env_falls_back_silently() {
        std::env::set_var("HELIX_TEST_RESOLVER_D", "banana");
        let got = resolve_knob(&flags(&[]), "shards",
                               "HELIX_TEST_RESOLVER_D",
                               "a positive integer", parse_pos)
            .unwrap();
        assert_eq!(got, None, "bad env value keeps the default");
        std::env::remove_var("HELIX_TEST_RESOLVER_D");
    }

    #[test]
    fn absent_everywhere_keeps_the_default() {
        let got = resolve_knob(&flags(&[]), "shards",
                               "HELIX_TEST_RESOLVER_NEVER_SET",
                               "a positive integer", parse_pos)
            .unwrap();
        assert_eq!(got, None);
    }

    #[test]
    fn tier_knobs_share_the_rule() {
        // --escalate-margin and --tier-bits resolve through the same
        // helper with their own parsers; pin the shapes used by main
        let margin = |s: &str| s.parse::<f32>().ok()
            .filter(|m| !m.is_nan() && *m >= 0.0);
        assert_eq!(
            resolve_knob(&flags(&[("escalate-margin", "inf")]),
                         "escalate-margin", "HELIX_TEST_RESOLVER_E",
                         "a non-negative number", &margin).unwrap(),
            Some((f32::INFINITY, KnobSource::Flag)));
        assert!(resolve_knob(&flags(&[("escalate-margin", "-1")]),
                             "escalate-margin", "HELIX_TEST_RESOLVER_E",
                             "a non-negative number", &margin).is_err());
        assert!(resolve_knob(&flags(&[("escalate-margin", "NaN")]),
                             "escalate-margin", "HELIX_TEST_RESOLVER_E",
                             "a non-negative number", &margin).is_err());
    }

    #[test]
    fn default_config_leaves_tiering_off() {
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.escalate_margin, None);
        assert_eq!(cfg.tier_bits, None);
        assert_eq!(cfg.reject_threshold, None,
                   "early rejection defaults off");
        assert_eq!(cfg.analysis_threads, 0,
                   "analysis stage defaults off");
    }

    #[test]
    fn reject_threshold_shares_the_margin_rule() {
        // --reject-threshold resolves through the same helper with the
        // same non-negative-margin parser as --escalate-margin
        let margin = |s: &str| s.parse::<f32>().ok()
            .filter(|m| !m.is_nan() && *m >= 0.0);
        assert_eq!(
            resolve_knob(&flags(&[("reject-threshold", "inf")]),
                         "reject-threshold", "HELIX_TEST_RESOLVER_F",
                         "a non-negative number", &margin).unwrap(),
            Some((f32::INFINITY, KnobSource::Flag)));
        assert_eq!(
            resolve_knob(&flags(&[("reject-threshold", "0")]),
                         "reject-threshold", "HELIX_TEST_RESOLVER_F",
                         "a non-negative number", &margin).unwrap(),
            Some((0.0, KnobSource::Flag)));
        assert!(resolve_knob(&flags(&[("reject-threshold", "-0.5")]),
                             "reject-threshold", "HELIX_TEST_RESOLVER_F",
                             "a non-negative number", &margin).is_err());
    }
}
