//! Adaptive stage autoscaling: a control loop that sizes the
//! pipeline's worker pools from *observed* utilization and tail
//! latency instead of startup constants.
//!
//! The paper's throughput claim rests on keeping every compute array
//! busy; the serving-side analogue is keeping every backend replica
//! and worker busy without parking idle ones on cores another stage
//! could use. A fixed `dnn_shards`/`decode_threads`/`vote_threads`
//! forces the operator to guess that balance per workload. This module
//! closes the loop instead — one controller thread, one decision core,
//! N stage pools:
//!
//! ```text
//!        every `tick`, for EACH controlled stage pool
//!   ┌───────────────────────────────────────────────────────────┐
//!   │  SAMPLE   per-live-slot busy-micros delta / tick wall     │
//!   │           + input-queue backlog fraction                  │
//!   │           + interval p99 of per-read latency (shared)     │
//!   │                         │                                 │
//!   │                         ▼                                 │
//!   │  DECIDE   Controller::observe — hysteresis (consecutive   │
//!   │           hot/cold ticks + post-event cooldown) around    │
//!   │           high_util / low_util; p99 over the SLO counts   │
//!   │           as hot even when utilization reads low          │
//!   │                 │               │                         │
//!   │            ScaleUp          ScaleDown                     │
//!   │                 ▼               ▼                         │
//!   │  ACT      spawn a worker    retire the least-busy slot    │
//!   │           into a free       (drop its queue sender; the   │
//!   │           slot (factory     worker drains what is staged  │
//!   │           clone / late      and exits — the same skip-    │
//!   │           open_shard, or    dead path a crash takes)      │
//!   │           a plain respawn                                 │
//!   │           for cheap decode/vote workers)                  │
//!   └───────────────────────────────────────────────────────────┘
//! ```
//!
//! The **SLO signal** is what makes the controller latency-aware:
//! utilization alone is blind to a trickle load where every read eats
//! the full batching deadline — shards look idle while p99 blows
//! through the budget. `AutoscaleConfig::slo` compares the p99 of the
//! *last tick's* completions (interval snapshots of
//! `Metrics::read_latency`, not the run-cumulative histogram an early
//! burst would pin forever) against the budget, and a breach counts
//! the tick as hot. An interval with no completions reports no signal
//! (not a breach): a stalled pipeline is the backlog signal's job.
//!
//! **Determinism contract:** scaling changes *when* windows run and on
//! *which* replica/worker — never what they produce. Every replica
//! computes bit-identical `LogProbs` for a given window and the
//! collector reassembles by `(read_id, window_idx)`, so a run under
//! the autoscaler calls byte-identical reads to a fixed-pool run over
//! the same input (integration-pinned in `tests/coordinator_stream.rs`,
//! including SLO-scaled runs).
//!
//! The decision core (`Controller`) is a pure function of the sampled
//! trace — no threads, no clocks — so the unit tests below drive it
//! with synthetic traces: saturation must scale up, idleness must
//! scale down, an SLO breach must scale up even at zero utilization,
//! and oscillation around a threshold must NOT flap.

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::{Metrics, ScaleAction, StageId, StageStats};
use crate::util::bounded::{bounded, QueueSet, Receiver,
                           RecvTimeoutError};

/// Tuning knobs for the adaptive stage controller. Construct with
/// struct-update syntax over `Default::default()` (or `from_env`) and
/// pass via `CoordinatorConfig::autoscale`; `normalized()` is applied
/// before use so inverted bounds cannot wedge the pool.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// floor on live DNN shards; the controller never retires below
    /// this.
    pub min_shards: usize,
    /// ceiling on live DNN shards; also the slot count
    /// (`Metrics::shards` length) the pipeline pre-allocates.
    pub max_shards: usize,
    /// control-loop sampling period.
    pub tick: Duration,
    /// mean live-slot utilization above which a tick counts as *hot*.
    pub high_util: f64,
    /// mean live-slot utilization below which a tick counts as *cold*.
    pub low_util: f64,
    /// consecutive hot ticks required before scaling up (hysteresis).
    pub up_ticks: u32,
    /// consecutive cold ticks required before scaling down
    /// (hysteresis; larger than `up_ticks` by default so the pool
    /// grows eagerly and shrinks reluctantly).
    pub down_ticks: u32,
    /// ticks to hold after any scale event before reconsidering, so
    /// the pool's reaction to its own resize settles into the samples.
    pub cooldown_ticks: u32,
    /// per-read p99 latency objective: when set, a tick whose
    /// *interval* p99 (completions since the previous tick) exceeds
    /// this counts as hot — even when utilization reads low — so a
    /// latency-sensitive trickle load still grows the pool. `None`
    /// scales on utilization/backlog alone.
    pub slo: Option<Duration>,
    /// also size the CTC decode pool with this controller. Its slot
    /// ceiling is `CoordinatorConfig::decode_threads` (the configured
    /// width becomes the ceiling; floor 1).
    pub scale_decode: bool,
    /// also size the vote/splice pool with this controller (ceiling
    /// `CoordinatorConfig::vote_threads`, floor 1).
    pub scale_vote: bool,
    /// also size the streaming-analysis pool with this controller
    /// (ceiling `CoordinatorConfig::analysis_threads`, floor 1).
    /// Ignored when the analysis stage is off.
    pub scale_analysis: bool,
    /// floor on live hq-tier DNN shards when tiered serving is armed
    /// (`CoordinatorConfig::escalate_margin`); `0` means "default",
    /// normalized to 1. Ignored on single-tier pipelines.
    pub hq_min_shards: usize,
    /// ceiling on live hq-tier DNN shards; `0` means "follow
    /// `max_shards`". Ignored on single-tier pipelines.
    pub hq_max_shards: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            tick: Duration::from_millis(50),
            high_util: 0.75,
            low_util: 0.20,
            up_ticks: 2,
            down_ticks: 4,
            cooldown_ticks: 2,
            slo: None,
            scale_decode: false,
            scale_vote: false,
            scale_analysis: false,
            hq_min_shards: 0,
            hq_max_shards: 0,
        }
    }
}

impl AutoscaleConfig {
    /// Clamp the knobs into a usable shape: bounds at least 1 with
    /// `max >= min`, a non-zero tick, threshold order `low <= high`,
    /// streak lengths of at least one tick, and a non-zero SLO (a
    /// zero SLO would read every completed read as a breach).
    pub fn normalized(mut self) -> AutoscaleConfig {
        self.min_shards = self.min_shards.max(1);
        self.max_shards = self.max_shards.max(self.min_shards);
        if self.tick.is_zero() {
            self.tick = Duration::from_millis(1);
        }
        if self.low_util > self.high_util {
            self.low_util = self.high_util;
        }
        self.up_ticks = self.up_ticks.max(1);
        self.down_ticks = self.down_ticks.max(1);
        if self.slo == Some(Duration::ZERO) {
            self.slo = None;
        }
        if self.hq_max_shards == 0 {
            self.hq_max_shards = self.max_shards;
        }
        self.hq_min_shards = self.hq_min_shards.max(1);
        self.hq_max_shards = self.hq_max_shards.max(self.hq_min_shards);
        self
    }

    /// Autoscaling selected by environment: enabled iff
    /// `HELIX_MAX_SHARDS` parses to a positive shard ceiling;
    /// `HELIX_MIN_SHARDS` and `HELIX_AUTOSCALE_TICK_MS` then refine
    /// the floor and the sampling period, `HELIX_SLO_MS` sets the p99
    /// latency objective, and `HELIX_AUTOSCALE_DECODE=1` /
    /// `HELIX_AUTOSCALE_VOTE=1` / `HELIX_AUTOSCALE_ANALYSIS=1` extend
    /// the controller to the decode, vote, and streaming-analysis
    /// pools (unparsable values keep the defaults). Returns `None` —
    /// autoscaling off — otherwise.
    pub fn from_env() -> Option<AutoscaleConfig> {
        let max = std::env::var("HELIX_MAX_SHARDS").ok()?
            .parse::<usize>().ok()
            .filter(|&n| n >= 1)?;
        let mut cfg = AutoscaleConfig {
            max_shards: max,
            ..AutoscaleConfig::default()
        };
        if let Some(n) = std::env::var("HELIX_MIN_SHARDS").ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
        {
            cfg.min_shards = n;
        }
        if let Some(ms) = std::env::var("HELIX_AUTOSCALE_TICK_MS").ok()
            .and_then(|s| s.parse::<u64>().ok())
            .filter(|&ms| ms >= 1)
        {
            cfg.tick = Duration::from_millis(ms);
        }
        if let Some(ms) = std::env::var("HELIX_SLO_MS").ok()
            .and_then(|s| s.parse::<u64>().ok())
            .filter(|&ms| ms >= 1)
        {
            cfg.slo = Some(Duration::from_millis(ms));
        }
        cfg.scale_decode = std::env::var("HELIX_AUTOSCALE_DECODE")
            .is_ok_and(|v| v == "1" || v == "true");
        cfg.scale_vote = std::env::var("HELIX_AUTOSCALE_VOTE")
            .is_ok_and(|v| v == "1" || v == "true");
        cfg.scale_analysis = std::env::var("HELIX_AUTOSCALE_ANALYSIS")
            .is_ok_and(|v| v == "1" || v == "true");
        if let Some(n) = std::env::var("HELIX_HQ_MIN_SHARDS").ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
        {
            cfg.hq_min_shards = n;
        }
        if let Some(n) = std::env::var("HELIX_HQ_MAX_SHARDS").ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
        {
            cfg.hq_max_shards = n;
        }
        Some(cfg.normalized())
    }
}

/// One control-loop observation of a stage pool.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// live slot count when the sample was taken.
    pub live: usize,
    /// mean per-live-slot busy fraction over the last tick (0–1).
    pub mean_util: f64,
    /// input-queue occupancy fraction (0–1): the stage's backpressure
    /// point. A saturated queue is treated as hot even when worker
    /// utilization reads low (e.g. the tick landed between batches),
    /// because blocked producers are the symptom the autoscaler exists
    /// to fix.
    pub backlog: f64,
    /// p99 of per-read end-to-end latency over the completions of the
    /// last tick, in µs (0 = no completions this tick, i.e. no
    /// signal). Compared against `AutoscaleConfig::slo` when set.
    pub p99_micros: u64,
}

/// What the controller wants done after an observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// spawn one more worker (pool below its ceiling and hot).
    ScaleUp,
    /// retire one worker (pool above its floor and cold).
    ScaleDown,
    /// leave the pool alone.
    Hold,
}

/// Pure decision core: feed it one `Sample` per tick, act on the
/// returned `Decision`. Holds only the hysteresis state (hot/cold
/// streak lengths and the post-event cooldown), so identical traces
/// always produce identical decision sequences. Each controlled stage
/// gets its own `Controller` (with that stage's bounds in the config),
/// all fed from the same sampling pass.
pub struct Controller {
    cfg: AutoscaleConfig,
    hot_streak: u32,
    cold_streak: u32,
    cooldown: u32,
}

impl Controller {
    /// Controller with fresh hysteresis state (cfg is normalized here).
    pub fn new(cfg: AutoscaleConfig) -> Controller {
        Controller {
            cfg: cfg.normalized(),
            hot_streak: 0,
            cold_streak: 0,
            cooldown: 0,
        }
    }

    /// Observe one tick and decide. Hysteresis rules:
    /// * during cooldown, always `Hold` (and streaks reset, so the
    ///   post-resize transient cannot count toward the next event);
    /// * a *hot* tick (mean util above `high_util`, or the input
    ///   queue ≥95% full, or — with an SLO configured — interval p99
    ///   over the SLO) extends the hot streak and resets the cold one
    ///   — and vice versa for *cold* (util below `low_util` while the
    ///   backlog is under half; an SLO breach vetoes cold via hot); a
    ///   tick that is neither resets both, which is what stops
    ///   threshold oscillation from ever accumulating a streak (no
    ///   flapping);
    /// * `ScaleUp` needs `up_ticks` consecutive hot ticks and headroom
    ///   below `max_shards`; `ScaleDown` needs `down_ticks` cold ticks
    ///   and slack above `min_shards`; both start the cooldown.
    pub fn observe(&mut self, s: Sample) -> Decision {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            self.hot_streak = 0;
            self.cold_streak = 0;
            return Decision::Hold;
        }
        let slo_breach = self.cfg.slo
            .is_some_and(|slo| s.p99_micros > 0
                         && s.p99_micros as u128 > slo.as_micros());
        let hot = s.mean_util > self.cfg.high_util
            || s.backlog >= 0.95
            || slo_breach;
        let cold = !hot
            && s.mean_util < self.cfg.low_util
            && s.backlog < 0.5;
        if hot {
            self.hot_streak += 1;
            self.cold_streak = 0;
        } else if cold {
            self.cold_streak += 1;
            self.hot_streak = 0;
        } else {
            self.hot_streak = 0;
            self.cold_streak = 0;
        }
        if hot && self.hot_streak >= self.cfg.up_ticks
            && s.live < self.cfg.max_shards
        {
            self.hot_streak = 0;
            self.cooldown = self.cfg.cooldown_ticks;
            return Decision::ScaleUp;
        }
        if cold && self.cold_streak >= self.cfg.down_ticks
            && s.live > self.cfg.min_shards
        {
            self.cold_streak = 0;
            self.cooldown = self.cfg.cooldown_ticks;
            return Decision::ScaleDown;
        }
        Decision::Hold
    }
}

/// What the control loop needs from a resizable stage pool. The DNN
/// shard host implements it over backend replicas (factory-built); the
/// decode/vote pools implement it through [`WorkerPool`] (cheap thread
/// respawns). Kept as a trait so the loop — and its failure modes —
/// can be exercised against a fake pool without spinning up backends.
pub trait StagePool: Send + Sync {
    /// total slot count (== the stage's ceiling).
    fn slots(&self) -> usize;
    /// slot ids with a live worker, ascending.
    fn live_slots(&self) -> Vec<usize>;
    /// cumulative busy-micros of the slot's worker.
    fn busy_micros(&self, slot: usize) -> u64;
    /// input-queue occupancy fraction (0–1).
    fn backlog(&self) -> f64;
    /// spawn a worker into a free slot; `None` when no slot is free.
    fn scale_up(&self) -> Option<usize>;
    /// retire the slot's worker (close its queue). `false` if already
    /// free.
    fn retire(&self, slot: usize) -> bool;
}

/// Thread-spawning callback for a [`WorkerPool`] slot: given the slot
/// id and the slot's queue receiver, start the worker thread.
pub type SpawnWorker<T> =
    Box<dyn Fn(usize, Receiver<T>) -> JoinHandle<()> + Send + Sync>;

/// A resizable pool of cheap worker threads (CTC decode, vote/splice)
/// behind a [`QueueSet`]: the same slot mechanics as the DNN shard
/// host — stable slot ids indexing per-slot `StageStats`, retire by
/// closing the slot's queue so the worker drains and exits through the
/// skip-dead dispatch path — minus the backend factory, because a
/// decode or vote worker is a plain thread the spawn callback can
/// recreate at will. Producers dispatch through `queues()` and never
/// observe membership edits.
pub struct WorkerPool<T> {
    stage: StageId,
    metrics: Arc<Metrics>,
    queues: Arc<QueueSet<T>>,
    per_worker_cap: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
    spawn: SpawnWorker<T>,
}

impl<T: Send> WorkerPool<T> {
    /// Build the pool and spawn a worker into every one of its `slots`
    /// (the stage starts at full configured width; the controller can
    /// then retire down to its floor and respawn back up). `stage`
    /// selects which of `Metrics::decode_workers` /
    /// `Metrics::vote_workers` the per-slot counters land in; a
    /// `Metrics` without slots for this stage (e.g. `default()`)
    /// simply records no per-slot stats.
    pub fn new(stage: StageId, metrics: Arc<Metrics>, slots: usize,
               per_worker_cap: usize, spawn: SpawnWorker<T>)
               -> Arc<WorkerPool<T>> {
        let pool = Arc::new(WorkerPool {
            stage,
            metrics,
            queues: Arc::new(QueueSet::with_slots(slots.max(1))),
            per_worker_cap: per_worker_cap.max(1),
            handles: Mutex::new(Vec::new()),
            spawn,
        });
        for _ in 0..slots.max(1) {
            let _ = pool.scale_up(); // a fresh set has a slot per worker
        }
        pool
    }

    fn stats(&self, slot: usize) -> Option<&StageStats> {
        match self.stage {
            StageId::Decode => self.metrics.decode_workers.get(slot),
            StageId::Vote => self.metrics.vote_workers.get(slot),
            StageId::Analysis =>
                self.metrics.analysis_workers.get(slot),
            // DNN slots live in Metrics::shards / Metrics::hq_shards
            StageId::Dnn | StageId::DnnHq => None,
        }
    }

    /// The queue set producers dispatch through (clone the `Arc`;
    /// membership edits stay invisible to dispatch).
    pub fn queues(&self) -> Arc<QueueSet<T>> {
        self.queues.clone()
    }

    /// Workers live right now.
    pub fn live_count(&self) -> usize {
        self.queues.live_count()
    }

    /// Take every worker `JoinHandle` spawned so far (for joining at
    /// shutdown). Call only after the controller is stopped, so no new
    /// handle can appear afterwards.
    pub fn take_handles(&self) -> Vec<JoinHandle<()>> {
        self.handles.lock().unwrap().drain(..).collect()
    }
}

impl<T: Send> StagePool for WorkerPool<T> {
    fn slots(&self) -> usize {
        self.queues.slots()
    }

    fn live_slots(&self) -> Vec<usize> {
        self.queues.live_slots()
    }

    fn busy_micros(&self, slot: usize) -> u64 {
        self.stats(slot).map_or(0, |s| {
            s.busy_micros.load(std::sync::atomic::Ordering::Relaxed)
        })
    }

    fn backlog(&self) -> f64 {
        self.queues.occupancy()
    }

    fn scale_up(&self) -> Option<usize> {
        // add() fails once the set is sealed (shutdown), so a racing
        // scale-up can never install a queue nobody will close
        let (tx, rx) = bounded::<T>(self.per_worker_cap);
        let slot = self.queues.add(tx)?;
        if let Some(st) = self.stats(slot) {
            st.mark_spawned(self.metrics.epoch_micros());
        }
        let handle = (self.spawn)(slot, rx);
        self.handles.lock().unwrap().push(handle);
        Some(slot)
    }

    fn retire(&self, slot: usize) -> bool {
        if self.queues.retire(slot) {
            if let Some(st) = self.stats(slot) {
                st.mark_retired(self.metrics.epoch_micros());
            }
            true
        } else {
            false
        }
    }
}

/// One stage under the controller: its pool, identity, and bounds.
/// The bounds override the config's `min_shards`/`max_shards` for this
/// stage (the DNN stage passes those through; decode/vote pass
/// `1..=configured width`).
pub struct StageControl {
    /// which stage this is (tags its scale events and report rows).
    pub stage: StageId,
    /// the pool the controller sizes.
    pub pool: Arc<dyn StagePool>,
    /// floor on live workers for this stage.
    pub min: usize,
    /// ceiling on live workers for this stage.
    pub max: usize,
}

struct StageState {
    ctl: Controller,
    prev_busy: Vec<u64>,
}

/// Time source for the control loop's tick-wall measurement. The
/// controller never reads the system clock directly (helix-lint denies
/// a bare `Instant::now()` inside tick logic): production passes
/// [`SampleClock::system`], tests inject a deterministic function via
/// [`SampleClock::from_fn`] so utilization math is reproducible.
#[derive(Clone, Copy)]
pub struct SampleClock(fn() -> Instant);

impl SampleClock {
    /// The real monotonic clock.
    pub fn system() -> SampleClock {
        SampleClock(Instant::now)
    }

    /// A caller-supplied time source (deterministic tests).
    pub fn from_fn(f: fn() -> Instant) -> SampleClock {
        SampleClock(f)
    }

    fn now(&self) -> Instant {
        (self.0)()
    }
}

impl Default for SampleClock {
    fn default() -> SampleClock {
        SampleClock::system()
    }
}

/// The control loop the coordinator spawns when
/// `CoordinatorConfig::autoscale` is set: sample → decide → act for
/// every controlled stage, once per `cfg.tick`, until `stop` is
/// signalled (or its sender drops) or the primary pool collapses.
/// `stages[0]` is the primary (DNN) pool — the loop exits when it has
/// no live slot, because a pipeline without its hot stage is dead.
/// Each stage runs its own hysteresis `Controller` (bounds from its
/// `StageControl`), all fed the same shared interval-p99 signal from
/// `metrics.read_latency` snapshots. Scale events are appended to
/// `metrics.scale_events()` tagged with the stage; the scale-down
/// victim is the live slot with the smallest busy-delta this tick
/// (ties retire the highest slot id, keeping slot 0 — the tail-batch
/// magnet — alive longest).
pub fn run(stages: &[StageControl], cfg: AutoscaleConfig,
           metrics: &Metrics, stop: &Receiver<()>) {
    run_with_clock(stages, cfg, metrics, stop, SampleClock::system());
}

/// [`run`] with an injected [`SampleClock`], the seam deterministic
/// tests use to pin the tick-wall arithmetic without sleeping.
pub fn run_with_clock(stages: &[StageControl], cfg: AutoscaleConfig,
                      metrics: &Metrics, stop: &Receiver<()>,
                      clock: SampleClock) {
    let cfg = cfg.normalized();
    if stages.is_empty() {
        return;
    }
    let mut states: Vec<StageState> = stages.iter()
        .map(|st| StageState {
            ctl: Controller::new(AutoscaleConfig {
                min_shards: st.min,
                max_shards: st.max,
                ..cfg
            }),
            prev_busy: (0..st.pool.slots())
                .map(|s| st.pool.busy_micros(s))
                .collect(),
        })
        .collect();
    let mut prev_lat = metrics.read_latency.snapshot();
    let mut last = clock.now();
    loop {
        match stop.recv_timeout(cfg.tick) {
            Err(RecvTimeoutError::Timeout) => {}
            // explicit stop or the coordinator dropped the stop sender
            Ok(()) | Err(RecvTimeoutError::Disconnected) => return,
        }
        let now = clock.now();
        let wall = now.duration_since(last).as_micros().max(1) as f64;
        last = now;
        // shared latency signal: p99 of the reads completed this tick
        let cur_lat = metrics.read_latency.snapshot();
        let p99_micros = cur_lat.quantile_since(&prev_lat, 0.99);
        prev_lat = cur_lat;
        if stages[0].pool.live_slots().is_empty() {
            return; // every primary replica failed: pipeline is dead
        }
        for (st, state) in stages.iter().zip(states.iter_mut()) {
            let live = st.pool.live_slots();
            if live.is_empty() {
                continue; // nothing to control (and nothing to retire)
            }
            let mut utils: Vec<(usize, f64)> =
                Vec::with_capacity(live.len());
            for &slot in &live {
                let busy = st.pool.busy_micros(slot);
                let delta = busy.saturating_sub(state.prev_busy[slot]);
                state.prev_busy[slot] = busy;
                utils.push((slot, (delta as f64 / wall).min(1.0)));
            }
            let mean_util = utils.iter().map(|(_, u)| *u).sum::<f64>()
                / utils.len() as f64;
            let sample = Sample {
                live: live.len(),
                mean_util,
                backlog: st.pool.backlog().clamp(0.0, 1.0),
                p99_micros,
            };
            match state.ctl.observe(sample) {
                Decision::ScaleUp => {
                    if let Some(slot) = st.pool.scale_up() {
                        // refresh the baseline so a recycled slot's old
                        // cumulative count does not read as a burst
                        state.prev_busy[slot] = st.pool.busy_micros(slot);
                        metrics.record_scale(st.stage, ScaleAction::Up,
                                             slot,
                                             st.pool.live_slots().len());
                    }
                }
                Decision::ScaleDown => {
                    let mut victim = utils[0];
                    for &(slot, u) in &utils[1..] {
                        if u < victim.1
                            || (u <= victim.1 && slot > victim.0)
                        {
                            victim = (slot, u);
                        }
                    }
                    if st.pool.retire(victim.0) {
                        metrics.record_scale(st.stage, ScaleAction::Down,
                                             victim.0,
                                             st.pool.live_slots().len());
                    }
                }
                Decision::Hold => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn fast_cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            high_util: 0.75,
            low_util: 0.25,
            up_ticks: 2,
            down_ticks: 3,
            cooldown_ticks: 1,
            ..AutoscaleConfig::default()
        }
    }

    fn s(live: usize, util: f64) -> Sample {
        Sample { live, mean_util: util, backlog: 0.0, p99_micros: 0 }
    }

    #[test]
    fn sample_clock_is_injectable_and_frozen_time_stands_still() {
        fn frozen() -> Instant {
            static BASE: std::sync::OnceLock<Instant> =
                std::sync::OnceLock::new();
            *BASE.get_or_init(Instant::now)
        }
        let clock = SampleClock::from_fn(frozen);
        let a = clock.now();
        let b = clock.now();
        assert_eq!(b.duration_since(a), Duration::ZERO,
                   "injected clock must be fully caller-controlled");
        let sys = SampleClock::default();
        let c = sys.now();
        assert!(sys.now() >= c, "system source stays monotonic");
    }

    #[test]
    fn normalized_clamps_degenerate_config() {
        let c = AutoscaleConfig {
            min_shards: 0,
            max_shards: 0,
            tick: Duration::ZERO,
            high_util: 0.3,
            low_util: 0.9, // inverted
            up_ticks: 0,
            down_ticks: 0,
            cooldown_ticks: 0,
            slo: Some(Duration::ZERO), // degenerate: every read breaches
            ..AutoscaleConfig::default()
        }.normalized();
        assert_eq!(c.min_shards, 1);
        assert_eq!(c.max_shards, 1);
        assert!(!c.tick.is_zero());
        assert!(c.low_util <= c.high_util);
        assert_eq!(c.up_ticks, 1);
        assert_eq!(c.down_ticks, 1);
        assert_eq!(c.slo, None, "a zero SLO is dropped, not enforced");
        assert_eq!(c.hq_min_shards, 1, "hq floor defaults to 1");
        assert_eq!(c.hq_max_shards, c.max_shards,
                   "hq ceiling follows max_shards when unset");
        // hq bounds clamp like the fast bounds do
        let hq = AutoscaleConfig {
            max_shards: 4,
            hq_min_shards: 3,
            hq_max_shards: 2, // inverted: ceiling follows floor
            ..AutoscaleConfig::default()
        }.normalized();
        assert_eq!(hq.hq_min_shards, 3);
        assert_eq!(hq.hq_max_shards, 3);
        // min above max: max follows min
        let c2 = AutoscaleConfig {
            min_shards: 8,
            max_shards: 2,
            ..AutoscaleConfig::default()
        }.normalized();
        assert_eq!(c2.min_shards, 8);
        assert_eq!(c2.max_shards, 8);
    }

    #[test]
    fn saturation_trace_scales_up_after_streak() {
        let mut ctl = Controller::new(fast_cfg());
        // tick 1 hot: streak too short
        assert_eq!(ctl.observe(s(1, 0.95)), Decision::Hold);
        // tick 2 hot: streak reached -> up
        assert_eq!(ctl.observe(s(1, 0.98)), Decision::ScaleUp);
        // cooldown tick holds even though still saturated
        assert_eq!(ctl.observe(s(2, 0.97)), Decision::Hold);
        // streak rebuilds after cooldown
        assert_eq!(ctl.observe(s(2, 0.96)), Decision::Hold);
        assert_eq!(ctl.observe(s(2, 0.99)), Decision::ScaleUp);
    }

    #[test]
    fn saturated_backlog_counts_as_hot_even_with_idle_shards() {
        let mut ctl = Controller::new(fast_cfg());
        // shards read idle (tick landed between batches) but submit()
        // is blocked on a full window queue: that is saturation
        let jam = Sample {
            live: 1, mean_util: 0.0, backlog: 1.0, p99_micros: 0,
        };
        assert_eq!(ctl.observe(jam), Decision::Hold);
        assert_eq!(ctl.observe(jam), Decision::ScaleUp);
    }

    #[test]
    fn slo_breach_counts_as_hot_at_zero_utilization() {
        // THE tentpole scenario: a latency-sensitive trickle load —
        // utilization and backlog both ~0, but the reads that did
        // complete this tick blew the p99 budget. Utilization-only
        // control would call this idle (and even scale DOWN); with an
        // SLO the tick is hot and the pool grows.
        let mut ctl = Controller::new(AutoscaleConfig {
            slo: Some(Duration::from_millis(10)),
            ..fast_cfg()
        });
        let breach = Sample {
            live: 1, mean_util: 0.0, backlog: 0.0, p99_micros: 50_000,
        };
        assert_eq!(ctl.observe(breach), Decision::Hold);
        assert_eq!(ctl.observe(breach), Decision::ScaleUp);
    }

    #[test]
    fn slo_breach_vetoes_scale_down() {
        // cold utilization + breached SLO must never shrink the pool
        let mut ctl = Controller::new(AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            high_util: 0.75,
            low_util: 0.25,
            up_ticks: 100, // never actually scale up in this test
            down_ticks: 2,
            cooldown_ticks: 0,
            slo: Some(Duration::from_millis(10)),
            ..AutoscaleConfig::default()
        });
        let breach = Sample {
            live: 3, mean_util: 0.01, backlog: 0.0, p99_micros: 90_000,
        };
        for _ in 0..20 {
            assert_eq!(ctl.observe(breach), Decision::Hold,
                       "breached SLO must veto cold ticks");
        }
        // same trace with p99 inside the budget: scales down normally
        let ok = Sample {
            live: 3, mean_util: 0.01, backlog: 0.0, p99_micros: 2_000,
        };
        assert_eq!(ctl.observe(ok), Decision::Hold);
        assert_eq!(ctl.observe(ok), Decision::ScaleDown);
    }

    #[test]
    fn empty_interval_p99_is_no_signal() {
        // p99_micros == 0 means "no completions this tick", which must
        // not read as an SLO breach (nor veto a cold streak)
        let mut ctl = Controller::new(AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            high_util: 0.75,
            low_util: 0.25,
            up_ticks: 1,
            down_ticks: 2,
            cooldown_ticks: 0,
            slo: Some(Duration::from_millis(10)),
            ..AutoscaleConfig::default()
        });
        let idle = Sample {
            live: 2, mean_util: 0.0, backlog: 0.0, p99_micros: 0,
        };
        assert_eq!(ctl.observe(idle), Decision::Hold);
        assert_eq!(ctl.observe(idle), Decision::ScaleDown);
    }

    #[test]
    fn idle_trace_scales_down_after_longer_streak() {
        let mut ctl = Controller::new(fast_cfg());
        assert_eq!(ctl.observe(s(3, 0.05)), Decision::Hold);
        assert_eq!(ctl.observe(s(3, 0.02)), Decision::Hold);
        assert_eq!(ctl.observe(s(3, 0.04)), Decision::ScaleDown);
        // cooldown, then the streak must rebuild from zero
        assert_eq!(ctl.observe(s(2, 0.01)), Decision::Hold);
        assert_eq!(ctl.observe(s(2, 0.01)), Decision::Hold);
        assert_eq!(ctl.observe(s(2, 0.02)), Decision::Hold);
        assert_eq!(ctl.observe(s(2, 0.03)), Decision::ScaleDown);
    }

    #[test]
    fn oscillation_around_threshold_never_flaps() {
        // utilization bouncing across high_util every other tick: the
        // neither-hot-nor-cold ticks reset the streak, so a controller
        // needing 2 consecutive hot ticks must never fire.
        let mut ctl = Controller::new(fast_cfg());
        for _ in 0..50 {
            assert_eq!(ctl.observe(s(2, 0.80)), Decision::Hold); // hot
            assert_eq!(ctl.observe(s(2, 0.50)), Decision::Hold); // mid
        }
        // same story around low_util: cold streaks keep resetting
        for _ in 0..50 {
            assert_eq!(ctl.observe(s(2, 0.20)), Decision::Hold); // cold
            assert_eq!(ctl.observe(s(2, 0.50)), Decision::Hold); // mid
        }
    }

    #[test]
    fn bounds_cap_scaling_in_both_directions() {
        let mut ctl = Controller::new(fast_cfg());
        // at max_shards even a sustained-hot trace holds
        for _ in 0..10 {
            assert_eq!(ctl.observe(s(4, 1.0)), Decision::Hold,
                       "must not scale past max_shards");
        }
        // at min_shards even a sustained-cold trace holds
        let mut ctl = Controller::new(fast_cfg());
        for _ in 0..10 {
            assert_eq!(ctl.observe(s(1, 0.0)), Decision::Hold,
                       "must not retire below min_shards");
        }
    }

    #[test]
    fn backlogged_cold_utilization_does_not_scale_down() {
        // util is low but the window queue is half-full-or-more: work
        // is arriving faster than batches launch, so shrinking now
        // would amplify the jam. Cold requires an empty-ish backlog.
        let mut ctl = Controller::new(fast_cfg());
        let draining = Sample {
            live: 3, mean_util: 0.1, backlog: 0.6, p99_micros: 0,
        };
        for _ in 0..10 {
            assert_eq!(ctl.observe(draining), Decision::Hold);
        }
    }

    #[test]
    fn worker_pool_scales_and_retires_through_stage_pool() {
        // the WorkerPool implements the same StagePool contract the
        // DNN host does: spawn into the lowest free slot, retire by
        // closing the queue, per-slot stats with lifecycle marks
        let m = Arc::new(Metrics::for_pipeline(1, 3, 1));
        let pool = WorkerPool::<u32>::new(
            StageId::Decode, m.clone(), 3, 4,
            Box::new(|_slot, rx: Receiver<u32>| {
                std::thread::spawn(move || {
                    while rx.recv().is_ok() {}
                })
            }));
        assert_eq!(pool.slots(), 3);
        assert_eq!(pool.live_slots(), vec![0, 1, 2]);
        assert_eq!(pool.live_count(), 3);
        assert!(m.decode_workers.iter().all(|s| s.is_live()));
        // retire slot 2: the worker drains its queue and exits
        assert!(pool.retire(2));
        assert!(!pool.retire(2), "double retire reports already-free");
        assert_eq!(pool.live_slots(), vec![0, 1]);
        assert!(!m.decode_workers[2].is_live());
        // respawn recycles the freed slot (generation 2)
        assert_eq!(pool.scale_up(), Some(2));
        assert_eq!(m.decode_workers[2].spawns.load(Ordering::Relaxed), 2);
        assert!(m.decode_workers[2].is_live());
        // dispatch reaches the live workers
        let mut rr = 0;
        let q = pool.queues();
        assert!(q.send_round_robin(&mut rr, 7));
        // shutdown: seal the set, workers drain out, handles join
        q.close_all();
        for h in pool.take_handles() {
            h.join().unwrap();
        }
        assert_eq!(pool.live_count(), 0);
        assert!(pool.scale_up().is_none(), "sealed set refuses spawns");
    }
}
