//! Adaptive shard autoscaling: a control loop that sizes the DNN
//! executor pool from *observed* utilization instead of a startup
//! constant.
//!
//! The paper's throughput claim rests on keeping every compute array
//! busy; the serving-side analogue is keeping every backend replica
//! busy without parking idle ones on cores the decode/vote pools could
//! use. A fixed `dnn_shards` forces the operator to guess that balance
//! per workload. This module closes the loop instead:
//!
//! ```text
//!        every `tick`
//!   ┌───────────────────────────────────────────────────────────┐
//!   │  SAMPLE   per-live-shard busy-micros delta / tick wall    │
//!   │           + window-queue backlog fraction                 │
//!   │                         │                                 │
//!   │                         ▼                                 │
//!   │  DECIDE   Controller::observe — hysteresis (consecutive   │
//!   │           hot/cold ticks + post-event cooldown) around    │
//!   │           high_util / low_util thresholds                 │
//!   │                 │               │                         │
//!   │            ScaleUp          ScaleDown                     │
//!   │                 ▼               ▼                         │
//!   │  ACT      spawn replica     retire the least-busy shard   │
//!   │           into a free       (drop its queue sender; the   │
//!   │           slot (factory     shard drains what is staged   │
//!   │           clone / late      and exits — the same skip-    │
//!   │           open_shard)       dead path a crash takes)      │
//!   └───────────────────────────────────────────────────────────┘
//! ```
//!
//! **Determinism contract:** scaling changes *when* windows run and on
//! *which* replica — never what they produce. Every replica computes
//! bit-identical `LogProbs` for a given window and the collector
//! reassembles by `(read_id, window_idx)`, so a run under the
//! autoscaler calls byte-identical reads to a fixed-shard run over the
//! same input (integration-pinned in `tests/coordinator_stream.rs`).
//!
//! The decision core (`Controller`) is a pure function of the sampled
//! trace — no threads, no clocks — so the unit tests below drive it
//! with synthetic utilization traces: saturation must scale up,
//! idleness must scale down, and oscillation around a threshold must
//! NOT flap.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::{Metrics, ScaleAction};
use crate::util::bounded::{Receiver, RecvTimeoutError};

/// Tuning knobs for the adaptive shard controller. Construct with
/// struct-update syntax over `Default::default()` (or `from_env`) and
/// pass via `CoordinatorConfig::autoscale`; `normalized()` is applied
/// before use so inverted bounds cannot wedge the pool.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// floor on live shards; the controller never retires below this.
    pub min_shards: usize,
    /// ceiling on live shards; also the slot count (`Metrics::shards`
    /// length) the pipeline pre-allocates.
    pub max_shards: usize,
    /// control-loop sampling period.
    pub tick: Duration,
    /// mean live-shard utilization above which a tick counts as *hot*.
    pub high_util: f64,
    /// mean live-shard utilization below which a tick counts as *cold*.
    pub low_util: f64,
    /// consecutive hot ticks required before scaling up (hysteresis).
    pub up_ticks: u32,
    /// consecutive cold ticks required before scaling down
    /// (hysteresis; larger than `up_ticks` by default so the pool
    /// grows eagerly and shrinks reluctantly).
    pub down_ticks: u32,
    /// ticks to hold after any scale event before reconsidering, so
    /// the pool's reaction to its own resize settles into the samples.
    pub cooldown_ticks: u32,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            tick: Duration::from_millis(50),
            high_util: 0.75,
            low_util: 0.20,
            up_ticks: 2,
            down_ticks: 4,
            cooldown_ticks: 2,
        }
    }
}

impl AutoscaleConfig {
    /// Clamp the knobs into a usable shape: bounds at least 1 with
    /// `max >= min`, a non-zero tick, threshold order `low <= high`,
    /// and streak lengths of at least one tick.
    pub fn normalized(mut self) -> AutoscaleConfig {
        self.min_shards = self.min_shards.max(1);
        self.max_shards = self.max_shards.max(self.min_shards);
        if self.tick.is_zero() {
            self.tick = Duration::from_millis(1);
        }
        if self.low_util > self.high_util {
            self.low_util = self.high_util;
        }
        self.up_ticks = self.up_ticks.max(1);
        self.down_ticks = self.down_ticks.max(1);
        self
    }

    /// Autoscaling selected by environment: enabled iff
    /// `HELIX_MAX_SHARDS` parses to a positive shard ceiling;
    /// `HELIX_MIN_SHARDS` and `HELIX_AUTOSCALE_TICK_MS` then refine
    /// the floor and the sampling period (unparsable values keep the
    /// defaults). Returns `None` — autoscaling off — otherwise.
    pub fn from_env() -> Option<AutoscaleConfig> {
        let max = std::env::var("HELIX_MAX_SHARDS").ok()?
            .parse::<usize>().ok()
            .filter(|&n| n >= 1)?;
        let mut cfg = AutoscaleConfig {
            max_shards: max,
            ..AutoscaleConfig::default()
        };
        if let Some(n) = std::env::var("HELIX_MIN_SHARDS").ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
        {
            cfg.min_shards = n;
        }
        if let Some(ms) = std::env::var("HELIX_AUTOSCALE_TICK_MS").ok()
            .and_then(|s| s.parse::<u64>().ok())
            .filter(|&ms| ms >= 1)
        {
            cfg.tick = Duration::from_millis(ms);
        }
        Some(cfg.normalized())
    }
}

/// One control-loop observation of the pool.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// live shard count when the sample was taken.
    pub live: usize,
    /// mean per-live-shard busy fraction over the last tick (0–1).
    pub mean_util: f64,
    /// window-queue occupancy fraction (0–1): the pipeline's
    /// backpressure point. A saturated window queue is treated as hot
    /// even when shard utilization reads low (e.g. the tick landed
    /// between batches), because blocked `submit()` callers are the
    /// symptom the autoscaler exists to fix.
    pub backlog: f64,
}

/// What the controller wants done after an observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// spawn one more shard (pool below `max_shards` and hot).
    ScaleUp,
    /// retire one shard (pool above `min_shards` and cold).
    ScaleDown,
    /// leave the pool alone.
    Hold,
}

/// Pure decision core: feed it one `Sample` per tick, act on the
/// returned `Decision`. Holds only the hysteresis state (hot/cold
/// streak lengths and the post-event cooldown), so identical traces
/// always produce identical decision sequences.
pub struct Controller {
    cfg: AutoscaleConfig,
    hot_streak: u32,
    cold_streak: u32,
    cooldown: u32,
}

impl Controller {
    /// Controller with fresh hysteresis state (cfg is normalized here).
    pub fn new(cfg: AutoscaleConfig) -> Controller {
        Controller {
            cfg: cfg.normalized(),
            hot_streak: 0,
            cold_streak: 0,
            cooldown: 0,
        }
    }

    /// Observe one tick and decide. Hysteresis rules:
    /// * during cooldown, always `Hold` (and streaks reset, so the
    ///   post-resize transient cannot count toward the next event);
    /// * a *hot* tick (mean util above `high_util`, or the window
    ///   queue ≥95% full) extends the hot streak and resets the cold
    ///   one — and vice versa for *cold* (util below `low_util` while
    ///   the backlog is under half); a tick that is neither resets
    ///   both, which is what stops threshold oscillation from ever
    ///   accumulating a streak (no flapping);
    /// * `ScaleUp` needs `up_ticks` consecutive hot ticks and headroom
    ///   below `max_shards`; `ScaleDown` needs `down_ticks` cold ticks
    ///   and slack above `min_shards`; both start the cooldown.
    pub fn observe(&mut self, s: Sample) -> Decision {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            self.hot_streak = 0;
            self.cold_streak = 0;
            return Decision::Hold;
        }
        let hot = s.mean_util > self.cfg.high_util || s.backlog >= 0.95;
        let cold = !hot
            && s.mean_util < self.cfg.low_util
            && s.backlog < 0.5;
        if hot {
            self.hot_streak += 1;
            self.cold_streak = 0;
        } else if cold {
            self.cold_streak += 1;
            self.hot_streak = 0;
        } else {
            self.hot_streak = 0;
            self.cold_streak = 0;
        }
        if hot && self.hot_streak >= self.cfg.up_ticks
            && s.live < self.cfg.max_shards
        {
            self.hot_streak = 0;
            self.cooldown = self.cfg.cooldown_ticks;
            return Decision::ScaleUp;
        }
        if cold && self.cold_streak >= self.cfg.down_ticks
            && s.live > self.cfg.min_shards
        {
            self.cold_streak = 0;
            self.cooldown = self.cfg.cooldown_ticks;
            return Decision::ScaleDown;
        }
        Decision::Hold
    }
}

/// What the control loop needs from the shard-pool host. Implemented
/// by the coordinator's pool internals; kept as a trait so the loop —
/// and its failure modes — can be exercised against a fake pool
/// without spinning up backends.
pub trait ShardPool: Send + Sync {
    /// total slot count (== `max_shards`).
    fn slots(&self) -> usize;
    /// slot ids with a live shard, ascending.
    fn live_slots(&self) -> Vec<usize>;
    /// cumulative forward-pass busy-micros of the slot's shard.
    fn busy_micros(&self, slot: usize) -> u64;
    /// window-queue occupancy fraction (0–1).
    fn backlog(&self) -> f64;
    /// spawn a shard into a free slot; `None` when no slot is free.
    fn scale_up(&self) -> Option<usize>;
    /// retire the slot's shard (close its queue). `false` if already
    /// free.
    fn retire(&self, slot: usize) -> bool;
}

/// The control loop the coordinator spawns when
/// `CoordinatorConfig::autoscale` is set: sample → decide → act, every
/// `cfg.tick`, until `stop` is signalled (or its sender drops) or the
/// pool collapses. Scale-up/-down events are appended to
/// `metrics.scale_events()`; the scale-down victim is the live shard
/// with the smallest busy-delta this tick (ties retire the highest
/// slot id, keeping slot 0 — the tail-batch magnet — alive longest).
pub fn run(pool: Arc<dyn ShardPool>, cfg: AutoscaleConfig,
           metrics: Arc<Metrics>, stop: Receiver<()>) {
    let cfg = cfg.normalized();
    let mut ctl = Controller::new(cfg);
    let n_slots = pool.slots();
    let mut prev_busy: Vec<u64> =
        (0..n_slots).map(|s| pool.busy_micros(s)).collect();
    let mut last = Instant::now();
    loop {
        match stop.recv_timeout(cfg.tick) {
            Err(RecvTimeoutError::Timeout) => {}
            // explicit stop or the coordinator dropped the stop sender
            Ok(()) | Err(RecvTimeoutError::Disconnected) => return,
        }
        let now = Instant::now();
        let wall = now.duration_since(last).as_micros().max(1) as f64;
        last = now;
        let live = pool.live_slots();
        if live.is_empty() {
            return; // every replica failed: nothing left to control
        }
        let mut utils: Vec<(usize, f64)> = Vec::with_capacity(live.len());
        for &slot in &live {
            let busy = pool.busy_micros(slot);
            let delta = busy.saturating_sub(prev_busy[slot]);
            prev_busy[slot] = busy;
            utils.push((slot, (delta as f64 / wall).min(1.0)));
        }
        let mean_util = utils.iter().map(|(_, u)| *u).sum::<f64>()
            / utils.len() as f64;
        let sample = Sample {
            live: live.len(),
            mean_util,
            backlog: pool.backlog().clamp(0.0, 1.0),
        };
        match ctl.observe(sample) {
            Decision::ScaleUp => {
                if let Some(slot) = pool.scale_up() {
                    // refresh the baseline so a recycled slot's old
                    // cumulative count does not read as a burst
                    prev_busy[slot] = pool.busy_micros(slot);
                    metrics.record_scale(ScaleAction::Up, slot,
                                         pool.live_slots().len());
                }
            }
            Decision::ScaleDown => {
                let mut victim = utils[0];
                for &(slot, u) in &utils[1..] {
                    if u < victim.1 || (u <= victim.1 && slot > victim.0) {
                        victim = (slot, u);
                    }
                }
                if pool.retire(victim.0) {
                    metrics.record_scale(ScaleAction::Down, victim.0,
                                         pool.live_slots().len());
                }
            }
            Decision::Hold => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            high_util: 0.75,
            low_util: 0.25,
            up_ticks: 2,
            down_ticks: 3,
            cooldown_ticks: 1,
            ..AutoscaleConfig::default()
        }
    }

    fn s(live: usize, util: f64) -> Sample {
        Sample { live, mean_util: util, backlog: 0.0 }
    }

    #[test]
    fn normalized_clamps_degenerate_config() {
        let c = AutoscaleConfig {
            min_shards: 0,
            max_shards: 0,
            tick: Duration::ZERO,
            high_util: 0.3,
            low_util: 0.9, // inverted
            up_ticks: 0,
            down_ticks: 0,
            cooldown_ticks: 0,
        }.normalized();
        assert_eq!(c.min_shards, 1);
        assert_eq!(c.max_shards, 1);
        assert!(!c.tick.is_zero());
        assert!(c.low_util <= c.high_util);
        assert_eq!(c.up_ticks, 1);
        assert_eq!(c.down_ticks, 1);
        // min above max: max follows min
        let c2 = AutoscaleConfig {
            min_shards: 8,
            max_shards: 2,
            ..AutoscaleConfig::default()
        }.normalized();
        assert_eq!(c2.min_shards, 8);
        assert_eq!(c2.max_shards, 8);
    }

    #[test]
    fn saturation_trace_scales_up_after_streak() {
        let mut ctl = Controller::new(fast_cfg());
        // tick 1 hot: streak too short
        assert_eq!(ctl.observe(s(1, 0.95)), Decision::Hold);
        // tick 2 hot: streak reached -> up
        assert_eq!(ctl.observe(s(1, 0.98)), Decision::ScaleUp);
        // cooldown tick holds even though still saturated
        assert_eq!(ctl.observe(s(2, 0.97)), Decision::Hold);
        // streak rebuilds after cooldown
        assert_eq!(ctl.observe(s(2, 0.96)), Decision::Hold);
        assert_eq!(ctl.observe(s(2, 0.99)), Decision::ScaleUp);
    }

    #[test]
    fn saturated_backlog_counts_as_hot_even_with_idle_shards() {
        let mut ctl = Controller::new(fast_cfg());
        // shards read idle (tick landed between batches) but submit()
        // is blocked on a full window queue: that is saturation
        let jam = Sample { live: 1, mean_util: 0.0, backlog: 1.0 };
        assert_eq!(ctl.observe(jam), Decision::Hold);
        assert_eq!(ctl.observe(jam), Decision::ScaleUp);
    }

    #[test]
    fn idle_trace_scales_down_after_longer_streak() {
        let mut ctl = Controller::new(fast_cfg());
        assert_eq!(ctl.observe(s(3, 0.05)), Decision::Hold);
        assert_eq!(ctl.observe(s(3, 0.02)), Decision::Hold);
        assert_eq!(ctl.observe(s(3, 0.04)), Decision::ScaleDown);
        // cooldown, then the streak must rebuild from zero
        assert_eq!(ctl.observe(s(2, 0.01)), Decision::Hold);
        assert_eq!(ctl.observe(s(2, 0.01)), Decision::Hold);
        assert_eq!(ctl.observe(s(2, 0.02)), Decision::Hold);
        assert_eq!(ctl.observe(s(2, 0.03)), Decision::ScaleDown);
    }

    #[test]
    fn oscillation_around_threshold_never_flaps() {
        // utilization bouncing across high_util every other tick: the
        // neither-hot-nor-cold ticks reset the streak, so a controller
        // needing 2 consecutive hot ticks must never fire.
        let mut ctl = Controller::new(fast_cfg());
        for _ in 0..50 {
            assert_eq!(ctl.observe(s(2, 0.80)), Decision::Hold); // hot
            assert_eq!(ctl.observe(s(2, 0.50)), Decision::Hold); // mid
        }
        // same story around low_util: cold streaks keep resetting
        for _ in 0..50 {
            assert_eq!(ctl.observe(s(2, 0.20)), Decision::Hold); // cold
            assert_eq!(ctl.observe(s(2, 0.50)), Decision::Hold); // mid
        }
    }

    #[test]
    fn bounds_cap_scaling_in_both_directions() {
        let mut ctl = Controller::new(fast_cfg());
        // at max_shards even a sustained-hot trace holds
        for _ in 0..10 {
            assert_eq!(ctl.observe(s(4, 1.0)), Decision::Hold,
                       "must not scale past max_shards");
        }
        // at min_shards even a sustained-cold trace holds
        let mut ctl = Controller::new(fast_cfg());
        for _ in 0..10 {
            assert_eq!(ctl.observe(s(1, 0.0)), Decision::Hold,
                       "must not retire below min_shards");
        }
    }

    #[test]
    fn backlogged_cold_utilization_does_not_scale_down() {
        // util is low but the window queue is half-full-or-more: work
        // is arriving faster than batches launch, so shrinking now
        // would amplify the jam. Cold requires an empty-ish backlog.
        let mut ctl = Controller::new(fast_cfg());
        let draining = Sample { live: 3, mean_util: 0.1, backlog: 0.6 };
        for _ in 0..10 {
            assert_eq!(ctl.observe(draining), Decision::Hold);
        }
    }
}
