//! Streaming analysis stage + GenPIP-style early rejection.
//!
//! **Analysis** extends the coordinator past the collector: every voted
//! read the vote pool emits is also side-fed (as an `AnalysisJob`) into
//! a pool of analysis workers that maintain, per tenant, an
//! *incremental* overlap graph — the same k-mer-seeded, banded-verified
//! suffix/prefix graph `pipeline::overlap::find_overlaps` builds
//! offline, discovered read-by-read as calls stream out. When the run
//! (or a tenant's slice of it) is done, [`AnalysisState::consensus`]
//! lays the graph out with the offline greedy assembler and polishes
//! the draft against the same reads, so the streaming product is
//! **byte-identical** to running `pipeline::consensus` over the called
//! reads after the fact (pinned in `tests/coordinator_stream.rs`).
//!
//! Identity argument: for any ordered read pair `(a, b)`,
//! `find_overlaps` emits an edge iff a tail-seed of `a` hits a
//! head-seed of `b`, `a` is at least `min_len` long, and the banded
//! verifier accepts — all order-free facts of the pair. The
//! incremental index applies the exact same predicate when the later
//! of the two reads arrives (in both directions), so the edge *set*
//! matches; `consensus` then sorts reads by id and edges by
//! `(a_idx, b_idx)`, reproducing `find_overlaps`' canonical emission
//! order, and the greedy assembler's first-wins tie-breaks see
//! identical input.
//!
//! **Rejection** is the GenPIP-style early exit: the CTC decode stage
//! already computes a top-two-beam confidence margin per window (for
//! tiered escalation); with `CoordinatorConfig::reject_threshold` set,
//! a window whose margin lands *below* the threshold marks its whole
//! read hopeless in the [`RejectGate`]. Every later window of that
//! read skips the beam search entirely (`Metrics::rejected_windows`),
//! the collector completes the read without voting or emitting it
//! (`Metrics::rejected_reads`), and the analysis stage never sees it —
//! the compute the read would have burned in decode/vote/overlap is
//! returned to live reads. Threshold semantics follow the escalation
//! margin: margins are non-negative, so `0.0` never rejects (and the
//! pipeline is byte-identical to a gate-free build), while
//! `f32::INFINITY` rejects every read whose decode produces a finite
//! margin.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::util::sync::Mutex;
use std::time::Instant;

use crate::basecall::vote::best_overlap;
use crate::pipeline::assembly::{assemble_contigs_with_overlaps,
                                assemble_with_overlaps};
use crate::pipeline::overlap::{seed_hashes, Overlap};
use crate::pipeline::polish::polish;
use crate::util::bounded::Receiver;

use super::autoscale::WorkerPool;
use super::job::AnalysisJob;
use super::metrics::{Metrics, StageId};

/// Overlap floor for the streaming assembler — the `min_overlap` the
/// offline identity pin runs `pipeline::consensus` with.
pub const ANALYSIS_MIN_OVERLAP: usize = 20;

/// Shared read-quality gate between the decode pool (which marks) and
/// the collector router (which drops + forgets). Keyed by `read_id`
/// alone — ids are globally unique across tenants.
pub struct RejectGate {
    threshold: f32,
    rejected: Mutex<HashSet<usize>>,
}

impl RejectGate {
    /// Gate with the given margin threshold (see
    /// `CoordinatorConfig::reject_threshold` for the semantics).
    pub fn new(threshold: f32) -> RejectGate {
        RejectGate {
            threshold,
            rejected: Mutex::new(HashSet::new()),
        }
    }

    /// The margin below which a window condemns its read.
    pub fn threshold(&self) -> f32 {
        self.threshold
    }

    /// Has this read already been condemned?
    pub fn is_rejected(&self, read_id: usize) -> bool {
        self.rejected.lock().unwrap().contains(&read_id)
    }

    /// Condemn a read. Returns `true` if this call newly marked it.
    pub fn mark(&self, read_id: usize) -> bool {
        self.rejected.lock().unwrap().insert(read_id)
    }

    /// Drop a read's mark once its last window has drained (no further
    /// window can consult the gate, so the set stays bounded by the
    /// reads in flight).
    pub fn forget(&self, read_id: usize) {
        self.rejected.lock().unwrap().remove(&read_id);
    }

    /// Drop every mark (end-of-stream; nothing can consult them now).
    pub fn clear(&self) {
        self.rejected.lock().unwrap().clear();
    }
}

/// One tenant's incremental assembly state: the reads seen so far (in
/// arrival order), the k-mer indexes over their heads/tails, and every
/// verified overlap edge, kept as `(read_id, read_id, len)` triples so
/// a later sort can translate them into the offline canonical order.
#[derive(Default)]
struct TenantAssembly {
    /// `(read_id, voted sequence)` in arrival order; slot index is the
    /// id space the seed indexes speak.
    reads: Vec<(usize, Vec<u8>)>,
    /// head-seed hash → slots whose first `min(len, min_overlap*2)`
    /// bases contain it (every read, like `find_overlaps`' head index).
    head_index: HashMap<u64, Vec<usize>>,
    /// tail-seed hash → slots; only reads at least `min_overlap` long
    /// (shorter reads are never an `a` side, exactly like the offline
    /// outer-loop skip).
    tail_index: HashMap<u64, Vec<usize>>,
    /// verified edges as `(a_read_id, b_read_id, len)`.
    overlaps: Vec<(usize, usize, usize)>,
}

struct AnalysisInner {
    tenants: HashMap<u64, TenantAssembly>,
    /// tombstones for cancelled tenants: ids are never reused, so a
    /// late `AnalysisJob` draining out of the queue after
    /// `drop_tenant` must be discarded, not resurrect the state.
    cancelled: HashSet<u64>,
}

/// The streaming analysis stage's shared state: per-tenant incremental
/// overlap graphs, queried for a polished consensus at any point.
/// Workers call [`add_read`](AnalysisState::add_read) as voted reads
/// stream out of the collector; `Coordinator::cancel_tenant` calls
/// [`drop_tenant`](AnalysisState::drop_tenant) so a dead connection
/// cannot leak partial contigs.
pub struct AnalysisState {
    min_overlap: usize,
    inner: Mutex<AnalysisInner>,
}

impl AnalysisState {
    /// Fresh state with the given overlap floor (the coordinator uses
    /// [`ANALYSIS_MIN_OVERLAP`]).
    pub fn new(min_overlap: usize) -> AnalysisState {
        AnalysisState {
            min_overlap,
            inner: Mutex::new(AnalysisInner {
                tenants: HashMap::new(),
                cancelled: HashSet::new(),
            }),
        }
    }

    /// The overlap floor this state verifies against.
    pub fn min_overlap(&self) -> usize {
        self.min_overlap
    }

    /// Fold one voted read into its tenant's overlap graph: discover
    /// every edge between it and the reads already indexed (both
    /// directions, same seed-then-verify rule as `find_overlaps`),
    /// then index its own head/tail seeds. Discarded without effect
    /// for tenants already dropped.
    pub fn add_read(&self, tenant: u64, read_id: usize, seq: Vec<u8>) {
        let min = self.min_overlap;
        let mut inner = self.inner.lock().unwrap();
        if inner.cancelled.contains(&tenant) {
            return;
        }
        let t = inner.tenants.entry(tenant).or_default();
        // edges with the new read as the `a` (suffix) side: its tail
        // seeds against the heads already indexed. Candidate slots are
        // sorted + deduped like the offline candidate list.
        if seq.len() >= min {
            let tail = &seq[seq.len() - seq.len().min(min * 2)..];
            let mut cands: Vec<usize> = seed_hashes(tail)
                .filter_map(|h| t.head_index.get(&h))
                .flatten()
                .copied()
                .collect();
            cands.sort_unstable();
            cands.dedup();
            for b in cands {
                if let Some(len) = best_overlap(&seq, &t.reads[b].1, min) {
                    t.overlaps.push((read_id, t.reads[b].0, len));
                }
            }
        }
        // edges with the new read as the `b` (prefix) side: its head
        // seeds against the tails already indexed.
        let head = &seq[..seq.len().min(min * 2)];
        let mut cands: Vec<usize> = seed_hashes(head)
            .filter_map(|h| t.tail_index.get(&h))
            .flatten()
            .copied()
            .collect();
        cands.sort_unstable();
        cands.dedup();
        for a in cands {
            if let Some(len) = best_overlap(&t.reads[a].1, &seq, min) {
                t.overlaps.push((t.reads[a].0, read_id, len));
            }
        }
        // index the new read: head seeds always (any read can be a
        // prefix side), tail seeds only when long enough to ever be a
        // suffix side.
        let slot = t.reads.len();
        for h in seed_hashes(head) {
            t.head_index.entry(h).or_default().push(slot);
        }
        if seq.len() >= min {
            let tail = &seq[seq.len() - seq.len().min(min * 2)..];
            for h in seed_hashes(tail) {
                t.tail_index.entry(h).or_default().push(slot);
            }
        }
        t.reads.push((read_id, seq));
    }

    /// Snapshot a tenant's reads (sorted by read id) and its overlap
    /// edges translated to indexes into that sorted order, sorted by
    /// `(a, b)` — exactly the read order and edge order the offline
    /// `find_overlaps` produces over the same reads.
    fn snapshot(&self, tenant: u64)
                -> Option<(Vec<Vec<u8>>, Vec<Overlap>)> {
        let inner = self.inner.lock().unwrap();
        let t = inner.tenants.get(&tenant)?;
        let mut order: Vec<usize> = (0..t.reads.len()).collect();
        order.sort_by_key(|&i| t.reads[i].0);
        let idx_of: HashMap<usize, usize> = order.iter().enumerate()
            .map(|(idx, &slot)| (t.reads[slot].0, idx))
            .collect();
        let seqs: Vec<Vec<u8>> = order.iter()
            .map(|&slot| t.reads[slot].1.clone())
            .collect();
        let mut overlaps: Vec<Overlap> = t.overlaps.iter()
            .map(|&(a_id, b_id, len)| Overlap {
                a: idx_of[&a_id],
                b: idx_of[&b_id],
                len,
            })
            .collect();
        overlaps.sort_by_key(|o| (o.a, o.b));
        Some((seqs, overlaps))
    }

    /// The tenant's overlap edges in the offline canonical order
    /// (read indexes follow read-id order). Empty for an unknown
    /// tenant. Test/telemetry surface for the graph-identity pin.
    pub fn overlaps(&self, tenant: u64) -> Vec<Overlap> {
        self.snapshot(tenant).map_or_else(Vec::new, |(_, o)| o)
    }

    /// Polished consensus of everything the tenant has streamed so
    /// far: greedy unitig layout over the incremental overlap graph,
    /// then pileup-polish with the same reads — byte-identical to
    /// `pipeline::consensus` over the tenant's called reads sorted by
    /// id. Empty if the tenant has no reads.
    pub fn consensus(&self, tenant: u64) -> Vec<u8> {
        match self.snapshot(tenant) {
            Some((seqs, overlaps)) => {
                if seqs.is_empty() {
                    return Vec::new();
                }
                let draft = assemble_with_overlaps(&seqs, &overlaps);
                polish(&draft, &seqs)
            }
            None => Vec::new(),
        }
    }

    /// Every contig of the tenant's incremental graph (the first is
    /// what [`consensus`](AnalysisState::consensus) polishes), for
    /// callers that want the disconnected pieces too.
    pub fn contigs(&self, tenant: u64) -> Vec<Vec<u8>> {
        match self.snapshot(tenant) {
            Some((seqs, overlaps)) if !seqs.is_empty() =>
                assemble_contigs_with_overlaps(&seqs, &overlaps),
            _ => Vec::new(),
        }
    }

    /// Purge a tenant's entire analysis state (its owning connection
    /// died) and tombstone the id so late jobs still draining out of
    /// the analysis queues are discarded instead of resurrecting it.
    /// Returns the number of reads dropped. Tenant 0 — the in-process
    /// library path — is refused, mirroring
    /// `ReadRegistry::cancel_tenant`.
    pub fn drop_tenant(&self, tenant: u64) -> usize {
        if tenant == 0 {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.cancelled.insert(tenant);
        inner.tenants.remove(&tenant)
            .map_or(0, |t| t.reads.len())
    }

    /// Reads currently indexed for `tenant` (0 for unknown/dropped
    /// tenants). Telemetry/tests.
    pub fn reads_indexed(&self, tenant: u64) -> usize {
        self.inner.lock().unwrap().tenants.get(&tenant)
            .map_or(0, |t| t.reads.len())
    }
}

/// Build the streaming-analysis worker pool: per-worker queues in a
/// QueueSet-backed [`WorkerPool`] (stage `StageId::Analysis`), fed
/// round-robin by the vote workers through a `Feeder`, resizable by
/// the autoscale controller when `AutoscaleConfig::scale_analysis` is
/// set. Workers fold each voted read into the shared
/// [`AnalysisState`]; per-slot busy time lands in
/// `Metrics::analysis_workers` and stage time in
/// `Metrics::analysis_micros`.
pub(crate) fn spawn_analysis_pool(
    metrics: Arc<Metrics>,
    n_analysis: usize,
    cap: usize,
    state: Arc<AnalysisState>,
) -> Arc<WorkerPool<AnalysisJob>> {
    let m = metrics.clone();
    WorkerPool::new(
        StageId::Analysis, metrics, n_analysis, cap,
        Box::new(move |slot, rx: Receiver<AnalysisJob>| {
            let m = m.clone();
            let state = state.clone();
            std::thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    let t0 = Instant::now();
                    state.add_read(job.tenant, job.read_id, job.seq);
                    let busy = t0.elapsed().as_micros() as u64;
                    m.add(&m.analysis_micros, busy);
                    if let Some(st) = m.analysis_workers.get(slot) {
                        m.add(&st.jobs, 1);
                        m.add(&st.busy_micros, busy);
                    }
                }
            })
        }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{self, find_overlaps};
    use crate::util::rng::Rng;

    fn shredded(genome_len: usize, read_len: usize, step: usize,
                seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        let genome: Vec<u8> =
            (0..genome_len).map(|_| rng.base()).collect();
        let mut reads = Vec::new();
        let mut s = 0;
        while s + read_len <= genome.len() {
            reads.push(genome[s..s + read_len].to_vec());
            s += step;
        }
        reads
    }

    /// THE identity pin at the unit level: the incremental graph must
    /// equal `find_overlaps` edge-for-edge in canonical order, and the
    /// streamed consensus must equal the offline one byte-for-byte —
    /// regardless of arrival order.
    #[test]
    fn incremental_graph_and_consensus_match_offline() {
        let reads = shredded(600, 80, 40, 31);
        let offline_edges = find_overlaps(&reads, 20);
        let offline = pipeline::consensus(&reads, 20);
        // in-order arrival
        let st = AnalysisState::new(20);
        for (id, r) in reads.iter().enumerate() {
            st.add_read(0, id, r.clone());
        }
        assert_eq!(st.overlaps(0), offline_edges);
        assert_eq!(st.consensus(0), offline);
        // reversed (worst-case out-of-order) arrival
        let st2 = AnalysisState::new(20);
        for (id, r) in reads.iter().enumerate().rev() {
            st2.add_read(0, id, r.clone());
        }
        assert_eq!(st2.overlaps(0), offline_edges,
                   "edge set/order must be arrival-order independent");
        assert_eq!(st2.consensus(0), offline);
    }

    /// Degenerate inputs the offline pipeline tolerates must stream
    /// through too: empty reads, short reads, a lone read, no reads.
    #[test]
    fn degenerate_reads_stream_without_panic() {
        let st = AnalysisState::new(20);
        assert!(st.consensus(0).is_empty(), "no reads yet");
        assert!(st.contigs(0).is_empty());
        let mut rng = Rng::new(33);
        let real: Vec<u8> = (0..80).map(|_| rng.base()).collect();
        let reads = vec![Vec::new(), real.clone(), vec![1u8, 2, 3],
                         real.clone()];
        for (id, r) in reads.iter().enumerate() {
            st.add_read(0, id, r.clone());
        }
        assert_eq!(st.overlaps(0), find_overlaps(&reads, 20));
        assert_eq!(st.consensus(0), pipeline::consensus(&reads, 20));
        assert_eq!(st.reads_indexed(0), 4);
    }

    /// Tenants are isolated: interleaved arrivals build independent
    /// graphs, and each consensus matches its own offline run.
    #[test]
    fn tenants_assemble_independently() {
        let r5 = shredded(400, 80, 40, 35);
        let r6 = shredded(400, 80, 40, 36);
        let st = AnalysisState::new(20);
        for (id, r) in r5.iter().enumerate() {
            st.add_read(5, id, r.clone());
            if let Some(r) = r6.get(id) {
                st.add_read(6, 100 + id, r.clone());
            }
        }
        assert_eq!(st.consensus(5), pipeline::consensus(&r5, 20));
        assert_eq!(st.consensus(6), pipeline::consensus(&r6, 20));
    }

    /// `drop_tenant` purges the graph AND tombstones the tenant, so a
    /// late job draining out of the queue after the purge is
    /// discarded; tenant 0 is refused like the registry refuses it.
    #[test]
    fn drop_tenant_purges_and_tombstones() {
        let st = AnalysisState::new(20);
        let reads = shredded(300, 80, 40, 37);
        for (id, r) in reads.iter().enumerate() {
            st.add_read(9, id, r.clone());
        }
        assert!(st.reads_indexed(9) > 0);
        assert_eq!(st.drop_tenant(9), reads.len());
        assert_eq!(st.reads_indexed(9), 0);
        assert!(st.consensus(9).is_empty());
        // the straggler: a job that was queued before the purge
        st.add_read(9, 999, reads[0].clone());
        assert_eq!(st.reads_indexed(9), 0,
                   "tombstone must discard late jobs");
        // the library path cannot be purged
        st.add_read(0, 0, reads[0].clone());
        assert_eq!(st.drop_tenant(0), 0);
        assert_eq!(st.reads_indexed(0), 1);
    }

    /// RejectGate bookkeeping: mark is idempotent-with-signal, forget
    /// and clear unmark, and the threshold is what was configured.
    #[test]
    fn reject_gate_marks_once_and_forgets() {
        let g = RejectGate::new(1.5);
        assert_eq!(g.threshold(), 1.5);
        assert!(!g.is_rejected(7));
        assert!(g.mark(7), "first mark is new");
        assert!(!g.mark(7), "re-mark reports already condemned");
        assert!(g.is_rejected(7));
        g.forget(7);
        assert!(!g.is_rejected(7));
        g.mark(1);
        g.mark(2);
        g.clear();
        assert!(!g.is_rejected(1) && !g.is_rejected(2));
    }
}
