//! The typed payloads that flow between pipeline stages: tier-tagged
//! windows entering the batcher, keyed signal batches entering the DNN
//! shards, and log-prob jobs entering the decode pool. Splitting these
//! from the stage code keeps the routing fabric readable: every field
//! that travels with a window — its tier, its enqueue stamp, its
//! escalation stamp — is declared in one place.

use std::time::Instant;

use crate::basecall::ctc::LogProbs;
use crate::runtime::Tier;

/// One window of raw signal en route to the DNN stage.
pub(crate) struct WindowJob {
    pub(crate) read_id: usize,
    pub(crate) window_idx: usize,
    /// owning tenant of the read this window belongs to: 0 for the
    /// in-process library path, a connection id (>= 1) for reads that
    /// arrived over the TCP front-end (`coordinator::net`). Rides every
    /// stage payload so the collector can route a completion back to
    /// (or drop it for) the submitting connection.
    pub(crate) tenant: u64,
    pub(crate) signal: Vec<f32>,
    /// which shard pool this window targets: `Fast` for fresh windows
    /// of a tiered pipeline, `Hq` for escalations and for every window
    /// of a single-tier run.
    pub(crate) tier: Tier,
    /// stamped as the window enters its queue (`submit()` for fresh
    /// windows, the decode worker's re-queue for escalations), so the
    /// batcher's deadline clock (and `Batch::oldest_wait`) counts time
    /// spent queued behind backpressure, not just time since the
    /// batcher's first dequeue.
    pub(crate) enqueued_at: Instant,
    /// when the decode pool escalated this window to the hq tier
    /// (`None` for fresh windows). Carried through the DNN and decode
    /// stages so the hq decode can record the escalation round-trip
    /// latency.
    pub(crate) escalated_at: Option<Instant>,
}

/// Identity of one window inside a [`ShardBatch`]: enough to route the
/// decoded result back to its read, plus the escalation stamp riding
/// along for latency accounting.
pub(crate) struct WindowKey {
    pub(crate) read_id: usize,
    pub(crate) window_idx: usize,
    /// see [`WindowJob::tenant`].
    pub(crate) tenant: u64,
    pub(crate) escalated_at: Option<Instant>,
}

/// One batch en route from the dispatcher to a DNN shard: the window
/// keys and their signals, split so a shard can hand the signal block
/// to the backend without re-walking the jobs. A batch is always
/// single-tier — the dispatcher never mixes lanes — so the receiving
/// shard's own model selection applies to every row.
pub(crate) struct ShardBatch {
    pub(crate) keys: Vec<WindowKey>,
    pub(crate) sigs: Vec<Vec<f32>>,
    pub(crate) full: bool,
}

/// One voted read en route from the vote pool to the streaming
/// analysis stage (overlap → assembly → polish). Carries only what the
/// incremental assembler needs; the full `CalledRead` (with its
/// per-window decodes) still streams to the caller unchanged.
pub(crate) struct AnalysisJob {
    pub(crate) read_id: usize,
    /// see [`WindowJob::tenant`].
    pub(crate) tenant: u64,
    /// the voted/spliced consensus sequence of the read.
    pub(crate) seq: Vec<u8>,
}

/// One window's log-probs en route to the CTC decode pool.
pub(crate) struct DecodeJob {
    pub(crate) read_id: usize,
    pub(crate) window_idx: usize,
    /// see [`WindowJob::tenant`].
    pub(crate) tenant: u64,
    pub(crate) lp: LogProbs,
    /// which tier produced `lp` — the decode worker only measures
    /// confidence (and may escalate) on `Fast` jobs.
    pub(crate) tier: Tier,
    /// the raw signal, carried through the fast tier only while
    /// escalation is armed, so a low-confidence window can be re-run
    /// at the hq tier without a round-trip to storage.
    pub(crate) signal: Option<Vec<f32>>,
    /// see [`WindowJob::escalated_at`].
    pub(crate) escalated_at: Option<Instant>,
}
