//! The dispatch thread: drains the window stream through a batcher and
//! routes each assembled batch onto a DNN shard queue. A single-tier
//! pipeline runs the classic `Batcher` over the one window queue; a
//! tiered pipeline runs the two-lane [`TieredBatcher`], routing fresh
//! batches to the fast pool and escalation batches to the hq pool —
//! lanes never share a batch, so a shard's model selection applies to
//! every row it receives.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::util::sync::AtomicU64;
use std::thread::JoinHandle;

use crate::util::bounded::{QueueSet, Receiver};

use super::batcher::{BatchPolicy, Batcher, TieredBatcher, LANE_FRESH};
use super::job::{ShardBatch, WindowJob, WindowKey};
use super::metrics::{Metrics, StageId};
use super::pool::rank_busiest;

/// The tiered half of the dispatcher's wiring: the escalation
/// side-channel receiver, the dispatched-but-undecided fast-window
/// count it shares with the decode pool (see `TieredBatcher` for the
/// shutdown protocol), and the hq pool's shard queues.
pub(crate) struct TierRouting {
    pub(crate) esc_rx: Receiver<WindowJob>,
    pub(crate) pending: Arc<AtomicU64>,
    pub(crate) hq_queues: Arc<QueueSet<ShardBatch>>,
}

/// Split a batch of window jobs into the key/signal pair a shard
/// consumes (one `Vec<Vec<f32>>` block the backend can run directly).
fn shard_batch(items: Vec<WindowJob>, full: bool) -> ShardBatch {
    let mut keys = Vec::with_capacity(items.len());
    let mut sigs = Vec::with_capacity(items.len());
    for job in items {
        keys.push(WindowKey {
            read_id: job.read_id,
            window_idx: job.window_idx,
            tenant: job.tenant,
            escalated_at: job.escalated_at,
        });
        sigs.push(job.signal);
    }
    ShardBatch { keys, sigs, full }
}

/// Spawn the dispatch thread. `tiered: None` reproduces the
/// single-tier dispatcher exactly (same batcher, same routing, same
/// teardown), which is what keeps escalation-off runs byte-identical;
/// `Some` runs the two-lane loop. Either way the thread seals every
/// shard queue set it routed to before exiting, so the shard threads
/// drain and exit no matter how the stream ended.
pub(crate) fn spawn_dispatch(
    rx_windows: Receiver<WindowJob>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    fast: Arc<QueueSet<ShardBatch>>,
    tiered: Option<TierRouting>,
) -> JoinHandle<()> {
    std::thread::spawn(move || match tiered {
        None => run_single(rx_windows, policy, metrics, fast),
        Some(t) => run_tiered(rx_windows, policy, metrics, fast, t),
    })
}

/// The classic single-queue dispatch loop: batch by size/deadline,
/// route full batches least-loaded and deadline tails onto the
/// busiest live shard (keeping the others drainable/retirable).
fn run_single(rx: Receiver<WindowJob>, policy: BatchPolicy,
              m: Arc<Metrics>, qs: Arc<QueueSet<ShardBatch>>) {
    let mut batcher =
        Batcher::with_stamp(rx, policy, |j: &WindowJob| j.enqueued_at);
    let mut rr = 0usize;
    while let Some(batch) = batcher.next_batch() {
        let tail = batch.is_tail();
        let out = shard_batch(batch.items, !tail);
        let delivered = if tail {
            let order = rank_busiest(m.stage_shards(StageId::Dnn), &qs);
            qs.send_preferring(&order, out)
        } else {
            qs.send_least_loaded(&mut rr, out)
        };
        if !delivered {
            // every shard is gone; nothing downstream can make
            // progress, so stop consuming windows
            break;
        }
    }
    qs.close_all();
}

/// The two-lane dispatch loop: the `TieredBatcher` hands back
/// `(lane, batch)` pairs — requeue lane first under contention — and
/// each lane routes onto its own pool with the same full/tail policy
/// as the single-tier loop. Fresh fast-lane windows are counted into
/// `pending` BEFORE their batch is sent (the decode worker decrements
/// after its escalation decision), so the batcher can never observe
/// "no pending windows" while an escalation is still in flight.
fn run_tiered(rx: Receiver<WindowJob>, policy: BatchPolicy,
              m: Arc<Metrics>, fast: Arc<QueueSet<ShardBatch>>,
              t: TierRouting) {
    let mut batcher = TieredBatcher::new(
        rx, t.esc_rx, policy,
        |j: &WindowJob| j.enqueued_at, t.pending.clone());
    let mut rr_fast = 0usize;
    let mut rr_hq = 0usize;
    while let Some((lane, batch)) = batcher.next_batch() {
        let tail = batch.is_tail();
        let n = batch.items.len() as u64;
        let out = shard_batch(batch.items, !tail);
        let (qs, rr, stage) = if lane == LANE_FRESH {
            (&fast, &mut rr_fast, StageId::Dnn)
        } else {
            (&t.hq_queues, &mut rr_hq, StageId::DnnHq)
        };
        if lane == LANE_FRESH {
            // count before send: once a fast batch is on a shard
            // queue, its windows may escalate at any time
            t.pending.fetch_add(n, Ordering::Release);
        }
        let delivered = if tail {
            let order = rank_busiest(m.stage_shards(stage), qs);
            qs.send_preferring(&order, out)
        } else {
            qs.send_least_loaded(rr, out)
        };
        if !delivered {
            if lane == LANE_FRESH {
                // the batch never reached a shard: no decode worker
                // will ever decrement for these windows
                t.pending.fetch_sub(n, Ordering::Release);
            }
            break;
        }
    }
    fast.close_all();
    t.hq_queues.close_all();
}
