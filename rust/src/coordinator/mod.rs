//! Layer-3 coordinator: the serving front of the system — a staged,
//! threaded, *streaming* pipeline (tokio is unavailable in the offline
//! build, so stages are OS threads joined by in-tree bounded channels —
//! same architecture, no async runtime). The full stage/queue map below
//! is `README.md` in this directory, rendered as module docs so its
//! usage snippet compiles and runs under `cargo test`.
//!
#![doc = include_str!("README.md")]

pub mod analysis;
pub mod autoscale;
pub mod batcher;
pub mod collector;
pub mod config;
mod dispatch;
mod job;
pub mod metrics;
pub mod net;
mod pool;
pub mod server;

pub use analysis::{AnalysisState, RejectGate, ANALYSIS_MIN_OVERLAP};
pub use autoscale::{AutoscaleConfig, Controller, Decision, Sample,
                    SpawnWorker, StageControl, StagePool, WorkerPool};
pub use batcher::{Batch, Batcher, BatchPolicy, TieredBatcher};
pub use collector::{Collector, CollectorConfig, DecodedWindow,
                    ReadRegistry};
pub use config::{resolve_knob, KnobSource, ServeConfig};
pub use metrics::{LatencyHistogram, LatencySnapshot, Metrics,
                  ScaleAction, ScaleEvent, ShardStats, StageId,
                  StageStats, TenantStats};
pub use net::{Client, ClientSummary, Server};
pub use server::{CalledRead, Coordinator, CoordinatorConfig};
