//! Layer-3 coordinator: the serving front of the system.
//!
//! A staged, threaded, *streaming* pipeline (DESIGN.md; tokio is
//! unavailable in the offline build, so stages are OS threads joined by
//! in-tree bounded channels — same architecture, no async runtime):
//!
//!   submit(read) -> [windower] -> [dynamic batcher + DNN executor thread
//!   (owns a `runtime::Backend`: native quantized executor by default,
//!   PJRT with the `xla` feature)] -> [CTC decode worker pool, per-worker
//!   queues] -> [collector router] -> [vote worker pool] -> CalledReads
//!   stream out via try_recv()/recv_timeout(); finish() drains the rest.
//!
//! Every interior stage boundary is bounded, so `submit()` backpressures
//! instead of buffering a whole run's raw signal; only the output queue
//! is uncapped (its occupancy is the run's own result set), and each
//! read is emitted the moment its last window decodes. The batcher implements the size-or-deadline policy of
//! serving systems (vLLM-style): a batch launches when full OR when the
//! oldest queued window exceeds the deadline. See `README.md` in this
//! directory for the stage/queue map.

pub mod batcher;
pub mod collector;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, Batcher, BatchPolicy};
pub use collector::{Collector, CollectorConfig, DecodedWindow,
                    ReadRegistry};
pub use metrics::{LatencyHistogram, Metrics};
pub use server::{CalledRead, Coordinator, CoordinatorConfig};
