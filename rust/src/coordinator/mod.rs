//! Layer-3 coordinator: the serving front of the system.
//!
//! A staged, threaded pipeline (DESIGN.md; tokio is unavailable in the
//! offline build, so stages are OS threads joined by mpsc channels — same
//! architecture, no async runtime):
//!
//!   submit(read) -> [windower] -> [dynamic batcher + DNN executor thread
//!   (owns the PJRT client)] -> [CTC decode worker pool] -> [per-read
//!   collector + voter] -> called reads out.
//!
//! The batcher implements the size-or-deadline policy of serving systems
//! (vLLM-style): a batch launches when full OR when the oldest queued
//! window exceeds the deadline.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, Batcher, BatchPolicy};
pub use metrics::Metrics;
pub use server::{CalledRead, Coordinator, CoordinatorConfig};
