//! `helix_check`: deterministic schedule exploration for the pipeline's
//! hand-rolled concurrency (a zero-dependency loom-lite).
//!
//! Compiled only under `--cfg helix_check`. Model tests call
//! [`explore`] with a closure that builds a concurrency structure,
//! spawns threads through [`spawn`], and asserts an invariant. The
//! closure runs once per *schedule*: real OS threads are serialized so
//! exactly one runs at a time, and every `util::sync` operation (mutex
//! acquire/release, condvar wait/notify, atomic op) is a controlled
//! yield point where a seeded RNG may switch threads (bounded
//! preemptions, PCT-style). Condvar waits additionally get injected
//! spurious wakeups and virtual-clock timeouts, and blocked-thread
//! cycles are reported as deadlocks instead of hanging the suite.
//!
//! Every failing schedule is identified by its seed and replays
//! exactly:
//!
//! ```text
//! HELIX_CHECK_SEED=17 RUSTFLAGS="--cfg helix_check" \
//!     cargo test -q model_name
//! ```
//!
//! `HELIX_CHECK_ITERS=N` overrides how many seeds each model explores.
//! Threads NOT spawned through [`spawn`] are invisible to the
//! scheduler and fall through to the plain `std` primitives, so the
//! ordinary test suite runs unchanged in a `helix_check` build.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::PoisonError;
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex,
                MutexGuard as StdGuard};
use std::time::Duration;

use crate::util::rng::Rng;

/// Hard cap on scheduling decisions per schedule; exceeding it is
/// reported as a livelock failure rather than hanging the test.
const STEP_CAP: u64 = 400_000;
/// Virtual nanoseconds the schedule clock advances per `Instant::now`.
const CLOCK_STEP_NANOS: u64 = 1_000;
/// A condvar wait wakes spuriously with probability `1/SPURIOUS_DENOM`.
const SPURIOUS_DENOM: usize = 4;
/// Each yield point preempts with probability `1/PREEMPT_DENOM` while
/// the schedule's preemption budget lasts.
const PREEMPT_DENOM: usize = 3;
/// Preemption budgets are drawn uniformly from `0..PREEMPT_BUDGET_MAX`.
const PREEMPT_BUDGET_MAX: usize = 4;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ThreadState {
    Runnable,
    /// Waiting for the mutex at this address.
    BlockedMutex(usize),
    /// Waiting on the condvar at address `cv`.
    BlockedCv { cv: usize, spurious: bool, deadline: Option<u64>,
                notified: bool },
    /// Waiting for thread `tid` to finish.
    BlockedJoin(usize),
    Finished,
}

struct CoreState {
    rng: Rng,
    /// Virtual schedule clock, nanoseconds.
    clock: u64,
    threads: Vec<ThreadState>,
    /// Why the last condvar grant woke (true = virtual timeout).
    wake_timed_out: Vec<bool>,
    /// Logical mutex ownership: mutex address -> thread id. Never
    /// iterated for a scheduling decision (iteration order of a
    /// `HashMap` is not deterministic); decisions walk `threads`.
    owners: HashMap<usize, usize>,
    running: Option<usize>,
    preemptions_left: usize,
    steps: u64,
    failure: Option<String>,
    /// Once true the scheduler stands down: every blocked thread is
    /// released so the schedule can unwind and the OS threads exit.
    aborted: bool,
}

/// One schedule's shared scheduler state.
struct Core {
    state: StdMutex<CoreState>,
    cv: StdCondvar,
    /// OS join handles for threads spawned during the schedule,
    /// joined by [`JoinHandle::join`] or swept up by `run_schedule`.
    os_handles: StdMutex<Vec<(usize, std::thread::JoinHandle<()>)>>,
}

impl Core {
    fn new(seed: u64) -> Core {
        let mut rng = Rng::new(seed ^ 0x6865_6c69_785f_636b);
        let budget = rng.below(PREEMPT_BUDGET_MAX);
        Core {
            state: StdMutex::new(CoreState {
                rng,
                clock: 0,
                threads: Vec::new(),
                wake_timed_out: Vec::new(),
                owners: HashMap::new(),
                running: None,
                preemptions_left: budget,
                steps: 0,
                failure: None,
                aborted: false,
            }),
            cv: StdCondvar::new(),
            os_handles: StdMutex::new(Vec::new()),
        }
    }

    fn lock(&self) -> StdGuard<'_, CoreState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

impl CoreState {
    fn all_finished(&self) -> bool {
        self.threads.iter().all(|t| *t == ThreadState::Finished)
    }
}

thread_local! {
    static CURRENT: std::cell::RefCell<Option<(Arc<Core>, usize)>> =
        const { std::cell::RefCell::new(None) };
}

fn current() -> Option<(Arc<Core>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

/// True when the calling thread belongs to an in-flight schedule (was
/// spawned through [`spawn`] or is a model body). `util::sync` uses
/// this to decide between the scheduler protocol and plain `std`.
pub fn is_model_thread() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

fn fail(st: &mut CoreState, core: &Core, msg: String) {
    if st.failure.is_none() {
        st.failure = Some(msg);
    }
    st.aborted = true;
    core.cv.notify_all();
}

/// Recognizable payload for the unwind that tears a schedule down.
const ABORT_MSG: &str = "helix_check: schedule aborted";

/// After an abort, a thread about to (re-)block must UNWIND, not fall
/// through to the backing `std` primitives: in a detected deadlock the
/// backing mutexes really are held in a cycle, and only unwinding (and
/// thereby dropping guards) can break it. Threads already unwinding
/// fall through instead (a double panic would abort the process); the
/// guards they still hold are released as the unwind proceeds.
fn abort_unwind() {
    if !std::thread::panicking() {
        panic!("{ABORT_MSG}");
    }
}

/// Transfer control to `tid`, resolving whatever it was blocked on.
fn grant(st: &mut CoreState, tid: usize, timed_out: bool) {
    match st.threads[tid] {
        ThreadState::Runnable => {}
        ThreadState::BlockedMutex(addr) => {
            st.owners.insert(addr, tid);
            st.threads[tid] = ThreadState::Runnable;
        }
        ThreadState::BlockedCv { .. } => {
            st.wake_timed_out[tid] = timed_out;
            st.threads[tid] = ThreadState::Runnable;
        }
        ThreadState::BlockedJoin(_) => {
            st.threads[tid] = ThreadState::Runnable;
        }
        ThreadState::Finished => unreachable!("granted finished thread"),
    }
    st.running = Some(tid);
}

/// Threads that could run right now without advancing the clock.
/// `skip` excludes the caller when probing for a preemption target.
fn primary_candidates(st: &CoreState, skip: Option<usize>) -> Vec<usize> {
    let mut out = Vec::new();
    for (tid, t) in st.threads.iter().enumerate() {
        if Some(tid) == skip {
            continue;
        }
        let ok = match *t {
            ThreadState::Runnable => st.running != Some(tid),
            ThreadState::BlockedMutex(addr) => {
                !st.owners.contains_key(&addr)
            }
            ThreadState::BlockedCv { spurious, notified, .. } => {
                notified || spurious
            }
            ThreadState::BlockedJoin(child) => {
                st.threads[child] == ThreadState::Finished
            }
            ThreadState::Finished => false,
        };
        if ok {
            out.push(tid);
        }
    }
    out
}

/// Pick the next thread to run. Timeouts are a LAST resort: a
/// deadline-armed condvar waiter is only woken by the clock when no
/// other thread can make progress, so a pending timeout can never
/// starve a runnable peer out of delivering the wakeup it is racing.
fn pick_next(st: &mut CoreState, core: &Core) {
    st.steps += 1;
    if st.steps > STEP_CAP {
        fail(st, core,
             format!("livelock: schedule exceeded {STEP_CAP} steps"));
        return;
    }
    let cands = primary_candidates(st, None);
    if !cands.is_empty() {
        let tid = cands[st.rng.below(cands.len())];
        grant(st, tid, false);
        return;
    }
    // No primary candidate: advance the virtual clock to a deadline.
    let mut dls = Vec::new();
    for (tid, t) in st.threads.iter().enumerate() {
        if let ThreadState::BlockedCv { deadline: Some(d),
                                        notified: false, .. } = *t {
            dls.push((tid, d));
        }
    }
    if !dls.is_empty() {
        let (tid, d) = dls[st.rng.below(dls.len())];
        st.clock = st.clock.max(d);
        grant(st, tid, true);
        return;
    }
    if st.all_finished() {
        st.running = None;
        return;
    }
    let shape: Vec<String> = st.threads.iter().enumerate()
        .map(|(i, t)| format!("t{i}={t:?}"))
        .collect();
    fail(st, core,
         format!("deadlock: no runnable thread [{}]", shape.join(", ")));
}

/// Block the calling OS thread until the scheduler hands it the turn
/// (or the schedule aborts).
fn wait_turn<'a>(core: &'a Core, me: usize,
                 mut st: StdGuard<'a, CoreState>)
                 -> StdGuard<'a, CoreState> {
    while !st.aborted && st.running != Some(me) {
        st = core.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
    }
    st
}

/// A yield point: with bounded probability, hand the turn to some
/// other ready thread and wait to be rescheduled.
fn maybe_preempt<'a>(core: &'a Core, me: usize,
                     mut st: StdGuard<'a, CoreState>)
                     -> StdGuard<'a, CoreState> {
    if st.aborted || st.preemptions_left == 0 {
        return st;
    }
    if st.rng.below(PREEMPT_DENOM) != 0 {
        return st;
    }
    let cands = primary_candidates(&st, Some(me));
    if cands.is_empty() {
        return st;
    }
    st.preemptions_left -= 1;
    st.steps += 1;
    let tid = cands[st.rng.below(cands.len())];
    grant(&mut st, tid, false);
    core.cv.notify_all();
    wait_turn(core, me, st)
}

/// Scheduler hook: logical mutex acquire (called by the `util::sync`
/// shim before it takes the backing `std` mutex).
pub(crate) fn mutex_acquire(addr: usize) {
    let Some((core, me)) = current() else { return };
    let mut st = core.lock();
    if st.aborted {
        drop(st);
        abort_unwind();
        return;
    }
    st = maybe_preempt(&core, me, st);
    if st.aborted {
        drop(st);
        abort_unwind();
        return;
    }
    match st.owners.get(&addr).copied() {
        None => {
            st.owners.insert(addr, me);
        }
        Some(o) if o == me => {
            // would self-deadlock on the backing std mutex next
            panic!("helix_check: recursive lock by model thread {me}");
        }
        Some(_) => {
            st.threads[me] = ThreadState::BlockedMutex(addr);
            pick_next(&mut st, &core);
            core.cv.notify_all();
            let st = wait_turn(&core, me, st);
            if st.aborted {
                drop(st);
                abort_unwind();
            }
        }
    }
}

/// Scheduler hook: logical mutex release (called AFTER the backing
/// `std` guard is dropped, so the granted waiter finds it free).
pub(crate) fn mutex_release(addr: usize) {
    let Some((core, me)) = current() else { return };
    let mut st = core.lock();
    if st.aborted {
        return;
    }
    st.owners.remove(&addr);
    let _st = maybe_preempt(&core, me, st);
}

/// Scheduler hook: atomically (under the core lock) register a condvar
/// wait, draw the spurious-wakeup decision, release logical ownership
/// of the paired mutex, and schedule someone else. The caller then
/// drops the backing `std` guard and calls [`cv_wait_block`].
pub(crate) fn cv_wait_begin(cv: usize, mutex: usize,
                            deadline: Option<u64>) {
    let Some((core, me)) = current() else { return };
    let mut st = core.lock();
    if st.aborted {
        return;
    }
    let spurious = st.rng.below(SPURIOUS_DENOM) == 0;
    st.owners.remove(&mutex);
    st.threads[me] = ThreadState::BlockedCv {
        cv, spurious, deadline, notified: false,
    };
    pick_next(&mut st, &core);
    core.cv.notify_all();
}

/// Scheduler hook: block until woken (notify, spurious, or virtual
/// timeout). Returns true when the wake was a timeout.
pub(crate) fn cv_wait_block() -> bool {
    let Some((core, me)) = current() else { return false };
    let st = core.lock();
    if st.aborted {
        drop(st);
        // The schedule is over; a thread parked in a wait loop would
        // otherwise spin on an immediately-returning wait forever.
        abort_unwind();
        return false;
    }
    let st = wait_turn(&core, me, st);
    if st.aborted {
        drop(st);
        abort_unwind();
        return false;
    }
    st.wake_timed_out[me]
}

/// Scheduler hook: wake one (seed-chosen) model waiter on `cv`.
pub(crate) fn cv_notify_one(cv: usize) {
    let Some((core, me)) = current() else { return };
    let mut st = core.lock();
    if st.aborted {
        return;
    }
    let mut waiters = Vec::new();
    for (tid, t) in st.threads.iter().enumerate() {
        if let ThreadState::BlockedCv { cv: c, notified: false, .. } = *t {
            if c == cv {
                waiters.push(tid);
            }
        }
    }
    if !waiters.is_empty() {
        let tid = waiters[st.rng.below(waiters.len())];
        if let ThreadState::BlockedCv { ref mut notified, .. } =
            st.threads[tid] {
            *notified = true;
        }
    }
    let _st = maybe_preempt(&core, me, st);
}

/// Scheduler hook: wake every model waiter on `cv`.
pub(crate) fn cv_notify_all(cv: usize) {
    let Some((core, me)) = current() else { return };
    let mut st = core.lock();
    if st.aborted {
        return;
    }
    for t in st.threads.iter_mut() {
        if let ThreadState::BlockedCv { cv: c, ref mut notified, .. } = *t {
            if c == cv {
                *notified = true;
            }
        }
    }
    let _st = maybe_preempt(&core, me, st);
}

/// Scheduler hook: an atomic op is about to run — a yield point.
/// Counts toward the step cap so an atomic spin loop is torn down as a
/// livelock instead of hanging the suite.
pub(crate) fn atomic_yield() {
    let Some((core, me)) = current() else { return };
    let mut st = core.lock();
    if st.aborted {
        drop(st);
        abort_unwind();
        return;
    }
    st.steps += 1;
    if st.steps > STEP_CAP {
        fail(&mut st, &core,
             format!("livelock: schedule exceeded {STEP_CAP} steps \
                      (atomic spin?)"));
        drop(st);
        abort_unwind();
        return;
    }
    let _st = maybe_preempt(&core, me, st);
}

/// Scheduler hook: read the virtual clock, advancing it one step so
/// single-threaded time still progresses.
pub(crate) fn clock_tick() -> u64 {
    let Some((core, _me)) = current() else { return 0 };
    let mut st = core.lock();
    st.clock = st.clock.saturating_add(CLOCK_STEP_NANOS);
    st.clock
}

/// Scheduler hook: convert a wait timeout into an absolute virtual
/// deadline on the schedule clock.
pub(crate) fn virtual_deadline(dur: Duration) -> Option<u64> {
    let (core, _me) = current()?;
    let st = core.lock();
    let nanos = u64::try_from(dur.as_nanos()).unwrap_or(u64::MAX);
    Some(st.clock.saturating_add(nanos))
}

fn finish_thread(core: &Core, me: usize) {
    let mut st = core.lock();
    st.threads[me] = ThreadState::Finished;
    if st.aborted {
        core.cv.notify_all();
        return;
    }
    if st.running == Some(me) {
        st.running = None;
        pick_next(&mut st, core);
    }
    core.cv.notify_all();
}

/// Marks the thread Finished even when its body panics (the panic is
/// separately recorded as a schedule failure by the spawn wrapper).
struct FinishGuard {
    core: Arc<Core>,
    tid: usize,
}

impl Drop for FinishGuard {
    fn drop(&mut self) {
        finish_thread(&self.core, self.tid);
    }
}

fn payload_to_string(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Handle to a model thread spawned with [`spawn`]; mirrors
/// `std::thread::JoinHandle` (join returns the body's value and
/// re-raises its panic).
pub struct JoinHandle<T> {
    core: Arc<Core>,
    tid: usize,
    result: Arc<StdMutex<Option<std::thread::Result<T>>>>,
}

impl<T> JoinHandle<T> {
    /// Wait (as a schedulable blocking point) for the thread to finish
    /// and return its value; re-raises the thread's panic.
    pub fn join(self) -> T {
        let me = current().map(|(_, tid)| tid);
        if let Some(me) = me {
            let mut st = self.core.lock();
            if !st.aborted {
                st.threads[me] = ThreadState::BlockedJoin(self.tid);
                pick_next(&mut st, &self.core);
                self.core.cv.notify_all();
                let _st = wait_turn(&self.core, me, st);
            }
        }
        // Make sure the OS thread has actually exited (it stores the
        // result before its FinishGuard runs, but join the handle so
        // no OS thread outlives its schedule).
        let handle = {
            let mut reg = self.core.os_handles.lock()
                .unwrap_or_else(PoisonError::into_inner);
            reg.iter().position(|(tid, _)| *tid == self.tid)
                .map(|i| reg.swap_remove(i).1)
        };
        if let Some(h) = handle {
            let _ = h.join();
        }
        let slot = self.result.lock()
            .unwrap_or_else(PoisonError::into_inner).take();
        match slot {
            Some(Ok(v)) => v,
            Some(Err(p)) => std::panic::resume_unwind(p),
            // Only reachable when the schedule aborted before the
            // child stored anything; propagate the teardown unwind.
            None => panic!("{ABORT_MSG}"),
        }
    }
}

/// Spawn a model thread inside the current schedule. Must be called
/// from a model thread (the [`explore`] body or another spawned
/// thread). The child starts Runnable and is scheduled like any other
/// yield-point candidate.
pub fn spawn<T, F>(f: F) -> JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (core, _me) = current()
        .expect("check::spawn called outside a model schedule");
    let tid = {
        let mut st = core.lock();
        st.threads.push(ThreadState::Runnable);
        st.wake_timed_out.push(false);
        st.threads.len() - 1
    };
    let result = Arc::new(StdMutex::new(None));
    let result2 = Arc::clone(&result);
    let core2 = Arc::clone(&core);
    let os = std::thread::Builder::new()
        .name(format!("helix-check-{tid}"))
        .spawn(move || {
            CURRENT.with(|c| {
                *c.borrow_mut() = Some((Arc::clone(&core2), tid));
            });
            let _fg = FinishGuard { core: Arc::clone(&core2), tid };
            {
                let st = core2.lock();
                let _st = wait_turn(&core2, tid, st);
            }
            let r = catch_unwind(AssertUnwindSafe(f));
            if let Err(ref p) = r {
                let msg = payload_to_string(p.as_ref());
                if msg != ABORT_MSG {
                    let mut st = core2.lock();
                    fail(&mut st, &core2,
                         format!("model thread {tid} panicked: {msg}"));
                }
            }
            *result2.lock().unwrap_or_else(PoisonError::into_inner) =
                Some(r);
        })
        .expect("spawn model thread");
    core.os_handles.lock().unwrap_or_else(PoisonError::into_inner)
        .push((tid, os));
    JoinHandle { core, tid, result }
}

/// Run `body` once under the schedule derived from `seed`.
fn run_schedule<F>(seed: u64, body: Arc<F>) -> Result<(), String>
where
    F: Fn() + Send + Sync + 'static,
{
    let core = Arc::new(Core::new(seed));
    {
        let mut st = core.lock();
        st.threads.push(ThreadState::Runnable);
        st.wake_timed_out.push(false);
        st.running = Some(0);
    }
    let core0 = Arc::clone(&core);
    let os0 = std::thread::Builder::new()
        .name("helix-check-0".to_string())
        .spawn(move || {
            CURRENT.with(|c| {
                *c.borrow_mut() = Some((Arc::clone(&core0), 0));
            });
            let _fg = FinishGuard { core: Arc::clone(&core0), tid: 0 };
            let r = catch_unwind(AssertUnwindSafe(|| body()));
            if let Err(ref p) = r {
                let msg = payload_to_string(p.as_ref());
                if msg != ABORT_MSG {
                    let mut st = core0.lock();
                    fail(&mut st, &core0,
                         format!("model body panicked: {msg}"));
                }
            }
        })
        .expect("spawn model body thread");
    {
        let mut st = core.lock();
        while !st.aborted && !st.all_finished() {
            st = core.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }
    let _ = os0.join();
    // Sweep up OS threads whose JoinHandle was dropped without join.
    loop {
        let handle = {
            let mut reg = core.os_handles.lock()
                .unwrap_or_else(PoisonError::into_inner);
            reg.pop()
        };
        match handle {
            Some((_tid, h)) => {
                let _ = h.join();
            }
            None => break,
        }
    }
    let failure = core.lock().failure.take();
    match failure {
        Some(msg) => Err(msg),
        None => Ok(()),
    }
}

fn env_iters(default_iters: u64) -> u64 {
    match std::env::var("HELIX_CHECK_ITERS") {
        Ok(s) => s.trim().parse().unwrap_or(default_iters),
        Err(_) => default_iters,
    }
}

/// Explore `iters` seeded schedules of `body`, panicking (with the
/// replay seed) on the first failing one. `HELIX_CHECK_SEED` replays a
/// single seed (combine with a test name filter — the env var applies
/// to every `explore` in the run); `HELIX_CHECK_ITERS` overrides the
/// seed count.
pub fn explore<F>(name: &str, iters: u64, body: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let body = Arc::new(body);
    if let Ok(s) = std::env::var("HELIX_CHECK_SEED") {
        let seed: u64 = s.trim().parse()
            .expect("HELIX_CHECK_SEED must be a u64");
        if let Err(msg) = run_schedule(seed, Arc::clone(&body)) {
            panic!("model '{name}' failed replaying \
                    HELIX_CHECK_SEED={seed}: {msg}");
        }
        return;
    }
    for seed in 0..env_iters(iters) {
        if let Err(msg) = run_schedule(seed, Arc::clone(&body)) {
            panic!("model '{name}' failed under schedule seed {seed}: \
                    {msg}\n  replay: HELIX_CHECK_SEED={seed} \
                    RUSTFLAGS=\"--cfg helix_check\" cargo test {name}");
        }
    }
}

/// Like [`explore`] but for fixtures with a deliberately-injected bug:
/// finds a failing seed, replays it to prove the failure is
/// deterministic, and returns the seed. Panics if no schedule fails
/// (the injected bug was not reachable) or if the replay diverges
/// (scheduler nondeterminism).
pub fn explore_expect_failure<F>(name: &str, iters: u64, body: F) -> u64
where
    F: Fn() + Send + Sync + 'static,
{
    let body = Arc::new(body);
    for seed in 0..env_iters(iters) {
        if run_schedule(seed, Arc::clone(&body)).is_err() {
            assert!(
                run_schedule(seed, Arc::clone(&body)).is_err(),
                "model '{name}': seed {seed} failed once but replayed \
                 clean — scheduler nondeterminism"
            );
            return seed;
        }
    }
    panic!("model '{name}': no failing schedule in {iters} seeds — \
            the injected bug is unreachable");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::{AtomicU64, Mutex};

    #[test]
    fn mutex_increments_are_exact_under_exploration() {
        explore("sanity_mutex_counter", 60, || {
            let n = Arc::new(Mutex::new(0u64));
            let mut hs = Vec::new();
            for _ in 0..3 {
                let n = Arc::clone(&n);
                hs.push(spawn(move || {
                    for _ in 0..4 {
                        *n.lock().unwrap() += 1;
                    }
                }));
            }
            for h in hs {
                h.join();
            }
            assert_eq!(*n.lock().unwrap(), 12);
        });
    }

    #[test]
    fn deadlock_is_reported_not_hung() {
        let seed = explore_expect_failure("sanity_deadlock", 50, || {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let h = spawn(move || {
                let _ga = a2.lock().unwrap();
                let _gb = b2.lock().unwrap();
            });
            let _gb = b.lock().unwrap();
            let _ga = a.lock().unwrap();
            drop(_ga);
            drop(_gb);
            h.join();
        });
        // some seed in range must order the acquires into the cycle
        assert!(seed < 50);
    }

    #[test]
    fn torn_read_modify_write_is_caught_and_replays() {
        // load+store (instead of fetch_add) is a torn increment; a
        // preemption between them loses an update. This is the
        // acceptance fixture: a forced seed reproduces the bug.
        let seed = explore_expect_failure("sanity_torn_counter", 300,
                                          || {
            let n = Arc::new(AtomicU64::new(0));
            let n2 = Arc::clone(&n);
            use std::sync::atomic::Ordering;
            let h = spawn(move || {
                let v = n2.load(Ordering::SeqCst);
                n2.store(v + 1, Ordering::SeqCst);
            });
            let v = n.load(Ordering::SeqCst);
            n.store(v + 1, Ordering::SeqCst);
            h.join();
            assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
        });
        assert!(seed < 300);
    }

    #[test]
    fn fetch_add_fixes_the_torn_counter() {
        explore("sanity_fetch_add", 120, || {
            let n = Arc::new(AtomicU64::new(0));
            let n2 = Arc::clone(&n);
            use std::sync::atomic::Ordering;
            let h = spawn(move || {
                n2.fetch_add(1, Ordering::SeqCst);
            });
            n.fetch_add(1, Ordering::SeqCst);
            h.join();
            assert_eq!(n.load(Ordering::SeqCst), 2);
        });
    }
}
