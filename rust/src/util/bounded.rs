//! Bounded MPSC channel (in-tree; crossbeam/flume are unavailable in the
//! offline build). The coordinator's pipeline stages are joined by these
//! instead of `std::sync::mpsc` so that a slow stage exerts backpressure on
//! its producer: `send` blocks while the queue is at capacity, which is what
//! keeps `Coordinator::submit()` from letting the window queue outrun the
//! DNN stage.
//!
//! Why not `std::sync::mpsc::sync_channel`? It covers blocking bounded
//! send, but the pipeline also wants queue introspection (`len`,
//! `capacity`) for telemetry and backpressure tests, and one sender/
//! receiver type that covers both the bounded interior queues and the
//! unbounded output queue (`unbounded()`), so the stages compose over a
//! single channel vocabulary we fully control.
//!
//! Semantics mirror `std::sync::mpsc` where they overlap: many senders, one
//! receiver; `recv` returns `Err` only once every sender is dropped AND the
//! queue is drained; `send` returns the value back in `Err` once the
//! receiver is gone.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use crate::util::sync::{Condvar, Instant, Mutex};

/// The receiver disconnected; the unsent value is returned.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Why a `try_send` did not enqueue.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// Queue at capacity right now.
    Full(T),
    /// Receiver gone.
    Disconnected(T),
}

/// All senders disconnected and the queue is drained.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

/// Why a `try_recv` returned nothing.
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    /// Queue empty right now (senders still alive).
    Empty,
    /// Every sender dropped and the queue is drained.
    Disconnected,
}

/// Why a `recv_timeout` returned nothing.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvTimeoutError {
    /// Nothing arrived before the deadline.
    Timeout,
    /// Every sender dropped and the queue is drained.
    Disconnected,
}

struct Inner<T> {
    buf: VecDeque<T>,
    cap: usize,
    senders: usize,
    rx_alive: bool,
}

struct Shared<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// Producer half of a channel; clone freely (MPSC).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Consumer half of a channel; exactly one per channel.
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Create a channel holding at most `cap` in-flight items (min 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner {
            buf: VecDeque::new(),
            cap: cap.max(1),
            senders: 1,
            rx_alive: true,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

/// Create a channel with no capacity bound: `send` never blocks. Used for
/// the coordinator's output queue, where the memory in flight is bounded
/// by the run's own result set and a cap would turn an undrained batch
/// caller into a silent deadlock.
pub fn unbounded<T>() -> (Sender<T>, Receiver<T>) {
    bounded(usize::MAX)
}

/// Fan a job out over per-worker queues: round-robin starting at `*rr`,
/// skipping workers whose queue is full (one slow worker must not
/// head-of-line block the producer while its siblings idle) or
/// disconnected. Blocks only when every live queue is full. A queue
/// that disconnects while we are blocked on it does not fail the
/// dispatch — the surviving workers are retried. Returns `false` iff
/// the job could not be delivered because every worker is gone — the
/// producer should treat that as downstream shutdown.
pub fn send_round_robin<T>(txs: &[Sender<T>], rr: &mut usize, job: T)
                           -> bool {
    let n = txs.len();
    if n == 0 {
        return false;
    }
    let mut job = job;
    loop {
        let mut full_at: Option<usize> = None;
        for k in 0..n {
            let i = (*rr + k) % n;
            match txs[i].try_send(job) {
                Ok(()) => {
                    *rr = i + 1;
                    return true;
                }
                Err(TrySendError::Full(j)) => {
                    if full_at.is_none() {
                        full_at = Some(i);
                    }
                    job = j;
                }
                Err(TrySendError::Disconnected(j)) => job = j,
            }
        }
        match full_at {
            // every live queue is at capacity: wait on the first live
            // one. If it dies while we wait, take the job back and
            // retry the survivors instead of reporting collapse.
            Some(i) => {
                *rr = i + 1;
                match txs[i].send(job) {
                    Ok(()) => return true,
                    Err(SendError(j)) => job = j,
                }
            }
            None => return false, // every worker queue disconnected
        }
    }
}

/// Fan a job out over per-shard queues by queue depth: try the live
/// queue with the fewest queued items first (least-loaded dispatch),
/// falling back to deeper queues, and blocking on the shallowest live
/// queue only when every live queue is at capacity. `*rr` rotates the
/// tie-break so equally-loaded (e.g. all-idle) shards are fed
/// round-robin instead of always hitting shard 0. A queue that
/// disconnects while we are blocked on it does not fail the dispatch —
/// the surviving queues are retried. Returns `false` iff every queue
/// has disconnected — the producer should treat that as downstream
/// shutdown.
pub fn send_least_loaded<T>(txs: &[Sender<T>], rr: &mut usize, job: T)
                            -> bool {
    let n = txs.len();
    if n == 0 {
        return false;
    }
    let start = *rr % n;
    *rr = rr.wrapping_add(1);
    let mut job = job;
    loop {
        // snapshot each depth ONCE (len() is racy and takes the queue
        // lock; a stale ordering only costs dispatch quality, while
        // re-reading inside a sort comparator could violate its total
        // order), then sort by (depth, rotated position) so ties keep
        // the rotation.
        let mut order: Vec<(usize, usize)> = (0..n)
            .map(|k| (txs[(start + k) % n].len(), k))
            .collect();
        order.sort_unstable();
        let mut shallowest_full: Option<usize> = None;
        for &(_, k) in &order {
            let i = (start + k) % n;
            match txs[i].try_send(job) {
                Ok(()) => return true,
                Err(TrySendError::Full(j)) => {
                    if shallowest_full.is_none() {
                        shallowest_full = Some(i);
                    }
                    job = j;
                }
                Err(TrySendError::Disconnected(j)) => job = j,
            }
        }
        match shallowest_full {
            // every live queue is at capacity: block on the shallowest.
            // If that queue dies while we wait, take the job back and
            // retry the survivors instead of reporting collapse.
            Some(i) => match txs[i].send(job) {
                Ok(()) => return true,
                Err(SendError(j)) => job = j,
            },
            None => return false, // every shard queue disconnected
        }
    }
}

/// Fan a job out over worker queues in a caller-chosen preference
/// order: try each listed queue once (skipping full or disconnected
/// ones), and when every live queue is at capacity, block on the most
/// preferred live one. This is the primitive behind the coordinator's
/// *tail-batch* routing, where the preference order is
/// busiest-shard-first (so small deadline-triggered batches pile onto
/// the shard already working instead of waking an idle replica that
/// least-loaded dispatch is keeping clear for full batches). A queue
/// that disconnects while we are blocked on it does not fail the
/// dispatch — the surviving queues are retried. Returns `false` iff
/// every queue has disconnected.
pub fn send_in_order<T>(txs: &[Sender<T>], order: &[usize], job: T)
                        -> bool {
    if txs.is_empty() || order.is_empty() {
        return false;
    }
    let mut job = job;
    loop {
        let mut preferred_full: Option<usize> = None;
        for &i in order {
            if i >= txs.len() {
                continue; // stale preference entry: ignore
            }
            match txs[i].try_send(job) {
                Ok(()) => return true,
                Err(TrySendError::Full(j)) => {
                    if preferred_full.is_none() {
                        preferred_full = Some(i);
                    }
                    job = j;
                }
                Err(TrySendError::Disconnected(j)) => job = j,
            }
        }
        match preferred_full {
            // every live queue is at capacity: block on the most
            // preferred live one. If it dies while we wait, take the
            // job back and retry the survivors.
            Some(i) => match txs[i].send(job) {
                Ok(()) => return true,
                Err(SendError(j)) => job = j,
            },
            None => return false, // every listed queue disconnected
        }
    }
}

/// One slot of a [`QueueSet`]: the live sender (if any) plus a
/// generation counter that increments on every `add`, so a stale actor
/// (e.g. a shard thread whose spawn failed long after its slot was
/// recycled) can prove it still owns the slot before retiring it.
struct QueueSlot<T> {
    tx: Option<Sender<T>>,
    generation: u64,
}

struct QueueTable<T> {
    slots: Vec<QueueSlot<T>>,
    /// set by `close_all`: the set is shutting down and must never
    /// accept another queue (a late `add` would install a queue nobody
    /// will ever close again).
    sealed: bool,
}

/// A fixed table of queue slots whose membership can change *mid-run*:
/// the coordinator's autoscaler adds a slot when it spawns a DNN shard
/// and retires a slot (dropping the `Sender`, so the shard's receiver
/// drains what is queued and then disconnects) when it scales down.
/// Producers dispatch through the set without ever seeing membership
/// edits — a retired queue simply stops accepting and the skip-dead
/// dispatch routes around it, which is exactly the degradation path a
/// crashed shard already exercises.
///
/// Slot ids are stable for the lifetime of the set and bounded by the
/// slot count fixed at construction, so they can index parallel
/// per-slot state (e.g. `Metrics::shards`). A retired slot can be
/// reused by a later `add` (slot ids are recycled, lowest-free-first);
/// each `add` bumps the slot's generation, and `retire_generation`
/// lets an asynchronous owner retire *its own* installation without
/// ever touching a successor that recycled the slot. `close_all`
/// seals the set: every queue closes and no further `add` succeeds,
/// so shutdown cannot race a scale-up into an orphaned queue.
pub struct QueueSet<T> {
    table: Mutex<QueueTable<T>>,
}

impl<T> QueueSet<T> {
    /// An empty set with `n` (min 1) slots, all free.
    pub fn with_slots(n: usize) -> QueueSet<T> {
        QueueSet {
            table: Mutex::new(QueueTable {
                slots: (0..n.max(1))
                    .map(|_| QueueSlot { tx: None, generation: 0 })
                    .collect(),
                sealed: false,
            }),
        }
    }

    /// Total slot count (fixed at construction).
    pub fn slots(&self) -> usize {
        self.table.lock().unwrap().slots.len()
    }

    /// Install a sender into the lowest free slot and return its slot
    /// id, or `None` when every slot is occupied or the set has been
    /// sealed by `close_all`.
    pub fn add(&self, tx: Sender<T>) -> Option<usize> {
        let mut g = self.table.lock().unwrap();
        if g.sealed {
            return None;
        }
        for (i, slot) in g.slots.iter_mut().enumerate() {
            if slot.tx.is_none() {
                slot.tx = Some(tx);
                slot.generation += 1;
                return Some(i);
            }
        }
        None
    }

    /// The slot's current generation (bumped on every `add`; 0 means
    /// never occupied). Read this right after `add` to get a token
    /// that `retire_generation` will honour.
    pub fn generation(&self, slot: usize) -> u64 {
        self.table.lock().unwrap().slots.get(slot)
            .map_or(0, |s| s.generation)
    }

    /// Drop the slot's sender so its receiver drains and disconnects
    /// (graceful retirement). Returns `false` when the slot was already
    /// free.
    pub fn retire(&self, slot: usize) -> bool {
        let mut g = self.table.lock().unwrap();
        match g.slots.get_mut(slot) {
            Some(s) => s.tx.take().is_some(),
            None => false,
        }
    }

    /// Retire the slot only if it still holds the installation that
    /// `add` returned `generation` for. A stale owner (the slot was
    /// since retired and/or recycled) gets `false` and must not touch
    /// the slot's parallel state.
    pub fn retire_generation(&self, slot: usize, generation: u64)
                             -> bool {
        let mut g = self.table.lock().unwrap();
        match g.slots.get_mut(slot) {
            Some(s) if s.generation == generation => {
                s.tx.take().is_some()
            }
            _ => false,
        }
    }

    /// Retire every occupied slot and **seal** the set: all receivers
    /// drain out and every later `add` fails. Shutdown only.
    pub fn close_all(&self) {
        let mut g = self.table.lock().unwrap();
        g.sealed = true;
        for s in g.slots.iter_mut() {
            s.tx = None;
        }
    }

    /// Slot ids currently occupied, ascending.
    pub fn live_slots(&self) -> Vec<usize> {
        self.table.lock().unwrap().slots.iter().enumerate()
            .filter_map(|(i, s)| s.tx.as_ref().map(|_| i))
            .collect()
    }

    /// Number of occupied slots.
    pub fn live_count(&self) -> usize {
        self.table.lock().unwrap().slots.iter()
            .filter(|s| s.tx.is_some())
            .count()
    }

    /// Clone the live senders (and their slot ids) so dispatch can run
    /// without holding the set lock. A clone taken here keeps a queue
    /// deliverable even if the slot is retired mid-dispatch; the
    /// receiver still drains every delivered item before it observes
    /// the disconnect, so no job is lost to the race.
    fn snapshot(&self) -> (Vec<Sender<T>>, Vec<usize>) {
        let g = self.table.lock().unwrap();
        let mut txs = Vec::new();
        let mut ids = Vec::new();
        for (i, s) in g.slots.iter().enumerate() {
            if let Some(tx) = &s.tx {
                txs.push(tx.clone());
                ids.push(i);
            }
        }
        (txs, ids)
    }

    /// `snapshot` without the slot-id vector, for the per-job dispatch
    /// paths that only need the senders (one less allocation on the
    /// hot path).
    fn snapshot_txs(&self) -> Vec<Sender<T>> {
        let g = self.table.lock().unwrap();
        g.slots.iter()
            .filter_map(|s| s.tx.as_ref().cloned())
            .collect()
    }

    /// Least-loaded dispatch over the live slots (see
    /// [`send_least_loaded`]). Returns `false` iff no slot could take
    /// the job (set empty or every live queue disconnected).
    pub fn send_least_loaded(&self, rr: &mut usize, job: T) -> bool {
        let txs = self.snapshot_txs();
        send_least_loaded(&txs, rr, job)
    }

    /// Round-robin dispatch over the live slots (see
    /// [`send_round_robin`]): skip-full, skip-dead, blocking only when
    /// every live queue is at capacity. Membership edits between calls
    /// simply change the rotation length — `*rr` is taken modulo the
    /// current live count. Returns `false` iff no slot could take the
    /// job.
    pub fn send_round_robin(&self, rr: &mut usize, job: T) -> bool {
        let txs = self.snapshot_txs();
        send_round_robin(&txs, rr, job)
    }

    /// Occupancy fraction (0–1) summed over the live queues: total
    /// queued items over total capacity. 0.0 when no slot is live.
    /// Racy like `Sender::len` — telemetry only.
    pub fn occupancy(&self) -> f64 {
        let txs = self.snapshot_txs();
        if txs.is_empty() {
            return 0.0;
        }
        let queued = txs.iter()
            .fold(0usize, |a, t| a.saturating_add(t.len()));
        let cap = txs.iter()
            .fold(0usize, |a, t| a.saturating_add(t.capacity()));
        queued as f64 / cap.max(1) as f64
    }

    /// Preference-ordered dispatch over the live slots (see
    /// [`send_in_order`]): `ranked_slots` lists slot ids most-preferred
    /// first; live slots missing from the ranking are tried last, in
    /// slot order. Returns `false` iff no slot could take the job.
    pub fn send_preferring(&self, ranked_slots: &[usize], job: T) -> bool {
        let (txs, ids) = self.snapshot();
        if txs.is_empty() {
            return false;
        }
        let mut order: Vec<usize> = Vec::with_capacity(txs.len());
        for &slot in ranked_slots {
            if let Some(pos) = ids.iter().position(|&id| id == slot) {
                if !order.contains(&pos) {
                    order.push(pos);
                }
            }
        }
        for pos in 0..txs.len() {
            if !order.contains(&pos) {
                order.push(pos);
            }
        }
        send_in_order(&txs, &order, job)
    }
}

struct FeederShared<T> {
    set: Arc<QueueSet<T>>,
}

impl<T> Drop for FeederShared<T> {
    fn drop(&mut self) {
        self.set.close_all();
    }
}

/// Cloneable producer-side guard over a [`QueueSet`]: when the last
/// clone drops, the set is sealed (`close_all`), so the consuming
/// pool's receivers drain and disconnect exactly when no producer
/// remains. This is how a *set-fed* stage boundary reproduces the
/// plain channel's drop-to-disconnect cascade: per-slot `Sender`s live
/// inside the set (they never drop on their own), so without this
/// guard a downstream pool would block on `recv` forever after its
/// producers exited. The DNN shard pool feeds the decode pool's queue
/// set this way — every shard thread holds a clone, and the last shard
/// out turns off the lights.
pub struct Feeder<T> {
    shared: Arc<FeederShared<T>>,
}

impl<T> Clone for Feeder<T> {
    fn clone(&self) -> Self {
        Feeder { shared: self.shared.clone() }
    }
}

impl<T> Feeder<T> {
    /// Wrap a queue set in a producer guard. All producers must hold
    /// clones of the SAME `Feeder` (clone it; do not call `new` twice
    /// on one set, or the first group to finish seals it early).
    pub fn new(set: Arc<QueueSet<T>>) -> Feeder<T> {
        Feeder { shared: Arc::new(FeederShared { set }) }
    }

    /// Round-robin dispatch over the set's live slots (see
    /// [`QueueSet::send_round_robin`]).
    pub fn send_round_robin(&self, rr: &mut usize, job: T) -> bool {
        self.shared.set.send_round_robin(rr, job)
    }
}

impl<T> Sender<T> {
    /// Block until there is room (backpressure), then enqueue.
    pub fn send(&self, t: T) -> Result<(), SendError<T>> {
        let mut g = self.shared.inner.lock().unwrap();
        loop {
            if !g.rx_alive {
                return Err(SendError(t));
            }
            if g.buf.len() < g.cap {
                g.buf.push_back(t);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            g = self.shared.not_full.wait(g).unwrap();
        }
    }

    /// Enqueue without blocking, or report why not.
    pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
        let mut g = self.shared.inner.lock().unwrap();
        if !g.rx_alive {
            return Err(TrySendError::Disconnected(t));
        }
        if g.buf.len() >= g.cap {
            return Err(TrySendError::Full(t));
        }
        g.buf.push_back(t);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Items currently queued (racy; for telemetry and tests).
    pub fn len(&self) -> usize {
        self.shared.inner.lock().unwrap().buf.len()
    }

    /// True when nothing is queued right now (racy, like `len`).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The channel's capacity bound (`usize::MAX` for unbounded).
    pub fn capacity(&self) -> usize {
        self.shared.inner.lock().unwrap().cap
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.inner.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut g = self.shared.inner.lock().unwrap();
        g.senders -= 1;
        if g.senders == 0 {
            // wake a blocked recv so it can observe the disconnect
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Block until an item arrives or every sender is gone.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut g = self.shared.inner.lock().unwrap();
        loop {
            if let Some(t) = g.buf.pop_front() {
                self.shared.not_full.notify_one();
                return Ok(t);
            }
            if g.senders == 0 {
                return Err(RecvError);
            }
            g = self.shared.not_empty.wait(g).unwrap();
        }
    }

    /// Take the next item without blocking, or say why not.
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut g = self.shared.inner.lock().unwrap();
        if let Some(t) = g.buf.pop_front() {
            self.shared.not_full.notify_one();
            return Ok(t);
        }
        if g.senders == 0 {
            return Err(TryRecvError::Disconnected);
        }
        Err(TryRecvError::Empty)
    }

    /// Block up to `timeout` for the next item.
    pub fn recv_timeout(&self, timeout: Duration)
                        -> Result<T, RecvTimeoutError> {
        let Some(deadline) = Instant::now().checked_add(timeout) else {
            // effectively infinite timeout
            return self.recv()
                .map_err(|_| RecvTimeoutError::Disconnected);
        };
        let mut g = self.shared.inner.lock().unwrap();
        loop {
            if let Some(t) = g.buf.pop_front() {
                self.shared.not_full.notify_one();
                return Ok(t);
            }
            if g.senders == 0 {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            g = self.shared.not_empty.wait_timeout(g, deadline - now)
                .unwrap().0;
        }
    }

    /// Items currently queued (racy; for telemetry and tests).
    pub fn len(&self) -> usize {
        self.shared.inner.lock().unwrap().buf.len()
    }

    /// True when nothing is queued right now (racy, like `len`).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The channel's capacity bound (`usize::MAX` for unbounded).
    pub fn capacity(&self) -> usize {
        self.shared.inner.lock().unwrap().cap
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut g = self.shared.inner.lock().unwrap();
        g.rx_alive = false;
        // wake blocked senders so they can observe the disconnect
        self.shared.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Ok(i));
        }
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
    }

    #[test]
    fn try_send_reports_full() {
        let (tx, rx) = bounded(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(TrySendError::Full(3)));
        assert_eq!(rx.recv(), Ok(1));
        tx.try_send(3).unwrap();
        assert_eq!(rx.recv(), Ok(2));
        assert_eq!(rx.recv(), Ok(3));
    }

    #[test]
    fn backpressure_caps_in_flight() {
        // a producer racing ahead of the consumer never has more than
        // `cap` items in flight: the (cap+1)-th send blocks.
        let (tx, rx) = bounded::<usize>(4);
        let sent = Arc::new(AtomicUsize::new(0));
        let s = sent.clone();
        let h = thread::spawn(move || {
            for i in 0..32 {
                tx.send(i).unwrap();
                s.fetch_add(1, Ordering::SeqCst);
            }
        });
        thread::sleep(Duration::from_millis(100));
        assert_eq!(sent.load(Ordering::SeqCst), 4, "sender ran past cap");
        assert_eq!(rx.len(), 4);
        for i in 0..32 {
            assert_eq!(rx.recv(), Ok(i));
        }
        h.join().unwrap();
        assert_eq!(sent.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn recv_disconnects_after_drain() {
        let (tx, rx) = bounded(4);
        tx.send(7).unwrap();
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(8).unwrap();
        drop(tx2);
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Ok(8));
        assert_eq!(rx.recv(), Err(RecvError));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }

    #[test]
    fn send_fails_without_receiver() {
        let (tx, rx) = bounded(1);
        drop(rx);
        assert_eq!(tx.send(5), Err(SendError(5)));
        assert_eq!(tx.try_send(6), Err(TrySendError::Disconnected(6)));
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (tx, rx) = bounded(1);
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)),
                   Err(RecvTimeoutError::Timeout));
        tx.send(9).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Ok(9));
        drop(tx);
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)),
                   Err(RecvTimeoutError::Disconnected));
    }

    #[test]
    fn round_robin_skips_full_and_dead_workers() {
        let (tx1, rx1) = bounded::<u32>(1);
        let (tx2, rx2) = bounded::<u32>(1);
        let (tx3, rx3) = bounded::<u32>(1);
        let txs = vec![tx1, tx2, tx3];
        let mut rr = 0;
        // fill worker 0, kill worker 1: job must land on worker 2
        assert!(send_round_robin(&txs, &mut rr, 10)); // -> worker 0
        drop(rx2);
        assert!(send_round_robin(&txs, &mut rr, 11)); // skips 1 -> 2
        assert_eq!(rx3.recv(), Ok(11));
        assert_eq!(rx1.recv(), Ok(10));
        // all receivers gone -> undeliverable
        drop(rx1);
        drop(rx3);
        assert!(!send_round_robin(&txs, &mut rr, 12));
    }

    #[test]
    fn round_robin_rotates_over_live_workers() {
        let (tx1, rx1) = bounded::<u32>(4);
        let (tx2, rx2) = bounded::<u32>(4);
        let txs = vec![tx1, tx2];
        let mut rr = 0;
        for v in 0..4 {
            assert!(send_round_robin(&txs, &mut rr, v));
        }
        assert_eq!(rx1.len(), 2);
        assert_eq!(rx2.len(), 2);
        assert_eq!(rx1.recv(), Ok(0));
        assert_eq!(rx2.recv(), Ok(1));
    }

    #[test]
    fn least_loaded_prefers_shallowest_queue() {
        let (tx1, rx1) = bounded::<u32>(4);
        let (tx2, rx2) = bounded::<u32>(4);
        let txs = vec![tx1, tx2];
        // preload queue 0 so queue 1 is strictly shallower
        txs[0].send(0).unwrap();
        txs[0].send(1).unwrap();
        let mut rr = 0;
        assert!(send_least_loaded(&txs, &mut rr, 10));
        assert!(send_least_loaded(&txs, &mut rr, 11));
        assert_eq!(rx2.len(), 2, "both jobs must land on the idle queue");
        assert_eq!(rx2.recv(), Ok(10));
        assert_eq!(rx2.recv(), Ok(11));
        assert_eq!(rx1.recv(), Ok(0));
    }

    #[test]
    fn least_loaded_ties_rotate() {
        let (tx1, rx1) = bounded::<u32>(4);
        let (tx2, rx2) = bounded::<u32>(4);
        let txs = vec![tx1, tx2];
        let mut rr = 0;
        // drain after each dispatch so every call sees an all-idle tie
        assert!(send_least_loaded(&txs, &mut rr, 0));
        assert_eq!(rx1.recv(), Ok(0));
        assert!(send_least_loaded(&txs, &mut rr, 1));
        assert_eq!(rx2.recv(), Ok(1));
        assert!(send_least_loaded(&txs, &mut rr, 2));
        assert_eq!(rx1.recv(), Ok(2));
    }

    #[test]
    fn least_loaded_skips_dead_and_reports_collapse() {
        let (tx1, rx1) = bounded::<u32>(1);
        let (tx2, rx2) = bounded::<u32>(1);
        let txs = vec![tx1, tx2];
        let mut rr = 0;
        drop(rx1);
        assert!(send_least_loaded(&txs, &mut rr, 5));
        assert_eq!(rx2.recv(), Ok(5));
        drop(rx2);
        assert!(!send_least_loaded(&txs, &mut rr, 6),
                "all shards gone must report undeliverable");
    }

    #[test]
    fn round_robin_survives_death_of_blocked_queue() {
        // regression (mirrors the least-loaded case): all queues full
        // -> dispatch blocks; the blocked queue's receiver dies -> the
        // job must reach a surviving worker, not be dropped.
        let (tx1, rx1) = bounded::<u32>(1);
        let (tx2, rx2) = bounded::<u32>(1);
        tx1.send(0).unwrap();
        tx2.send(1).unwrap();
        let txs = vec![tx1, tx2];
        let h = thread::spawn(move || {
            let mut rr = 0; // blocks on worker 0 first
            send_round_robin(&txs, &mut rr, 9)
        });
        thread::sleep(Duration::from_millis(50));
        drop(rx1);
        thread::sleep(Duration::from_millis(50));
        assert_eq!(rx2.recv(), Ok(1)); // make room on the survivor
        assert!(h.join().unwrap(),
                "dispatch must survive the death of the blocked queue");
        assert_eq!(rx2.recv(), Ok(9));
    }

    #[test]
    fn least_loaded_survives_death_of_blocked_queue() {
        // regression: both queues full -> dispatch blocks on the
        // shallowest; that queue's receiver dies -> the job must be
        // re-routed to the survivor, not dropped as "all collapsed".
        let (tx1, rx1) = bounded::<u32>(1);
        let (tx2, rx2) = bounded::<u32>(1);
        tx1.send(0).unwrap();
        tx2.send(1).unwrap();
        let txs = vec![tx1, tx2];
        let h = thread::spawn(move || {
            let mut rr = 0; // start=0: blocks on queue 0 first
            send_least_loaded(&txs, &mut rr, 9)
        });
        thread::sleep(Duration::from_millis(50));
        drop(rx1); // kill the queue the dispatcher is blocked on
        thread::sleep(Duration::from_millis(50));
        assert_eq!(rx2.recv(), Ok(1)); // make room on the survivor
        assert!(h.join().unwrap(),
                "dispatch must survive the death of the blocked queue");
        assert_eq!(rx2.recv(), Ok(9));
    }

    #[test]
    fn unbounded_never_blocks() {
        let (tx, rx) = unbounded();
        for i in 0..10_000 {
            tx.send(i).unwrap(); // would deadlock here if capped
        }
        assert_eq!(rx.len(), 10_000);
        assert_eq!(rx.recv(), Ok(0));
        drop(tx);
        let mut n = 1;
        while rx.recv().is_ok() {
            n += 1;
        }
        assert_eq!(n, 10_000);
    }

    #[test]
    fn send_in_order_respects_preference() {
        let (tx1, rx1) = bounded::<u32>(4);
        let (tx2, rx2) = bounded::<u32>(4);
        let txs = vec![tx1, tx2];
        // preference says queue 1 first, even though queue 0 is idle too
        assert!(send_in_order(&txs, &[1, 0], 7));
        assert_eq!(rx2.recv(), Ok(7));
        assert!(rx1.is_empty());
    }

    #[test]
    fn send_in_order_skips_full_and_dead() {
        let (tx1, rx1) = bounded::<u32>(1);
        let (tx2, rx2) = bounded::<u32>(1);
        let (tx3, rx3) = bounded::<u32>(1);
        tx1.send(0).unwrap(); // preferred queue full
        drop(rx2); // second choice dead
        let txs = vec![tx1, tx2, tx3];
        assert!(send_in_order(&txs, &[0, 1, 2], 9));
        assert_eq!(rx3.recv(), Ok(9));
        // stale out-of-range preference entries are ignored
        assert!(send_in_order(&txs, &[17, 2], 10));
        assert_eq!(rx3.recv(), Ok(10));
        drop(rx1);
        drop(rx3);
        assert!(!send_in_order(&txs, &[0, 1, 2], 11),
                "all queues gone must report undeliverable");
    }

    #[test]
    fn send_in_order_survives_death_of_blocked_queue() {
        // both queues full -> dispatch blocks on the preferred one; its
        // receiver dies -> the job must reach the survivor.
        let (tx1, rx1) = bounded::<u32>(1);
        let (tx2, rx2) = bounded::<u32>(1);
        tx1.send(0).unwrap();
        tx2.send(1).unwrap();
        let txs = vec![tx1, tx2];
        let h = thread::spawn(move || send_in_order(&txs, &[0, 1], 9));
        thread::sleep(Duration::from_millis(50));
        drop(rx1); // kill the queue the dispatcher is blocked on
        thread::sleep(Duration::from_millis(50));
        assert_eq!(rx2.recv(), Ok(1)); // make room on the survivor
        assert!(h.join().unwrap(),
                "dispatch must survive the death of the blocked queue");
        assert_eq!(rx2.recv(), Ok(9));
    }

    #[test]
    fn queue_set_adds_into_lowest_free_slot() {
        let set = QueueSet::<u32>::with_slots(3);
        assert_eq!(set.slots(), 3);
        assert_eq!(set.live_count(), 0);
        assert_eq!(set.generation(0), 0, "never-occupied slot is gen 0");
        let (tx_a, _rx_a) = bounded::<u32>(1);
        let (tx_b, _rx_b) = bounded::<u32>(1);
        assert_eq!(set.add(tx_a), Some(0));
        assert_eq!(set.add(tx_b), Some(1));
        assert_eq!(set.live_slots(), vec![0, 1]);
        assert_eq!(set.generation(0), 1);
        // retiring frees the slot for reuse (lowest-free-first)
        assert!(set.retire(0));
        assert!(!set.retire(0), "double retire must report already-free");
        let (tx_c, _rx_c) = bounded::<u32>(1);
        assert_eq!(set.add(tx_c), Some(0));
        assert_eq!(set.generation(0), 2, "recycling bumps the generation");
        let (tx_d, _rx_d) = bounded::<u32>(1);
        assert_eq!(set.add(tx_d), Some(2));
        let (tx_e, _rx_e) = bounded::<u32>(1);
        assert_eq!(set.add(tx_e), None, "full set must refuse");
        set.close_all();
        assert_eq!(set.live_count(), 0);
        // close_all seals: a racing late add must not install a queue
        // that nobody will ever close again
        let (tx_f, _rx_f) = bounded::<u32>(1);
        assert_eq!(set.add(tx_f), None, "sealed set must refuse adds");
    }

    #[test]
    fn queue_set_retire_generation_ignores_stale_owners() {
        let set = QueueSet::<u32>::with_slots(2);
        let (tx_a, _rx_a) = bounded::<u32>(1);
        let slot = set.add(tx_a).unwrap();
        let stale_gen = set.generation(slot);
        // the slot is retired and recycled before the first owner acts
        assert!(set.retire(slot));
        let (tx_b, rx_b) = bounded::<u32>(1);
        assert_eq!(set.add(tx_b), Some(slot));
        // the stale owner's conditional retire must be a no-op...
        assert!(!set.retire_generation(slot, stale_gen),
                "stale generation must not retire the successor");
        assert_eq!(set.live_slots(), vec![slot]);
        let mut rr = 0;
        assert!(set.send_least_loaded(&mut rr, 9));
        assert_eq!(rx_b.recv(), Ok(9));
        // ...while the current owner's succeeds
        let cur_gen = set.generation(slot);
        assert!(set.retire_generation(slot, cur_gen));
        assert_eq!(set.live_count(), 0);
    }

    #[test]
    fn queue_set_retire_disconnects_receiver_after_drain() {
        let set = QueueSet::<u32>::with_slots(2);
        let (tx, rx) = bounded::<u32>(2);
        let slot = set.add(tx).unwrap();
        let mut rr = 0;
        assert!(set.send_least_loaded(&mut rr, 5));
        set.retire(slot);
        // the queued item survives retirement, then the disconnect lands
        assert_eq!(rx.recv(), Ok(5));
        assert_eq!(rx.recv(), Err(RecvError));
        // an empty set cannot deliver
        assert!(!set.send_least_loaded(&mut rr, 6));
        assert!(!set.send_preferring(&[0, 1], 6));
    }

    #[test]
    fn queue_set_send_preferring_routes_to_ranked_slot() {
        let set = QueueSet::<u32>::with_slots(3);
        let (tx0, rx0) = bounded::<u32>(4);
        let (tx1, rx1) = bounded::<u32>(4);
        let (tx2, rx2) = bounded::<u32>(4);
        assert_eq!(set.add(tx0), Some(0));
        assert_eq!(set.add(tx1), Some(1));
        assert_eq!(set.add(tx2), Some(2));
        // rank slot 2 busiest-first: tail jobs pile onto it
        assert!(set.send_preferring(&[2, 0], 1));
        assert!(set.send_preferring(&[2, 0], 2));
        assert_eq!(rx2.len(), 2);
        // a ranking naming only retired slots falls back to live ones
        set.retire(2);
        assert!(set.send_preferring(&[2], 3));
        assert_eq!(rx0.len() + rx1.len(), 1);
        assert_eq!(rx2.len(), 2, "retired queue must take no new jobs");
    }

    #[test]
    fn queue_set_round_robin_rotates_and_reports_occupancy() {
        let set = QueueSet::<u32>::with_slots(2);
        assert_eq!(set.occupancy(), 0.0, "empty set has no occupancy");
        let (tx0, rx0) = bounded::<u32>(2);
        let (tx1, rx1) = bounded::<u32>(2);
        assert_eq!(set.add(tx0), Some(0));
        assert_eq!(set.add(tx1), Some(1));
        let mut rr = 0;
        for v in 0..4 {
            assert!(set.send_round_robin(&mut rr, v));
        }
        assert_eq!(rx0.len(), 2);
        assert_eq!(rx1.len(), 2);
        // 4 queued over 4 total capacity
        assert!((set.occupancy() - 1.0).abs() < 1e-12);
        assert_eq!(rx0.recv(), Ok(0));
        assert!((set.occupancy() - 0.75).abs() < 1e-12);
        // a retired slot leaves the occupancy math (live queues only)
        set.retire(1);
        assert!((set.occupancy() - 0.5).abs() < 1e-12, "1 of 2 queued");
        drop(rx1);
    }

    #[test]
    fn feeder_last_drop_closes_the_set() {
        let set = Arc::new(QueueSet::<u32>::with_slots(1));
        let (tx, rx) = bounded::<u32>(4);
        assert_eq!(set.add(tx), Some(0));
        let feeder = Feeder::new(set.clone());
        let clone = feeder.clone();
        let mut rr = 0;
        assert!(feeder.send_round_robin(&mut rr, 5));
        drop(feeder);
        // one clone still alive: the queue must stay open
        assert_eq!(rx.recv(), Ok(5));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        drop(clone);
        // last producer gone: sealed + disconnected
        assert_eq!(rx.recv(), Err(RecvError));
        let (tx2, _rx2) = bounded::<u32>(1);
        assert_eq!(set.add(tx2), None, "sealed set must refuse adds");
    }

    #[test]
    fn dropping_receiver_unblocks_sender() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let h = thread::spawn(move || tx.send(2));
        thread::sleep(Duration::from_millis(50));
        drop(rx);
        assert_eq!(h.join().unwrap(), Err(SendError(2)));
    }
}

// Schedule-exploration models for the channel/queue-set invariants
// documented in docs/CONCURRENCY.md. Compiled only under
// `--cfg helix_check`; run via `./ci.sh check`.
#[cfg(all(test, helix_check))]
mod model_tests {
    use super::*;
    use crate::util::check::{explore, spawn};

    /// Every item sent is received exactly once, in order, across all
    /// explored interleavings — including schedules where the sender
    /// blocks on a full queue and schedules with injected spurious
    /// condvar wakeups (the `send`/`recv` wait loops must re-check
    /// their predicates, not trust the wakeup).
    #[test]
    fn model_send_recv_delivers_everything_in_order() {
        explore("model_send_recv_delivers_everything_in_order", 150,
                || {
            let (tx, rx) = bounded::<u32>(2);
            let h = spawn(move || {
                for i in 0..4 {
                    tx.send(i).unwrap();
                }
            });
            let mut got = Vec::new();
            loop {
                match rx.recv() {
                    Ok(v) => got.push(v),
                    Err(RecvError) => break,
                }
            }
            h.join();
            assert_eq!(got, vec![0, 1, 2, 3]);
        });
    }

    /// `recv_timeout` with a generous deadline must NEVER time out
    /// while a sender is runnable and about to deliver: the virtual
    /// clock only fires a deadline when no other thread can make
    /// progress, mirroring real time where a 60s timeout cannot beat
    /// a running sender.
    #[test]
    fn model_recv_timeout_never_fires_early() {
        explore("model_recv_timeout_never_fires_early", 120, || {
            let (tx, rx) = bounded::<u32>(1);
            let h = spawn(move || {
                rx.recv_timeout(Duration::from_secs(60))
            });
            tx.send(7).unwrap();
            assert_eq!(h.join(), Ok(7));
        });
    }

    /// With a live but idle sender, `recv_timeout` must report
    /// `Timeout` (not hang, not `Disconnected`) — and must survive
    /// spurious wakeups by recomputing the remaining deadline rather
    /// than re-waiting the full timeout forever.
    #[test]
    fn model_recv_timeout_fires_when_idle() {
        explore("model_recv_timeout_fires_when_idle", 120, || {
            let (tx, rx) = bounded::<u32>(1);
            let h = spawn(move || {
                rx.recv_timeout(Duration::from_millis(1))
            });
            assert_eq!(h.join(), Err(RecvTimeoutError::Timeout));
            drop(tx);
        });
    }

    /// A sender dropping while the receiver waits with a deadline must
    /// surface as `Disconnected`, never as a spurious `Timeout` and
    /// never as a hang.
    #[test]
    fn model_recv_timeout_sees_disconnect() {
        explore("model_recv_timeout_sees_disconnect", 120, || {
            let (tx, rx) = bounded::<u32>(1);
            let h = spawn(move || {
                rx.recv_timeout(Duration::from_secs(60))
            });
            drop(tx);
            assert_eq!(h.join(),
                       Err(RecvTimeoutError::Disconnected));
        });
    }

    /// PR 4 regression, schedule-exhaustive: a stale owner calling
    /// `retire_generation` with an old token can never kill a slot
    /// that was since recycled by a newer `add` — whatever order the
    /// graceful retire, the recycling `add`, and the stale retire
    /// interleave in.
    #[test]
    fn model_stale_generation_retire_never_kills_recycled_slot() {
        explore(
            "model_stale_generation_retire_never_kills_recycled_slot",
            200, || {
            let set = Arc::new(QueueSet::<u32>::with_slots(1));
            let (tx1, _rx1) = bounded::<u32>(1);
            let slot = set.add(tx1).expect("empty set accepts");
            let g1 = set.generation(slot);
            let set2 = Arc::clone(&set);
            let h = spawn(move || set2.retire_generation(0, g1));
            let retired = set.retire(slot);
            let (tx2, _rx2) = bounded::<u32>(1);
            let slot2 = set.add(tx2);
            let stale = h.join();
            // the single gen-1 installation can be retired at most
            // once, by whichever call got there first
            assert!(!(stale && retired),
                    "one installation retired twice");
            // the recycling add always lands (the slot is free by
            // construction) and must still be live afterwards
            assert_eq!(slot2, Some(0));
            assert_eq!(set.live_slots(), vec![0],
                       "stale retire killed the recycled slot");
        });
    }

    /// `close_all` seals against a racing `add`: whichever order they
    /// land in, a sealed set ends with zero live slots (an add that
    /// slipped in first is closed by `close_all`; one that arrives
    /// after the seal is refused), so shutdown can never orphan a
    /// queue that nobody will close again.
    #[test]
    fn model_close_all_seals_against_racing_add() {
        explore("model_close_all_seals_against_racing_add", 150, || {
            let set = Arc::new(QueueSet::<u32>::with_slots(2));
            let set2 = Arc::clone(&set);
            let h = spawn(move || set2.close_all());
            let (tx, _rx) = bounded::<u32>(1);
            let added = set.add(tx);
            h.join();
            assert_eq!(set.live_count(), 0,
                       "sealed set still has a live slot \
                        (add result: {added:?})");
            let (tx3, _rx3) = bounded::<u32>(1);
            assert_eq!(set.add(tx3), None,
                       "sealed set accepted a post-seal add");
        });
    }

    /// The last-`Feeder`-drop seal chain always unblocks every
    /// receiver: all jobs sent before the producers exit are drained,
    /// and both consumers then observe the disconnect instead of
    /// blocking forever — across all interleavings of the two
    /// producer drops and the consumer recv loops.
    #[test]
    fn model_feeder_last_drop_unblocks_every_receiver() {
        explore("model_feeder_last_drop_unblocks_every_receiver", 150,
                || {
            let set = Arc::new(QueueSet::<u32>::with_slots(2));
            let (tx_a, rx_a) = bounded::<u32>(2);
            let (tx_b, rx_b) = bounded::<u32>(2);
            assert_eq!(set.add(tx_a), Some(0));
            assert_eq!(set.add(tx_b), Some(1));
            let feeder = Feeder::new(Arc::clone(&set));
            let mut producers = Vec::new();
            for base in [0u32, 100] {
                let f = feeder.clone();
                producers.push(spawn(move || {
                    let mut rr = 0;
                    assert!(f.send_round_robin(&mut rr, base));
                    assert!(f.send_round_robin(&mut rr, base + 1));
                }));
            }
            drop(feeder);
            let mut consumers = Vec::new();
            for rx in [rx_a, rx_b] {
                consumers.push(spawn(move || {
                    let mut got = 0usize;
                    while rx.recv().is_ok() {
                        got += 1;
                    }
                    got
                }));
            }
            for p in producers {
                p.join();
            }
            let total: usize =
                consumers.into_iter().map(|c| c.join()).sum();
            assert_eq!(total, 4, "seal chain lost or duplicated jobs");
        });
    }
}
