//! Small in-tree replacements for crates unavailable in the offline build
//! environment (DESIGN.md §Substitutions): a seeded RNG (`rng`), a JSON
//! subset parser (`json`), a property-testing helper (`prop`), and a
//! bounded MPSC channel (`bounded`) used to join the coordinator's
//! pipeline stages with backpressure.

pub mod bounded;
pub mod json;
pub mod prop;
pub mod rng;
