//! Small in-tree replacements for crates unavailable in the offline build
//! environment (DESIGN.md §Substitutions): a seeded RNG (`rng`), a JSON
//! subset parser (`json`), and a property-testing helper (`prop`).

pub mod json;
pub mod prop;
pub mod rng;
