//! Small in-tree replacements for crates unavailable in the offline build
//! environment (DESIGN.md §Substitutions): a seeded RNG (`rng`), a JSON
//! subset parser (`json`), a property-testing helper (`prop`), a
//! bounded MPSC channel (`bounded`) used to join the coordinator's
//! pipeline stages with backpressure, the sync-primitive shim (`sync`)
//! those structures are built on, and — under `--cfg helix_check` — the
//! deterministic schedule explorer (`check`, a zero-dependency
//! loom-lite) that model-tests their concurrency invariants (see
//! docs/CONCURRENCY.md).

pub mod bounded;
#[cfg(helix_check)]
pub mod check;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
