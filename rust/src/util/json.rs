//! Minimal JSON parser/serializer — in-tree replacement for `serde_json`
//! (offline build). Covers the full JSON grammar minus exotic escapes; this
//! is what reads `artifacts/pore_model.json`, `meta.json` and the golden
//! test vectors produced by the python build path.

use std::collections::BTreeMap;
use std::fmt;

/// One parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (always kept as f64)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object, key-sorted for deterministic serialization
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number value truncated to usize, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers -> `Vec<f64>` (the common artifact payload).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).collect())
    }

    /// Array of numbers -> `Vec<f32>` (weight/level payloads).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64().map(|y| y as f32)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.b.get(self.i) {
            None => Err("eof".into()),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('?'));
                            self.i += 4;
                        }
                        Some(&c) => out.push(c as char),
                        None => return Err("eof in escape".into()),
                    }
                    self.i += 1;
                }
                c => {
                    // pass through UTF-8 bytes verbatim
                    let len = utf8_len(c);
                    out.push_str(
                        std::str::from_utf8(&self.b[self.i..self.i + len])
                            .map_err(|e| e.to_string())?);
                    self.i += len;
                }
            }
        }
        Err("eof in string".into())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap().as_str(), Some("hi"));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2]
                       .get("b").unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"k":[1,2.5,"s",null,true]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn f32_vec_helper() {
        let j = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.5]);
    }
}
