//! Synchronization shim: the ONE import point for the `Mutex`/`Condvar`/
//! atomic/`Instant` vocabulary used by the pipeline's hand-rolled
//! concurrency structures (`util::bounded`, the coordinator's
//! [`QuotaGate`](crate::coordinator::net), connection registry, analysis
//! state, and the tiered-shutdown `pending` counter).
//!
//! In a **normal build** every name here is a plain re-export of the
//! `std` type — zero wrappers, identical codegen, nothing to audit.
//!
//! Under **`--cfg helix_check`** the same names resolve to model-aware
//! hybrids that route *model threads* (threads spawned through
//! [`util::check`](crate::util::check) inside a schedule exploration)
//! through the deterministic scheduler:
//!
//! * every lock acquire/release, condvar wait/notify, and atomic op is a
//!   controlled yield point, so seeded schedules can interleave threads
//!   at exactly the places real preemption could;
//! * condvar waits get scheduler-injected **spurious wakeups** and
//!   **virtual-clock timeouts**, so wait-loop predicates and deadline
//!   arithmetic are exercised far beyond what wall-clock tests reach;
//! * [`Instant`] reads virtual nanoseconds from the schedule clock, so
//!   `recv_timeout`-style deadline math is deterministic under the model.
//!
//! Threads NOT registered with the scheduler (every ordinary unit test,
//! even in a `helix_check` build) fall straight through to the `std`
//! primitives, so the regular suite runs unchanged under the check cfg.
//! Mixing model and non-model threads on the *same* structure instance
//! during a schedule is unsupported — model tests own their structures.

#[cfg(not(helix_check))]
pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize};
#[cfg(not(helix_check))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
#[cfg(not(helix_check))]
pub use std::time::Instant;

#[cfg(helix_check)]
pub use model::{AtomicBool, AtomicU64, AtomicUsize, Condvar, Instant,
                Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(helix_check)]
mod model {
    use std::cmp::Ordering as CmpOrdering;
    use std::convert::Infallible;
    use std::ops::{Deref, DerefMut, Sub};
    use std::sync::atomic::Ordering;
    use std::sync::PoisonError;
    use std::time::Duration;

    use crate::util::check;

    /// Model-aware mutex: storage lives in a real `std::sync::Mutex`
    /// (which is what non-model threads use directly); model threads
    /// additionally acquire *logical* ownership through the scheduler,
    /// which is where schedule exploration happens.
    pub struct Mutex<T> {
        storage: std::sync::Mutex<T>,
    }

    impl<T> Mutex<T> {
        /// Wrap `t` (same shape as `std::sync::Mutex::new`).
        pub fn new(t: T) -> Mutex<T> {
            Mutex { storage: std::sync::Mutex::new(t) }
        }

        fn addr(&self) -> usize {
            self as *const Mutex<T> as *const () as usize
        }

        /// Acquire the lock. The `Result` is always `Ok` (the model
        /// never poisons), shaped so `.lock().unwrap()` call sites are
        /// identical to the `std` ones.
        pub fn lock(&self) -> Result<MutexGuard<'_, T>, Infallible> {
            let model = check::is_model_thread();
            if model {
                check::mutex_acquire(self.addr());
            }
            let inner = self.storage.lock()
                .unwrap_or_else(PoisonError::into_inner);
            Ok(MutexGuard { lock: self, inner: Some(inner), model })
        }
    }

    /// Guard returned by [`Mutex::lock`]; releases logical ownership
    /// back to the scheduler (a yield point) when dropped by a model
    /// thread.
    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        inner: Option<std::sync::MutexGuard<'a, T>>,
        model: bool,
    }

    impl<T> Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard holds storage")
        }
    }

    impl<T> DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard holds storage")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // storage first, then logical ownership: a waiter scheduled
            // by the release must find the std mutex already free.
            self.inner.take();
            if self.model {
                check::mutex_release(self.lock.addr());
            }
        }
    }

    /// Atomically release the storage guard without running the normal
    /// Drop (the scheduler-side release already happened inside
    /// `cv_wait_begin`, under the same core lock that registered the
    /// wait — that is what makes release-and-wait atomic).
    fn release_storage<T>(mut guard: MutexGuard<'_, T>) {
        guard.inner.take();
        std::mem::forget(guard);
    }

    /// Result of a [`Condvar::wait_timeout`] under the model.
    #[derive(Clone, Copy, Debug)]
    pub struct WaitTimeoutResult(bool);

    impl WaitTimeoutResult {
        /// True when the wait ended because the (virtual) deadline
        /// passed rather than by notification.
        pub fn timed_out(&self) -> bool {
            self.0
        }
    }

    /// Model-aware condition variable. Model threads wait and notify
    /// through the scheduler (with injected spurious wakeups and
    /// virtual-deadline timeouts); non-model threads delegate to the
    /// embedded `std::sync::Condvar`.
    pub struct Condvar {
        std: std::sync::Condvar,
    }

    impl Condvar {
        /// A fresh condvar (same shape as `std::sync::Condvar::new`).
        pub fn new() -> Condvar {
            Condvar { std: std::sync::Condvar::new() }
        }

        fn addr(&self) -> usize {
            self as *const Condvar as *const () as usize
        }

        /// Release the guard, wait to be woken (notify, or a
        /// scheduler-injected spurious wakeup), re-acquire, return the
        /// new guard. Always `Ok` — shaped for `.wait(g).unwrap()`.
        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>)
                           -> Result<MutexGuard<'a, T>, Infallible> {
            if guard.model {
                let lock = guard.lock;
                check::cv_wait_begin(self.addr(), lock.addr(), None);
                release_storage(guard);
                let _timed_out = check::cv_wait_block();
                lock.lock()
            } else {
                let mut guard = guard;
                let inner = guard.inner.take()
                    .expect("guard holds storage");
                let inner = self.std.wait(inner)
                    .unwrap_or_else(PoisonError::into_inner);
                guard.inner = Some(inner);
                Ok(guard)
            }
        }

        /// [`Condvar::wait`] with a deadline. Under the model the
        /// deadline is virtual: when no other thread can make progress
        /// the schedule clock jumps to it and the wait reports a
        /// timeout.
        pub fn wait_timeout<'a, T>(&self, guard: MutexGuard<'a, T>,
                                   dur: Duration)
            -> Result<(MutexGuard<'a, T>, WaitTimeoutResult), Infallible>
        {
            if guard.model {
                let lock = guard.lock;
                let deadline = check::virtual_deadline(dur);
                check::cv_wait_begin(self.addr(), lock.addr(), deadline);
                release_storage(guard);
                let timed_out = check::cv_wait_block();
                let g = lock.lock()?;
                Ok((g, WaitTimeoutResult(timed_out)))
            } else {
                let mut guard = guard;
                let inner = guard.inner.take()
                    .expect("guard holds storage");
                let (inner, res) = self.std.wait_timeout(inner, dur)
                    .unwrap_or_else(PoisonError::into_inner);
                guard.inner = Some(inner);
                Ok((guard, WaitTimeoutResult(res.timed_out())))
            }
        }

        /// Wake one waiter (the scheduler picks which model waiter
        /// deterministically from the schedule's seed).
        pub fn notify_one(&self) {
            if check::is_model_thread() {
                check::cv_notify_one(self.addr());
            }
            self.std.notify_one();
        }

        /// Wake every waiter.
        pub fn notify_all(&self) {
            if check::is_model_thread() {
                check::cv_notify_all(self.addr());
            }
            self.std.notify_all();
        }
    }

    impl Default for Condvar {
        fn default() -> Condvar {
            Condvar::new()
        }
    }

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum Stamp {
        Real(std::time::Instant),
        /// virtual nanoseconds on the schedule clock.
        Virtual(u64),
    }

    /// Hybrid monotonic timestamp: model threads read virtual
    /// nanoseconds from the schedule clock (every read advances it a
    /// little, so single-threaded time still progresses); non-model
    /// threads get the real `std::time::Instant`. Instants from the two
    /// domains must never be compared — in practice each deadline
    /// computation creates and consumes its instants on one thread.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct Instant(Stamp);

    impl Instant {
        /// The current (virtual or real) time.
        pub fn now() -> Instant {
            if check::is_model_thread() {
                Instant(Stamp::Virtual(check::clock_tick()))
            } else {
                Instant(Stamp::Real(std::time::Instant::now()))
            }
        }

        /// `self + d`, `None` on overflow (callers treat `None` as an
        /// infinite deadline, mirroring `std`).
        pub fn checked_add(&self, d: Duration) -> Option<Instant> {
            match self.0 {
                Stamp::Real(t) => {
                    t.checked_add(d).map(|t| Instant(Stamp::Real(t)))
                }
                Stamp::Virtual(n) => u64::try_from(d.as_nanos()).ok()
                    .and_then(|dn| n.checked_add(dn))
                    .map(|n| Instant(Stamp::Virtual(n))),
            }
        }

        /// Time since this instant (saturating at zero).
        pub fn elapsed(&self) -> Duration {
            Instant::now() - *self
        }

        /// `self - earlier`, saturating at zero like
        /// `std::time::Instant::duration_since` post-1.60.
        pub fn duration_since(&self, earlier: Instant) -> Duration {
            *self - earlier
        }
    }

    impl Sub<Instant> for Instant {
        type Output = Duration;
        fn sub(self, rhs: Instant) -> Duration {
            match (self.0, rhs.0) {
                (Stamp::Real(a), Stamp::Real(b)) => {
                    a.saturating_duration_since(b)
                }
                (Stamp::Virtual(a), Stamp::Virtual(b)) => {
                    Duration::from_nanos(a.saturating_sub(b))
                }
                _ => panic!("helix_check: virtual/real Instant mix"),
            }
        }
    }

    impl PartialOrd for Instant {
        fn partial_cmp(&self, other: &Instant) -> Option<CmpOrdering> {
            Some(self.cmp(other))
        }
    }

    impl Ord for Instant {
        fn cmp(&self, other: &Instant) -> CmpOrdering {
            match (self.0, other.0) {
                (Stamp::Real(a), Stamp::Real(b)) => a.cmp(&b),
                (Stamp::Virtual(a), Stamp::Virtual(b)) => a.cmp(&b),
                _ => panic!("helix_check: virtual/real Instant mix"),
            }
        }
    }

    macro_rules! model_atomic {
        ($(#[$doc:meta])* $name:ident, $std:ty, $prim:ty) => {
            $(#[$doc])*
            pub struct $name {
                v: $std,
            }

            impl $name {
                /// Wrap an initial value.
                pub const fn new(v: $prim) -> $name {
                    $name { v: <$std>::new(v) }
                }

                /// Load (a scheduler yield point; the model always runs
                /// the op itself SeqCst).
                pub fn load(&self, _order: Ordering) -> $prim {
                    check::atomic_yield();
                    self.v.load(Ordering::SeqCst)
                }

                /// Store (a scheduler yield point).
                pub fn store(&self, v: $prim, _order: Ordering) {
                    check::atomic_yield();
                    self.v.store(v, Ordering::SeqCst);
                }

                /// Swap (a scheduler yield point).
                pub fn swap(&self, v: $prim, _order: Ordering) -> $prim {
                    check::atomic_yield();
                    self.v.swap(v, Ordering::SeqCst)
                }
            }
        };
    }

    model_atomic!(
        /// Model-aware `AtomicBool`: identical API, every op is a
        /// scheduler yield point for model threads.
        AtomicBool, std::sync::atomic::AtomicBool, bool);
    model_atomic!(
        /// Model-aware `AtomicU64` (the tiered-shutdown `pending`
        /// counter routes through this, so the two-phase protocol's
        /// load/decrement orderings are schedule-explorable).
        AtomicU64, std::sync::atomic::AtomicU64, u64);
    model_atomic!(
        /// Model-aware `AtomicUsize`.
        AtomicUsize, std::sync::atomic::AtomicUsize, usize);

    impl AtomicU64 {
        /// Add, returning the previous value (a yield point).
        pub fn fetch_add(&self, v: u64, _order: Ordering) -> u64 {
            check::atomic_yield();
            self.v.fetch_add(v, Ordering::SeqCst)
        }

        /// Subtract, returning the previous value (a yield point).
        pub fn fetch_sub(&self, v: u64, _order: Ordering) -> u64 {
            check::atomic_yield();
            self.v.fetch_sub(v, Ordering::SeqCst)
        }
    }

    impl AtomicUsize {
        /// Add, returning the previous value (a yield point).
        pub fn fetch_add(&self, v: usize, _order: Ordering) -> usize {
            check::atomic_yield();
            self.v.fetch_add(v, Ordering::SeqCst)
        }

        /// Subtract, returning the previous value (a yield point).
        pub fn fetch_sub(&self, v: usize, _order: Ordering) -> usize {
            check::atomic_yield();
            self.v.fetch_sub(v, Ordering::SeqCst)
        }
    }
}
