//! Deterministic xoshiro256++ RNG + distributions (normal, geometric-ish)
//! — in-tree replacement for `rand`/`rand_distr` (offline build).
//!
//! Determinism matters here: the Monte-Carlo device studies (Fig 15/16) and
//! the synthetic datasets must be reproducible run-to-run, so every consumer
//! takes an explicit seed.

/// xoshiro256++ by Blackman & Vigna (public domain reference constants).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator (splitmix64-expanded; any seed is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/sigma.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Log-normal such that the *multiplicative* sigma is `rel_sigma` of the
    /// mean — the form device papers use for "X% process variation".
    pub fn lognormal_rel(&mut self, mean: f64, rel_sigma: f64) -> f64 {
        if rel_sigma <= 0.0 {
            return mean;
        }
        let var = (rel_sigma * rel_sigma).ln_1p();
        let mu = mean.ln() - var / 2.0;
        (mu + var.sqrt() * self.normal()).exp()
    }

    /// Random DNA base id in [0, 4).
    #[inline]
    pub fn base(&mut self) -> u8 {
        (self.next_u64() % 4) as u8
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(1);
        let m: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..40_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn lognormal_rel_mean_approx() {
        let mut r = Rng::new(4);
        let m: f64 = (0..40_000).map(|_| r.lognormal_rel(10.0, 0.1)).sum::<f64>()
            / 40_000.0;
        assert!((m - 10.0).abs() < 0.15, "{m}");
    }
}
