//! Tiny property-testing helper — in-tree replacement for `proptest`
//! (offline build). Runs a closure over N randomized cases from a seeded
//! RNG; on failure it reports the case index and seed so the case can be
//! replayed deterministically.

use crate::util::rng::Rng;

/// Run `cases` randomized checks. The closure gets a per-case RNG and the
/// case index; it should panic (assert!) on property violation.
pub fn check<F: FnMut(&mut Rng, usize)>(name: &str, cases: usize, mut f: F) {
    for i in 0..cases {
        let seed = 0x9E37_79B9u64
            .wrapping_mul(i as u64 + 1)
            .wrapping_add(name.len() as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng, i)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {i} (seed {seed}): {:?}",
                   e.downcast_ref::<String>()
                       .map(|s| s.as_str())
                       .or_else(|| e.downcast_ref::<&str>().copied())
                       .unwrap_or("panic"));
        }
    }
}

/// Random DNA sequence of length in [lo, hi].
pub fn dna(rng: &mut Rng, lo: usize, hi: usize) -> Vec<u8> {
    let n = rng.range(lo as i64, hi as i64) as usize;
    (0..n).map(|_| rng.base()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counter", 25, |_, _| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, |_, i| assert!(i < 5, "boom"));
    }

    #[test]
    fn dna_in_bounds() {
        let mut rng = Rng::new(9);
        for _ in 0..100 {
            let s = dna(&mut rng, 3, 12);
            assert!(s.len() >= 3 && s.len() <= 12);
            assert!(s.iter().all(|&b| b < 4));
        }
    }
}
