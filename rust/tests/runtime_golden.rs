//! Integration tests over the real AOT artifacts: HLO text -> PJRT compile
//! -> execute, checked against golden outputs computed by JAX at export
//! time, plus the pallas-vs-jnp cross-check and a full coordinator run.
//! `--features xla` only — the default build's equivalent coverage runs
//! against the native backend in `native_backend.rs`.
//!
//! These tests skip (with a message) when `make artifacts` has not produced
//! artifacts yet, so `cargo test` stays green on a fresh checkout.
#![cfg(feature = "xla")]

use helix::basecall::ctc::LogProbs;
use helix::basecall::NUM_SYMBOLS;
use helix::coordinator::{Coordinator, CoordinatorConfig};
use helix::genome::pore::PoreModel;
use helix::genome::synth::{RunSpec, SequencingRun};
use helix::runtime::meta::{artifacts_available, default_artifacts_dir};
use helix::runtime::{Backend, BackendKind, Engine};
use helix::util::json::Json;

fn artifacts() -> Option<String> {
    let dir = default_artifacts_dir();
    if artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        None
    }
}

#[test]
fn golden_guppy_fp32_matches_jax() {
    let Some(dir) = artifacts() else { return };
    let text = std::fs::read_to_string(format!("{dir}/golden_guppy32.json"))
        .expect("golden file");
    let j = Json::parse(&text).unwrap();
    let input = j.get("input").unwrap().as_f32_vec().unwrap();
    let want = j.get("output").unwrap().as_f32_vec().unwrap();

    let mut engine = Engine::new(&dir).unwrap();
    let exe = engine.load("guppy", 32, 1).unwrap();
    let got = exe.run(&[&input]).unwrap();
    assert_eq!(got.len(), 1);
    let got = &got[0].data;
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-3,
                "logprob {i}: rust-PJRT {g} vs jax {w}");
    }
}

#[test]
fn pallas_and_jnp_artifacts_agree() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    if engine.meta.entries.iter().all(|e| e.name != "guppy_32_jnp_b1") {
        eprintln!("skipping: jnp twin not exported");
        return;
    }
    let window = engine.meta.window;
    let sig: Vec<f32> = (0..window)
        .map(|i| ((i as f32) * 0.37).sin())
        .collect();
    // kernel-bearing artifact
    let a = engine.load("guppy", 32, 1).unwrap().run(&[&sig]).unwrap();
    // pure-jnp twin: load by direct entry lookup
    let entry = engine.meta.entries.iter()
        .find(|e| e.name == "guppy_32_jnp_b1").unwrap().clone();
    let proto = xla::HloModuleProto::from_text_file(
        engine.meta.path_of(&entry).to_str().unwrap()).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let client = xla::PjRtClient::cpu().unwrap();
    let exe = client.compile(&comp).unwrap();
    let lit = xla::Literal::vec1(&sig).reshape(&[1, window as i64]).unwrap();
    let out = exe.execute::<xla::Literal>(&[lit]).unwrap()[0][0]
        .to_literal_sync().unwrap()
        .to_tuple1().unwrap()
        .to_vec::<f32>().unwrap();
    assert_eq!(out.len(), a[0].data.len());
    for (x, y) in out.iter().zip(&a[0].data) {
        assert!((x - y).abs() < 1e-3, "pallas {y} vs jnp {x}");
    }
}

#[test]
fn outputs_are_normalized_log_probs() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let window = engine.meta.window;
    let sig = vec![0.25f32; window];
    let lps = engine.run_windows("guppy", 32, &[sig]).unwrap();
    let lp: &LogProbs = &lps[0];
    for t in 0..lp.t {
        let total: f32 = lp.row(t).iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-3, "t={t}: sum {total}");
        assert_eq!(lp.row(t).len(), NUM_SYMBOLS);
    }
}

#[test]
fn run_windows_handles_ragged_batches() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let window = engine.meta.window;
    // 11 windows: exercises batch tiling + tail padding
    let windows: Vec<Vec<f32>> = (0..11)
        .map(|k| (0..window).map(|i| ((i + k) as f32 * 0.11).cos()).collect())
        .collect();
    let lps = engine.run_windows("guppy", 32, &windows).unwrap();
    assert_eq!(lps.len(), 11);
    // same window in different batch positions must give the same output
    let single = engine.run_windows("guppy", 32, &windows[3..4]).unwrap();
    for (a, b) in lps[3].data.iter().zip(&single[0].data) {
        assert!((a - b).abs() < 1e-4, "batch-position dependence: {a} vs {b}");
    }
}

#[test]
fn quantized_artifacts_execute_and_differ() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    if engine.meta.find("guppy", 5, 1).is_none() {
        eprintln!("skipping: 5-bit artifact not exported");
        return;
    }
    let window = engine.meta.window;
    let sig: Vec<f32> = (0..window).map(|i| (i as f32 * 0.2).sin()).collect();
    let fp = engine.run_windows("guppy", 32, &[sig.clone()]).unwrap();
    let q5 = engine.run_windows("guppy", 5, &[sig]).unwrap();
    // different weights (finetuned) + fake-quant: outputs must differ, but
    // both be valid distributions
    let diff: f32 = fp[0].data.iter().zip(&q5[0].data)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1e-3, "5-bit artifact identical to fp32?");
    let total: f32 = q5[0].row(0).iter().map(|x| x.exp()).sum();
    assert!((total - 1.0).abs() < 1e-3);
}

#[test]
fn coordinator_end_to_end_calls_reads() {
    let Some(dir) = artifacts() else { return };
    let pm = PoreModel::load(&format!("{dir}/pore_model.json")).unwrap();
    let run = SequencingRun::simulate(&pm, RunSpec {
        genome_len: 600,
        coverage: 2,
        read_len_min: 200,
        read_len_max: 300,
        seed: 3,
    });
    let mut coord = Coordinator::new(CoordinatorConfig {
        model: "guppy".into(),
        bits: 32,
        backend: BackendKind::Xla,
        artifacts_dir: dir,
        ..Default::default()
    }).unwrap();
    for r in &run.reads {
        coord.submit(r);
    }
    let called = coord.finish().unwrap();
    assert_eq!(called.len(), run.reads.len());
    for c in &called {
        assert!(!c.seq.is_empty(), "read {} decoded empty", c.read_id);
        assert!(c.seq.iter().all(|&b| b < 4));
        assert!(!c.window_decodes.is_empty());
    }
}
