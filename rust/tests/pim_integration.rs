//! Cross-module integration tests of the PIM simulator: functional
//! hardware models vs their software references, and end-to-end scheme
//! consistency. No artifacts required.

use helix::basecall::ctc::{beam_search, LogProbs};
use helix::basecall::vote::consensus;
use helix::pim::comparator::ComparatorArray;
use helix::pim::crossbar::{crossbar_vmm, exact_vmm, ArrayConfig};
use helix::pim::ctc_engine::decode_on_crossbar;
use helix::pim::mapper::Topology;
use helix::pim::schemes::{evaluate, Scheme};
use helix::util::rng::Rng;

fn random_lp(t: usize, seed: u64) -> LogProbs {
    let mut rng = Rng::new(seed);
    let mut data = Vec::new();
    for _ in 0..t {
        let raw: Vec<f64> = (0..5).map(|_| rng.f64() + 0.05).collect();
        let s: f64 = raw.iter().sum();
        data.extend(raw.iter().map(|p| ((p / s).ln()) as f32));
    }
    LogProbs::new(t, data)
}

#[test]
fn crossbar_ctc_engine_equals_software_beam_over_many_inputs() {
    // the paper's §4.3 mapping must be functionally transparent
    for seed in 0..25u64 {
        let lp = random_lp(15, seed);
        assert_eq!(decode_on_crossbar(&lp, 10), beam_search(&lp, 10),
                   "seed {seed}");
    }
}

#[test]
fn comparator_vote_agrees_with_software_vote() {
    // hardware longest-match + majority == software consensus for
    // substitution-corrupted reads
    let arr = ComparatorArray::paper();
    let mut rng = Rng::new(5);
    for _ in 0..30 {
        let truth: Vec<u8> = (0..25).map(|_| rng.base()).collect();
        let mut a = truth.clone();
        let i = rng.below(a.len());
        a[i] = (a[i] + 1) % 4;
        // hardware path: verify reads align via longest match first
        let m = arr.longest_match(&truth, &truth);
        assert_eq!(m, truth.len().min(arr.symbols_per_row()));
        let cons = consensus(&truth, &[&a, &truth]);
        assert_eq!(cons, truth);
    }
}

#[test]
fn crossbar_vmm_through_8bit_adc_supports_16bit_inference() {
    // ISAAC's operating point: 16-bit operands, 8-bit ADC per slice pass —
    // the result must track the exact product closely enough for inference.
    let mut rng = Rng::new(9);
    let rows = 128;
    let x: Vec<f64> = (0..rows).map(|_| rng.f64()).collect();
    let w: Vec<Vec<f64>> = (0..rows)
        .map(|_| (0..4).map(|_| rng.f64()).collect())
        .collect();
    let cfg = ArrayConfig::default();
    let got = crossbar_vmm(&x, &w, &cfg, 16, 16);
    let want = exact_vmm(&x, &w, 16, 16);
    for (g, e) in got.iter().zip(&want) {
        assert!((g - e).abs() / e.abs().max(1e-9) < 0.02,
                "rel err too big: {g} vs {e}");
    }
}

#[test]
fn full_scheme_matrix_is_finite_and_positive() {
    for topo in Topology::all() {
        for s in Scheme::all() {
            for beam in [2usize, 10, 30] {
                let e = evaluate(s, &topo, beam);
                assert!(e.t_total() > 0.0 && e.t_total().is_finite());
                assert!(e.power_w > 0.0 && e.area_mm2 > 0.0);
                assert!(e.throughput().is_finite());
            }
        }
    }
}

#[test]
fn adc_resolution_bounds_vmm_error_for_the_seat_operating_point() {
    // Helix's operating point: 5-bit quantized model through the 5-bit
    // SOT-MRAM ADC arrays. The ADC-induced error (vs the model's own exact
    // fixed-point product) must be small, and must shrink monotonically as
    // ADC resolution grows. (The *accuracy* argument for SEAT is model-
    // level and validated by the python training sweep, Fig 21/22.)
    let mut rng = Rng::new(11);
    let rows = 64;
    let x: Vec<f64> = (0..rows).map(|_| rng.f64()).collect();
    let w: Vec<Vec<f64>> = (0..rows)
        .map(|_| (0..4).map(|_| rng.f64()).collect())
        .collect();
    let want = exact_vmm(&x, &w, 5, 5);
    let mean_rel = |adc_bits: u32| {
        let cfg = ArrayConfig { adc_bits, ..Default::default() };
        let got = crossbar_vmm(&x, &w, &cfg, 5, 5);
        got.iter().zip(&want)
            .map(|(g, e)| (g - e).abs() / e.abs().max(1e-9))
            .sum::<f64>() / want.len() as f64
    };
    let e3 = mean_rel(3);
    let e5 = mean_rel(5);
    let e8 = mean_rel(8);
    assert!(e5 < 0.10, "5-bit ADC mean rel err {e5}");
    assert!(e8 <= e5 && e5 <= e3, "not monotone: {e8} {e5} {e3}");
}
