//! Deterministic schedule-exploration models over the crate's PUBLIC
//! concurrency surface (the `pub(crate)` internals carry their models
//! as in-file unit tests). Compiled and run only under
//! `RUSTFLAGS="--cfg helix_check"` — `./ci.sh check` drives it.
//!
//! Every test explores seeded interleavings via `util::check::explore`;
//! a failure prints the losing seed, replayable with
//! `HELIX_CHECK_SEED=<seed> RUSTFLAGS="--cfg helix_check" cargo test
//! --test check_models <name>`. See docs/CONCURRENCY.md for the
//! invariant catalog.
#![cfg(helix_check)]

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use helix::coordinator::batcher::{BatchPolicy, TieredBatcher,
                                  LANE_REQUEUE};
use helix::coordinator::AnalysisState;
use helix::util::bounded::{bounded, Feeder, QueueSet};
use helix::util::check;
use helix::util::sync::AtomicU64;

/// Tiered-batcher test item: (re-)enqueue stamp + payload. The stamp
/// stays on std's real clock — `TieredBatcher`'s public API speaks
/// `std::time::Instant` — and a 3600s `max_wait` keeps every
/// deadline-math branch inert so the model exercises only the
/// channel/counter protocol.
struct J(Instant, u32);

fn stamp(j: &J) -> Instant {
    j.0
}

/// Invariant (f): the two-phase tiered shutdown never drops an
/// in-flight escalation. The decode-side protocol is `send the
/// re-queue, THEN decrement pending (Release)`; the batcher may only
/// end the stream after observing pending == 0 (Acquire) and draining
/// the side channel once more. Explored: every interleaving of the
/// escalator against the batcher's shutdown probe.
#[test]
fn model_two_phase_shutdown_never_drops_inflight_escalation() {
    check::explore(
        "model_two_phase_shutdown_never_drops_inflight_escalation",
        150,
        || {
            let (ftx, frx) = bounded::<J>(4);
            let (rtx, rrx) = bounded::<J>(4);
            // one fast-tier window is dispatched and undecided
            let pending = Arc::new(AtomicU64::new(1));
            let p = pending.clone();
            let escalator = check::spawn(move || {
                let _ = rtx.send(J(Instant::now(), 42));
                p.fetch_sub(1, Ordering::Release);
            });
            // fresh intake closes while the decision is in flight
            drop(ftx);
            let mut b = TieredBatcher::new(
                frx,
                rrx,
                BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_secs(3600),
                },
                stamp,
                pending,
            );
            let mut got = Vec::new();
            while let Some((lane, batch)) = b.next_batch() {
                assert_eq!(lane, LANE_REQUEUE,
                           "no fresh items exist; only the re-queue \
                            lane may flush");
                got.extend(batch.items.iter().map(|x| x.1));
            }
            escalator.join();
            assert_eq!(got, vec![42],
                       "in-flight escalation dropped at shutdown");
        },
    );
}

/// PR-9 regression as a model: a clean-FIN tenant purge
/// (`drop_tenant`) racing a late `add_read` still draining out of the
/// analysis queue must never resurrect the tenant — the tombstone
/// makes the late add a no-op regardless of arrival order.
#[test]
fn model_clean_fin_purge_discards_racing_add_read() {
    check::explore(
        "model_clean_fin_purge_discards_racing_add_read",
        120,
        || {
            let st = Arc::new(AnalysisState::new(20));
            let s2 = st.clone();
            let adder = check::spawn(move || {
                s2.add_read(7, 1, vec![1, 2, 3]);
            });
            let dropped = st.drop_tenant(7);
            adder.join();
            assert!(dropped <= 1, "at most the racing read existed");
            assert_eq!(st.reads_indexed(7), 0,
                       "racing add_read resurrected a purged tenant");
            // the tombstone also holds for every later straggler
            st.add_read(7, 2, vec![1, 2, 3]);
            assert_eq!(st.reads_indexed(7), 0,
                       "tombstone must outlive the purge");
        },
    );
}

/// Public QueueSet/Feeder cross-check: a slot retired mid-stream never
/// loses a job — every job a producer pushed is either delivered to a
/// still-drainable queue or reported back as undeliverable, across all
/// interleavings of `Feeder::send` against `retire`.
#[test]
fn model_feeder_routing_conserves_jobs_across_retirement() {
    check::explore(
        "model_feeder_routing_conserves_jobs_across_retirement",
        150,
        || {
            let set = Arc::new(QueueSet::with_slots(2));
            let (tx0, rx0) = bounded::<u32>(8);
            let (tx1, rx1) = bounded::<u32>(8);
            assert_eq!(set.add(tx0), Some(0));
            assert_eq!(set.add(tx1), Some(1));
            let feeder = Feeder::new(set.clone());
            let producer = check::spawn(move || {
                let mut rejected = 0u32;
                for i in 0..3u32 {
                    if feeder.send(i).is_err() {
                        rejected += 1;
                    }
                }
                rejected
            });
            set.retire(0);
            let rejected = producer.join();
            set.close_all();
            let mut delivered = 0u32;
            for rx in [rx0, rx1] {
                while rx.recv().is_ok() {
                    delivered += 1;
                }
            }
            assert_eq!(delivered + rejected, 3,
                       "job lost or duplicated across retirement");
        },
    );
}
